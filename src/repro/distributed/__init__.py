from . import sharding
from .sharding import (act_specs, activation_specs, batch_specs,
                       cache_spec_tree, constrain, dp_axes,
                       named_sharding_tree, param_spec_tree)
