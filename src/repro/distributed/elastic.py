"""Elastic scaling: rebuild the mesh from whatever devices are alive and
re-lay-out a checkpoint onto it.

The checkpoint format stores parameters unsharded by tree path
(repro.checkpoint), and the sharding rules are pure functions of
(param tree, mesh), so scaling from e.g. 256 -> 192 chips after losing
a host is: build the largest valid mesh, recompute specs, restore with
``shardings=``.  The only constraint is that the model axis keeps
dividing the TP-sharded dims -- `candidate_meshes` enumerates valid
shapes largest-first.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax

from . import sharding as shard_lib


def candidate_meshes(n_devices: int, max_model: int = 16
                     ) -> List[Tuple[int, int]]:
    """(data, model) shapes using as many devices as possible, preferring
    larger model-parallel degree (keeps per-device weight shards small)."""
    out = []
    for model in range(min(max_model, n_devices), 0, -1):
        data = n_devices // model
        if data * model >= 1:
            out.append((data, model))
    out.sort(key=lambda dm: (-(dm[0] * dm[1]), -dm[1]))
    return out


def make_elastic_mesh(devices=None, max_model: int = 16):
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    data, model = candidate_meshes(n, max_model)[0]
    used = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:used])


def elastic_restore(ckpt_manager, params_template, cfg=None, *,
                    mesh=None, fsdp: bool = False, step: Optional[int] = None):
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    mesh = mesh or make_elastic_mesh()
    specs = shard_lib.param_spec_tree(params_template, cfg, fsdp=fsdp)
    shardings = shard_lib.named_sharding_tree(specs, mesh)
    step, params, opt, meta = ckpt_manager.restore(
        step, params_template, None, shardings=shardings)
    return mesh, step, params, meta
