"""Fault-tolerance runtime pieces that live OUTSIDE the jitted step:

* ``Heartbeat``        -- per-step deadline watchdog (straggler/hang
                          detection).  On a real fleet the callback would
                          page the controller to re-shard around the slow
                          host; here it records the event and (optionally)
                          raises so the launcher can restart from the last
                          checkpoint.
* ``PreemptionGuard``  -- SIGTERM-aware flag: the train loop checks it
                          every step and checkpoints before exiting, which
                          is how TPU preemption notices are handled.
* ``retry_step``       -- re-execute a step function on transient device
                          errors with exponential backoff, restoring from
                          the last good state.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional


class Heartbeat:
    def __init__(self, deadline_s: float = 300.0,
                 on_straggle: Optional[Callable[[float], None]] = None):
        self.deadline_s = deadline_s
        self.on_straggle = on_straggle
        self.last = time.monotonic()
        self.straggle_events = 0

    def beat(self):
        now = time.monotonic()
        dt = now - self.last
        self.last = now
        if dt > self.deadline_s:
            self.straggle_events += 1
            if self.on_straggle:
                self.on_straggle(dt)
        return dt


class PreemptionGuard:
    """Install with ``with PreemptionGuard() as g: ... if g.fired: ...``"""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.signals = signals
        self.fired = False
        self._old = {}

    def _handler(self, signum, frame):
        self.fired = True

    def __enter__(self):
        for s in self.signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, old in self._old.items():
            signal.signal(s, old)
        return False


def retry_step(fn, *args, retries: int = 3, backoff_s: float = 1.0,
               on_retry: Optional[Callable[[int, Exception], None]] = None,
               jitter: float = 0.5, seed: int = 0,
               sleep: Callable[[float], None] = time.sleep):
    """Run ``fn(*args)``, retrying only errors classified *transient*
    (preemption / interconnect / resource families -- see
    :func:`repro.runtime.guard.classify_error`) with seeded-jittered
    exponential backoff.  Fatal errors (shape / compile / programming
    errors) re-raise immediately: retrying those just fails slower.
    Exhausted retries re-raise the last transient error."""
    from repro.runtime.guard import Backoff, classify_error
    backoff = Backoff(base_s=backoff_s, jitter=jitter, seed=seed)
    attempt = 0
    while True:
        try:
            return fn(*args)
        except Exception as e:  # noqa: BLE001 - triage point
            if classify_error(e) == "fatal":
                raise
            attempt += 1
            if attempt > retries:
                raise
            if on_retry:
                on_retry(attempt, e)
            sleep(backoff.delay(attempt))


def jax_runtime_errors():
    try:
        from jax.errors import JaxRuntimeError
        return JaxRuntimeError
    except Exception:  # pragma: no cover
        return RuntimeError
