"""Sharding rules: name-pattern parameter PartitionSpecs, activation
constraints, and the DP/TP/EP/SP mapping onto the (pod, data, model) mesh.

Axis semantics:
  * ``pod``   -- outermost data parallelism across pods (multi-pod mesh)
  * ``data``  -- intra-pod data parallelism (batch); doubles as the FSDP
                 axis for expert weights on the big MoE archs and as the
                 sequence axis for long-context decode caches
  * ``model`` -- tensor parallelism (heads / ffn hidden / experts / vocab)

Activation constraints are injected through a contextvar so the model
code stays mesh-agnostic: ``constrain(x, "residual")`` is a no-op unless
the launcher installed specs for the current trace.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP_AXES = ("pod", "data")   # batch axes (pod may be absent on 1-pod mesh)


_ACT_SPECS: contextvars.ContextVar[Optional[Dict[str, NamedSharding]]] = \
    contextvars.ContextVar("activation_specs", default=None)


@contextlib.contextmanager
def activation_specs(specs: Dict[str, NamedSharding]):
    tok = _ACT_SPECS.set(specs)
    try:
        yield
    finally:
        _ACT_SPECS.reset(tok)


def constrain(x, name: str):
    specs = _ACT_SPECS.get()
    if specs is None or name not in specs:
        return x
    return jax.lax.with_sharding_constraint(x, specs[name])


def dp_axes(mesh: Mesh):
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# parameter specs by name pattern
# ---------------------------------------------------------------------------

# (regex over the '/'-joined param path, spec builder).  `fsdp_axes`
# enables sharding the big expert / ffn / lora weights over the data
# (and pod) axes too (ZeRO-3 style); `stacked` handles the leading
# scan-group dimension.
def _rules(fsdp_axes, ep_data: bool = False):
    dat = fsdp_axes if fsdp_axes else None
    if ep_data:
        # gather-free expert parallelism: experts stationary, sharded
        # E over 'data' and F over 'model'; tokens move (all-to-all)
        expert_rules = [
            (r"ffn/router$",  P(None, None)),
            (r"ffn/w[ig]$",   P("data", None, "model")),
            (r"ffn/wo$",      P("data", "model", None)),
        ]
    else:
        expert_rules = [
            (r"ffn/router$",  P(dat, None)),
            (r"ffn/w[ig]$",   P("model", None, dat)),
            (r"ffn/wo$",      P("model", dat, None)),
        ]
    return expert_rules + [
        (r"embed/table$",            P("model", None)),
        (r"lm_head/w$",              P(None, "model")),
        # attention
        (r"(mixer|attn)/w[qkv]$",    P(None, "model")),
        (r"(mixer|attn)/wo$",        P("model", None)),
        (r"(mixer|attn)/b[qkv]$",    P("model")),
        # MLA
        (r"mixer/wq_a$",             P(dat, None)),
        (r"mixer/wq_b$",             P(None, "model")),
        (r"mixer/wkv_a$",            P(dat, None)),
        (r"mixer/wkv_b$",            P(None, "model")),
        (r"mixer/(q|kv)_norm$",      P(None)),
        # dense mlp
        (r"(ffn|mlp|shared)/w[ig]$", P(dat, "model")),
        (r"(ffn|mlp|shared)/wo$",    P("model", dat)),
        # mamba
        (r"mixer/in_proj$",          P(None, "model")),
        (r"mixer/conv_w$",           P("model", None)),
        (r"mixer/conv_b$",           P("model")),
        (r"mixer/x_proj$",           P("model", None)),
        (r"mixer/dt_proj$",          P(None, "model")),
        (r"mixer/dt_bias$",          P("model")),
        (r"mixer/A_log$",            None),  # shape-dependent, see below
        (r"mixer/D$",                P("model")),
        (r"mixer/norm_scale$",       P("model")),
        (r"mixer/out_proj$",         P("model", None)),
        # shared-attn in_proj, norms, everything small: replicate
        (r"shared_attn/in_proj$",    P(None, None)),
        (r".*norm.*",                P()),
        (r".*",                      P()),
    ]


def param_spec_tree(params, cfg=None, *, fsdp: bool = False,
                    fsdp_axes=("data",), ep_data: bool = False):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs
    too).  Leaves under `blocks/` carry a leading scan dim -> prepend None.
    """
    rules = _rules(tuple(fsdp_axes) if fsdp else None, ep_data=ep_data)

    def spec_for(path_str: str, ndim: int, stacked: bool):
        base_ndim = ndim - 1 if stacked else ndim
        for pat, spec in rules:
            if re.search(pat, path_str):
                if spec is None:  # A_log: (di,n) for mamba1, (nh,) for m2
                    spec = P("model", None) if base_ndim == 2 else P("model")
                if len(spec) > base_ndim:
                    continue  # rule for a higher-rank leaf (e.g. expert
                              # (E,D,F) rule vs a dense (D,F) ffn)
                spec = P(*(tuple(spec) + (None,) * (base_ndim - len(spec))))
                if stacked:
                    spec = P(None, *spec)
                return spec
        return P()

    def walk(path, leaf):
        path_str = "/".join(str(getattr(k, "key", k)) for k in path)
        stacked = path_str.startswith("blocks/")
        return spec_for(path_str, leaf.ndim, stacked)

    return jax.tree_util.tree_map_with_path(walk, params)


def named_sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache / activation specs
# ---------------------------------------------------------------------------

def batch_specs(mesh: Mesh, input_mode: str):
    """Input shardings for a train/prefill batch."""
    dp = dp_axes(mesh)
    if input_mode == "tokens":
        inp = P(dp, None)
    else:
        inp = P(dp, None, None)
    return {"inputs": NamedSharding(mesh, inp),
            "labels": NamedSharding(mesh, P(dp, None))}


def act_specs(mesh: Mesh, *, seq_shard: bool = False,
              ep_data: bool = False):
    """Residual-stream activation constraint.  seq_shard=True shards the
    sequence over 'model' (sequence parallelism between blocks)."""
    dp = dp_axes(mesh)
    spec = P(dp, "model", None) if seq_shard else P(dp, None, None)
    all_axes = dp + ("model",)
    ep_ax = "data" if ep_data else "model"
    return {"residual": NamedSharding(mesh, spec),
            # MoE dispatch buffer: expert-major rows (EP axis)
            "moe_experts": NamedSharding(mesh, P(ep_ax, None, None)),
            # flat token tables: rows over every mesh axis
            "moe_tokens": NamedSharding(mesh, P(all_axes, None)),
            # Megatron TP intermediates (see ModelConfig.megatron_sp)
            "mlp_hidden": NamedSharding(mesh, P(dp, None, "model")),
            "attn_heads": NamedSharding(mesh, P(dp, "model", None, None))}


def cache_spec_tree(cache_shapes, cfg, mesh: Mesh, batch: int):
    """KV/state cache shardings, matched on exact shapes from the config.
    Batch >= dp size -> shard batch; else shard the sequence axis over
    'data' (long-context single-request serving)."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_sharded = batch >= dp_size and batch % dp_size == 0
    bax = dp if batch_sharded else None
    sax = None if batch_sharded else "data"

    tp = mesh.shape["model"]

    def leaf(path, x):
        path_str = "/".join(str(getattr(k, "key", k)) for k in path)
        stacked = path_str.startswith("blocks/")
        shape = x.shape[1:] if stacked else x.shape
        nd = len(shape)
        if nd == 4 and shape[1] == cfg.n_kv_heads and shape[3] == cfg.hd:
            # attn kv (B, Hkv, S, hd): heads over model when divisible,
            # else the head dim (GQA kv=8 on tp=16)
            if cfg.n_kv_heads % tp == 0:
                spec = P(bax, "model", sax, None)
            else:
                spec = P(bax, None, sax, "model")
        elif nd == 4:
            # mamba2 h (B, nh, N, P): heads over model
            spec = P(bax, "model" if cfg.ssd_heads % tp == 0 else None,
                     None, None)
        elif nd == 3 and shape[1] == cfg.d_inner and cfg.ssm_kind:
            # mamba1 h (B, di, n): channels over model
            spec = P(bax, "model", None)
        elif nd == 3 and cfg.ssm_kind and shape[1] == cfg.conv_kernel - 1:
            # conv cache (B, K-1, C): channels over model
            spec = P(bax, None, "model")
        elif nd == 3:
            # mla latents (B, S, L/dr): seq over data when not batch-sharded
            spec = P(bax, sax, None)
        else:
            spec = P(*([bax] + [None] * (nd - 1)))
        if stacked:
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)
