from .pipeline import DataConfig, SyntheticPipeline
