"""Deterministic synthetic token pipeline.

Production shape: stateful iterator with an explicit, checkpointable
state (epoch, step, PRNG key), shardable across data-parallel hosts
(each host generates only its local slice), and restartable to the exact
batch after preemption -- the properties a real data loader must have
for fault-tolerant training; the token source here is synthetic (a
mixture of Zipf-distributed unigrams and repeated motifs so models have
non-trivial structure to learn).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 1024
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    input_mode: str = "tokens"    # tokens | embeddings
    d_model: int = 0              # for embeddings mode
    motif_len: int = 16
    n_motifs: int = 64


class SyntheticPipeline:
    """state = (step,); every batch is a pure function of (seed, step,
    host_slice) so resume-after-restart is exact."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        if cfg.global_batch % host_count:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self.step = 0
        root = np.random.default_rng(cfg.seed)
        # fixed motif bank (part of the dataset definition, not the state)
        self._motifs = root.integers(
            1, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len))
        # Zipf-ish unigram distribution
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, state: Dict):
        self.step = int(state["step"])

    # -- batch generation ----------------------------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.host_index))

    def _tokens(self, rng, b, s):
        toks = rng.choice(self.cfg.vocab_size, size=(b, s),
                          p=self._probs).astype(np.int32)
        # overwrite random spans with motifs (learnable structure)
        n_spans = max(1, s // (2 * self.cfg.motif_len))
        for i in range(b):
            for _ in range(n_spans):
                m = rng.integers(0, self.cfg.n_motifs)
                start = rng.integers(0, max(1, s - self.cfg.motif_len))
                L = min(self.cfg.motif_len, s - start)
                toks[i, start:start + L] = self._motifs[m, :L]
        return toks

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng_for(self.step)
        self.step += 1
        b, s = self.local_batch, cfg.seq_len
        toks = self._tokens(rng, b, s + 1)
        if cfg.input_mode == "tokens":
            return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
        emb = rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
        return {"inputs": emb, "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
