"""Mixture-of-Experts FFN with top-k routing, shared experts, and
capacity-bounded sort-based dispatch (gather/scatter, NOT the GShard
one-hot-einsum dispatch whose FLOPs would dwarf the expert matmuls).

Dispatch: every (token, slot) pair is ranked within its expert queue via
an argsort of the flat expert assignment; ranks >= capacity are dropped
(their gate mass is simply lost, standard "token dropping").  Tokens are
scattered into an (E*C, D) buffer, experts run as one batched SwiGLU
matmul (E, C, D) x (E, D, F), and results are gathered back weighted by
the (renormalized) top-k gates.

Expert parallelism: the (E, ...) expert weights shard over the "model"
(and optionally "data") mesh axes; XLA turns the scatter/gather into the
dispatch collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from .layers import dense_init, split


def moe_init(key, cfg, dtype=None):
    dtype = dtype or cfg.jparam_dtype()
    d = cfg.d_model
    fe = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    ks = split(key, 5)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(fe)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "wi": jax.random.normal(ks[1], (e, d, fe), dtype) * scale_in,
        "wg": jax.random.normal(ks[2], (e, d, fe), dtype) * scale_in,
        "wo": jax.random.normal(ks[3], (e, fe, d), dtype) * scale_out,
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        kk = split(ks[4], 3)
        p["shared"] = {"wi": dense_init(kk[0], d, fs, dtype),
                       "wg": dense_init(kk[1], d, fs, dtype),
                       "wo": dense_init(kk[2], fs, d, dtype,
                                        scale=1.0 / np.sqrt(fs))}
    return p


def _capacity(n_tokens: int, cfg) -> int:
    c = int(np.ceil(cfg.capacity_factor * n_tokens * cfg.top_k
                    / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # pad to multiple of 8


def moe_block(p, x, cfg):
    """x: (B,S,D) -> (out (B,S,D), aux_loss ())."""
    b, s, d = x.shape
    n = b * s
    k = cfg.top_k
    e = cfg.n_experts
    cap = _capacity(n, cfg)
    xf = x.reshape(n, d)
    xf = constrain(xf, "moe_tokens")

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (N,E)
    gates, idx = jax.lax.top_k(probs, k)                        # (N,k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)

    # load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = e * jnp.sum(me * ce)

    # --- sort-based within-expert ranking --------------------------------
    flat_e = idx.reshape(-1)                                    # (N*k,)
    sort_i = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_i]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank_sorted = jnp.arange(n * k) - starts[sorted_e]
    rank = jnp.zeros((n * k,), jnp.int32).at[sort_i].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, e * cap)        # drop slot

    # --- dispatch ---------------------------------------------------------
    token_id = jnp.repeat(jnp.arange(n), k)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].add(xf[token_id], mode="drop",
                           unique_indices=False)
    he = buf[:e * cap].reshape(e, cap, d)
    he = constrain(he, "moe_experts")  # expert-major over 'model' (EP)

    # --- expert SwiGLU ----------------------------------------------------
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", he,
                                  p["wg"].astype(x.dtype)))
    up = jnp.einsum("ecd,edf->ecf", he, p["wi"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", gate * up, p["wo"].astype(x.dtype))
    y = constrain(y, "moe_experts")
    y = y.reshape(e * cap, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)

    # --- combine ----------------------------------------------------------
    ys = y[slot] * (gates.reshape(-1)[:, None].astype(y.dtype)
                    * keep[:, None])
    ys = constrain(ys, "moe_tokens")
    out = jnp.sum(ys.reshape(n, k, d), axis=1)
    out = constrain(out, "moe_tokens")

    if cfg.n_shared_experts:
        sp = p["shared"]
        hsh = jax.nn.silu(xf @ sp["wg"].astype(x.dtype)) * (
            xf @ sp["wi"].astype(x.dtype))
        out = out + hsh @ sp["wo"].astype(x.dtype)
    return out.reshape(b, s, d), aux * cfg.router_aux_weight


def moe_block_dense_ref(p, x, cfg):
    """Oracle: compute ALL experts for every token, combine with the same
    top-k renormalized gates, no capacity dropping.  O(E) FLOPs -- tests
    only."""
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    gate_full = jnp.zeros_like(probs)
    gate_full = jnp.take_along_axis(
        gate_full, idx, axis=1) * 0  # noop to keep shapes clear
    gfull = jnp.zeros((n, cfg.n_experts), jnp.float32)
    gfull = gfull.at[jnp.arange(n)[:, None], idx].set(gates)
    hg = jax.nn.silu(jnp.einsum("nd,edf->nef", xf, p["wg"].astype(x.dtype)))
    hu = jnp.einsum("nd,edf->nef", xf, p["wi"].astype(x.dtype))
    ye = jnp.einsum("nef,efd->ned", hg * hu, p["wo"].astype(x.dtype))
    out = jnp.einsum("ned,ne->nd", ye.astype(jnp.float32), gfull)
    out = out.astype(x.dtype)
    if cfg.n_shared_experts:
        sp = p["shared"]
        hsh = jax.nn.silu(xf @ sp["wg"].astype(x.dtype)) * (
            xf @ sp["wi"].astype(x.dtype))
        out = out + hsh @ sp["wo"].astype(x.dtype)
    return out.reshape(b, s, d)
