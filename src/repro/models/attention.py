"""Attention for the model stack, pure JAX (the lowering path for
dry-run/roofline; the Pallas kernels in repro.kernels are the TPU fast
path validated against the same oracles).

Three execution strategies, selected by sequence length and config:

* ``simple``    -- full masked attention (small seqs, autodiff handles bwd)
* ``flash``     -- chunked online-softmax with a custom VJP that
                   recomputes per-chunk scores in the backward pass
                   (memory O(S * chunk) instead of O(S^2))
* ``decode``    -- one-token query against a long KV cache

The flash path has two *schedules*, the XLA-level mirror of the
GridPlan lowerings (``repro.core.plan``):

* ``dense``      -- every (q, k-chunk) pair is computed and masked: the
                    bounding-box analogue (2x wasted FLOPs for causal).
* ``triangular`` -- a static python loop over q chunks whose per-row
                    k-extents come from the block domain via
                    ``GridPlan.row_extents()``: the compact block-space
                    analogue (exactly the paper's Theorem-2 work saving
                    applied to the 2-simplex domain of causal attention).

``schedule`` also accepts GridPlan lowering names ("closed_form",
"prefetch_lut", "bounding", "compact"), mapped through
``plan.xla_schedule`` -- the launch configs plumb one lowering knob to
both the Pallas kernels and this XLA path.

GQA is handled by grouping q heads as (Hkv, G) so K/V are never
materialized per-q-head.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domain import make_attention_domain
from repro.core.plan import GridPlan, xla_schedule

NEG_INF = float(-1e30)


def _schedule_name(schedule: str) -> str:
    """Normalize: accept schedules and GridPlan lowering names."""
    if schedule in ("dense", "triangular"):
        return schedule
    return xla_schedule(schedule)


def _mask(qpos, kpos, kind: str, window: int):
    if kind == "full":
        return None
    m = kpos <= qpos
    if kind == "local":
        m &= kpos > qpos - window
    return m


def _apply_mask(s, mask):
    return s if mask is None else jnp.where(mask, s, NEG_INF)


# ---------------------------------------------------------------------------
# simple (full materialization)
# ---------------------------------------------------------------------------

def simple_attention(q, k, v, *, kind="causal", window=0,
                     scale: Optional[float] = None):
    """q: (B,H,Sq,D); k,v: (B,Hkv,Sk,D).  f32 softmax, returns q.dtype."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    s = _apply_mask(s, _mask(qpos, kpos, kind, window))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return o.reshape(b, h, sq, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash: chunked online softmax with custom VJP
# ---------------------------------------------------------------------------

def _chunk_fwd_scan(qg, k, v, kind, window, scale, chunk, q_offset):
    """Online-softmax over k chunks.  qg: (B,Hkv,G,Sq,D); k,v: (B,Hkv,Sk,D).
    Returns o (f32) and lse, both (B,Hkv,G,Sq,*)."""
    b, hkv, g, sq, d = qg.shape
    sk = k.shape[2]
    dv = v.shape[-1]
    nc = sk // chunk
    kc = k.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)[:, None] + q_offset

    def step(carry, inp):
        ci, kci, vci = inp
        acc, m, l = carry
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kci,
                       preferred_element_type=jnp.float32) * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        s = _apply_mask(s, _mask(qpos, kpos, kind, window))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vci.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (jnp.arange(nc), kc, vc))
    l = jnp.where(l == 0, 1.0, l)
    return acc / l, m + jnp.log(l)


def _chunk_bwd_scan(qg, k, v, o, lse, dog, kind, window, scale, chunk,
                    q_offset):
    """Backward for the dense schedule.  Shapes as in _chunk_fwd_scan;
    o/do/lse in the grouped layout.  Returns dqg, dk, dv."""
    b, hkv, g, sq, d = qg.shape
    sk = k.shape[2]
    dv = v.shape[-1]
    nc = sk // chunk
    kc = k.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)[:, None] + q_offset
    delta = jnp.sum(dog * o, axis=-1, keepdims=True)  # (B,Hkv,G,Sq,1)

    def step(dq, inp):
        ci, kci, vci = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kci,
                       preferred_element_type=jnp.float32) * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        s = _apply_mask(s, _mask(qpos, kpos, kind, window))
        p = jnp.exp(s - lse)
        dv_c = jnp.einsum("bhgqk,bhgqd->bhkd", p, dog,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dog, vci.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds,
                             kci.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    dq, (dkc, dvc) = jax.lax.scan(step, dq0, (jnp.arange(nc), kc, vc))
    dk_out = dkc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk, d)
    dv_out = dvc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk, dv)
    return dq, dk_out, dv_out


def _tri_klen(i: int, chunk: int, sk: int, sq: int, kind: str,
              window: int) -> tuple[int, int]:
    """Static (k_start, k_len) for q chunk i under the compact schedule
    with a q/k offset (cross-attention-style sk > sq)."""
    hi = min(sk, (i + 1) * chunk + (sk - sq))
    if kind == "local":
        lo = max(0, (i * chunk + (sk - sq) - window) // chunk * chunk)
    else:
        lo = 0
    return lo, hi - lo


@functools.lru_cache(maxsize=256)
def _compact_extents(kind: str, window: int, chunk: int, sq: int,
                     sk: int) -> tuple:
    """Static per-q-chunk (k_start, k_len) for the compact schedule.

    For the square self-attention case the extents come from the block
    domain itself (``GridPlan.row_extents``), so any domain the engine
    registers schedules correctly; the offset case (sk > sq) keeps the
    token-level closed form.  Cached: re-entered on every fwd AND bwd
    trace of the custom-vjp flash."""
    m_q = sq // chunk
    if sq != sk:
        return tuple(_tri_klen(i, chunk, sk, sq, kind, window)
                     for i in range(m_q))
    wb = (-(-window // chunk) + 1) if kind == "local" else 0
    domain = make_attention_domain(kind, m_q, m_q, wb)
    ext = GridPlan(domain).row_extents()
    return tuple((int(lo) * chunk, (int(hi) + 1 - int(lo)) * chunk)
                 for lo, hi in ext)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, kind, window, scale, chunk, schedule):
    o, _ = _flash_fwd_impl(q, k, v, kind, window, scale, chunk, schedule)
    return o


def _flash_fwd_impl(q, k, v, kind, window, scale, chunk, schedule):
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    q_offset = sk - sq
    if schedule == "dense" or kind == "full":
        o, lse = _chunk_fwd_scan(qg, k, v, kind, window, scale, chunk,
                                 q_offset)
    else:  # triangular / band compact schedule: static loop over q chunks
        nq = sq // chunk
        extents = _compact_extents(kind, window, chunk, sq, sk)
        os_, lses = [], []
        for i in range(nq):
            lo, ln = extents[i]
            qi = qg[:, :, :, i * chunk:(i + 1) * chunk]
            oi, lsei = _chunk_fwd_scan(
                qi, k[:, :, lo:lo + ln], v[:, :, lo:lo + ln], kind, window,
                scale, min(chunk, ln), q_offset + i * chunk - lo)
            os_.append(oi)
            lses.append(lsei)
        o = jnp.concatenate(os_, axis=3)
        lse = jnp.concatenate(lses, axis=3)
    return o.reshape(b, h, sq, v.shape[-1]).astype(q.dtype), lse


def _flash_vjp_fwd(q, k, v, kind, window, scale, chunk, schedule):
    o, lse = _flash_fwd_impl(q, k, v, kind, window, scale, chunk, schedule)
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(kind, window, scale, chunk, schedule, res, do):
    q, k, v, o, lse = res
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dvd = v.shape[-1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    og = o.reshape(b, hkv, g, sq, dvd).astype(jnp.float32)
    dog = do.reshape(b, hkv, g, sq, dvd).astype(jnp.float32)
    q_offset = sk - sq
    if schedule == "dense" or kind == "full":
        dq, dk, dv = _chunk_bwd_scan(qg, k, v, og, lse, dog, kind, window,
                                     scale, chunk, q_offset)
    else:
        nq = sq // chunk
        extents = _compact_extents(kind, window, chunk, sq, sk)
        dq = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
        dk = jnp.zeros((b, hkv, sk, d), jnp.float32)
        dv = jnp.zeros((b, hkv, sk, dvd), jnp.float32)
        for i in range(nq):
            lo, ln = extents[i]
            sl = slice(i * chunk, (i + 1) * chunk)
            dqi, dki, dvi = _chunk_bwd_scan(
                qg[:, :, :, sl], k[:, :, lo:lo + ln], v[:, :, lo:lo + ln],
                og[:, :, :, sl], lse[:, :, :, sl], dog[:, :, :, sl],
                kind, window, scale, min(chunk, ln),
                q_offset + i * chunk - lo)
            dq = dq.at[:, :, :, sl].set(dqi)
            dk = dk.at[:, :, lo:lo + ln].add(dki)
            dv = dv.at[:, :, lo:lo + ln].add(dvi)
    return (dq.reshape(b, h, sq, d).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention_xla(q, k, v, *, kind="causal", window=0,
                        scale: Optional[float] = None, chunk=1024,
                        schedule="dense"):
    schedule = _schedule_name(schedule)
    if scale is None:
        scale = float(1.0 / np.sqrt(q.shape[-1]))
    chunk = min(chunk, k.shape[2])
    if k.shape[2] % chunk:
        raise ValueError("Sk must be divisible by chunk")
    if schedule == "triangular" and q.shape[2] % chunk:
        raise ValueError("Sq must be divisible by chunk for triangular")
    return _flash(q, k, v, kind, window, float(scale), chunk, schedule)


# ---------------------------------------------------------------------------
# decode: one new token against a KV cache
# ---------------------------------------------------------------------------

#: the mesh the block-space decode path shards its continuous-batching
#: slot groups over; set by the serving layer (``set_decode_mesh``) so
#: the model stack stays mesh-agnostic.
_DECODE_MESH = None
_DECODE_AXIS = "data"


def set_decode_mesh(mesh, axis: str = "data") -> None:
    """Register the serving mesh for :func:`decode_attention_flash`
    (``None`` disables sharding).  Called by ``launch/serve.py`` when a
    mesh is in play; the next traced decode step picks it up."""
    global _DECODE_MESH, _DECODE_AXIS
    _DECODE_MESH = mesh
    _DECODE_AXIS = axis


def decode_attention_flash(q, k, v, pos, *, kind="causal", window=0,
                           scale: Optional[float] = None,
                           block_k: int = 128, backend=None, mesh=None,
                           shard_axis: Optional[str] = None):
    """Single-token decode through the block-space Pallas kernel.

    q: (B,H,1,D); k,v: (B,Hkv,Smax,D) caches; pos: () current position,
    or a (B,) int32 vector of *per-row* positions (continuous batching:
    every slot decodes at its own depth; a scalar broadcasts).
    The kernel receives ``pos`` as a run-time operand (SMEM on
    TPU, a regular operand on GPU): keys beyond ``pos`` are masked and
    key *blocks* beyond ``pos // block_k`` are predicated off -- the
    run-time analogue of the paper's block-space work saving.  On the
    gpu structure the in-kernel loop bound truncates outright, so a
    short sequence in a long cache *reads* O(pos / block_k) tiles; on
    the TPU structure the static grid still pipelines every cache tile
    through VMEM and only the dead blocks' compute is skipped
    (``pl.when``), so the tile-traffic saving is gpu-only.
    ``kind='local'`` anchors the sliding window at ``pos`` inside the
    kernel.

    ``mesh`` (default: the registered serving mesh) shards the *batch*
    axis -- continuous-batching slot groups: each device decodes its
    contiguous group of slots with its cache shard, embarrassingly
    parallel (no collectives).  A batch that does not tile the mesh
    axis runs the kernel unsharded instead; a cache length that does
    not tile ``block_k`` falls back to the XLA
    :func:`decode_attention`."""
    b, h, _, d = q.shape
    sk = k.shape[2]
    block_k = min(block_k, sk)
    if sk % block_k:
        return decode_attention(q, k, v, pos, kind=kind, window=window,
                                scale=scale)
    w = window if kind == "local" else 0
    kw = dict(kind="full", window=w, scale=scale, block_q=1,
              block_k=block_k, backend=backend)
    if mesh is None:
        mesh = _DECODE_MESH
    axis = shard_axis or _DECODE_AXIS
    if mesh is None or b % int(mesh.shape[axis]):
        return flash_attention_kernel(q, k, v, seq_pos=pos, **kw)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def device_fn(qd, kd, vd, posd):
        return flash_attention_kernel(qd, kd, vd, seq_pos=posd, **kw)

    posv = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    batched = P(axis, None, None, None)
    return shard_map(
        device_fn, mesh=mesh,
        in_specs=(batched, batched, batched, P(axis)),
        out_specs=batched, check_rep=False)(q, k, v, posv)


def flash_attention_kernel(*args, **kwargs):
    """The Pallas kernel entry point (import indirection keeps the XLA
    model stack importable without the kernels package in play)."""
    from repro.kernels.flash_attention import flash_attention
    return flash_attention(*args, **kwargs)


def decode_attention(q, k, v, pos, *, kind="causal", window=0,
                     scale: Optional[float] = None):
    """q: (B,H,1,D); k,v: (B,Hkv,S,D) cache; pos: () current position
    or (B,) per-row positions.  Keys at kpos > pos (unfilled cache
    tail) are masked out."""
    b, h, _, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(sk)[None, None, None, :]
    pos = jnp.asarray(pos)
    if pos.ndim:  # (B,) per-row decode positions
        pos = pos.reshape(b, 1, 1, 1)
    valid = kpos <= pos
    if kind == "local":
        valid &= kpos > pos - window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v.dtype), v)
    return o.reshape(b, h, 1, v.shape[-1]).astype(q.dtype)


def decode_attention_paged(q, kv_pool, page_table, pos, *,
                           window: int = 0,
                           scale: Optional[float] = None,
                           grid_mode: str = "compact", backend=None,
                           mesh=None, shard_axis: Optional[str] = None,
                           verify: bool = False):
    """Paged single-token decode through the block-space Pallas kernel.

    q: (B,H,1,D) slot queries; kv_pool: (P, 2*Hkv, page_size, D) fused
    page pool; page_table: (B, max_pages) i32; pos: (B,) per-slot
    positions (a scalar broadcasts).  See
    :func:`repro.kernels.flash_attention.paged_flash_attention`.

    ``mesh`` (default: the registered serving mesh) shards the *slot*
    axis: each device decodes its contiguous slot group against its
    page-table rows with the pool replicated -- embarrassingly
    parallel, like the contiguous decode path.  A batch that does not
    tile the mesh axis runs unsharded."""
    b = q.shape[0]
    posv = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    kw = dict(window=window, scale=scale, grid_mode=grid_mode,
              backend=backend, verify=verify)
    if mesh is None:
        mesh = _DECODE_MESH
    axis = shard_axis or _DECODE_AXIS
    if mesh is None or b % int(mesh.shape[axis]):
        return paged_attention_kernel(q, kv_pool, page_table, posv, **kw)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def device_fn(qd, pool, ptd, posd):
        return paged_attention_kernel(qd, pool, ptd, posd, **kw)

    return shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis, None, None, None), P(None, None, None, None),
                  P(axis, None), P(axis)),
        out_specs=P(axis, None, None, None), check_rep=False)(
            q, kv_pool, page_table.astype(jnp.int32), posv)


def paged_attention_kernel(*args, **kwargs):
    """Import indirection for the paged Pallas kernel (as
    :func:`flash_attention_kernel`)."""
    from repro.kernels.flash_attention import paged_flash_attention
    return paged_flash_attention(*args, **kwargs)


def decode_attention_paged_xla(q, kv_pool, page_table, pos, *,
                               window: int = 0,
                               scale: Optional[float] = None):
    """Pure-XLA paged decode: gather the mapped pages back into
    contiguous caches, then run :func:`decode_attention`.  The oracle
    of the paged bit-identity tests and the degradation ladder's
    ``paged-xla`` rung (no Pallas in the loop)."""
    from repro.core.paged import gather_kv
    k, v = gather_kv(kv_pool, page_table)
    kind = "local" if window else "causal"
    return decode_attention(q, k, v, pos, kind=kind, window=window,
                            scale=scale)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def attention(q, k, v, *, kind="causal", window=0, scale=None,
              chunk=1024, schedule="dense", flash_threshold=8192):
    """schedule: "dense" | "triangular", or any GridPlan lowering name
    ("closed_form" | "prefetch_lut" | "bounding" | "compact")."""
    sq, sk = q.shape[2], k.shape[2]
    if sq == 1:
        raise ValueError("use decode_attention for single-token queries")
    if max(sq, sk) <= flash_threshold:
        return simple_attention(q, k, v, kind=kind, window=window,
                                scale=scale)
    return flash_attention_xla(q, k, v, kind=kind, window=window,
                               scale=scale, chunk=chunk, schedule=schedule)
