from . import attention, layers, mla, model, moe, ssm
from .config import ModelConfig
from .model import (abstract_init, decode_step, decode_step_paged,
                    forward, init, init_cache, init_paged_cache,
                    logits_fn, loss_fn, prefill, scatter_prefill_pages)
