from . import attention, layers, mla, model, moe, ssm
from .config import ModelConfig
from .model import (abstract_init, decode_step, forward, init, init_cache,
                    logits_fn, loss_fn, prefill)
