"""Model assembly: embeddings -> scanned layer groups -> head.

Layers are grouped by their repeating signature (attention pattern,
MoE period, hybrid shared-attention period) and executed with
``lax.scan`` over stacked parameters -- one traced body per
architecture regardless of depth (compile-time matters: 40 dry-run
cells x 2 meshes).  A non-scanned prefix covers e.g. DeepSeek's
first-dense layer.

Three entry points per architecture:
  * ``loss_fn``     -- train forward + chunked cross-entropy
  * ``prefill``     -- forward returning per-layer caches + last logits
  * ``decode_step`` -- one token through all layers with cache update

Cache pytrees mirror the parameter layout ({prefix_i, blocks.slot_s})
so the same scan drives both.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import mla as mla_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from .config import ModelConfig
from repro.distributed.sharding import constrain


# ---------------------------------------------------------------------------
# layer signatures and grouping
# ---------------------------------------------------------------------------

def layer_sig(cfg: ModelConfig, i: int) -> Tuple[str, str, str, bool]:
    mixer = cfg.layer_mixer(i)
    akind = cfg.attn_kind(i) if mixer in ("attn", "mla") else ""
    ffn = cfg.layer_ffn(i) if cfg.d_ff or cfg.moe else "none"
    if cfg.family == "hybrid":
        ffn = "none"  # zamba-style: MLP lives in the shared block
    return (mixer, akind, ffn, cfg.has_shared_attn(i))


def _lcm(*xs):
    out = 1
    for x in xs:
        out = math.lcm(out, max(1, x))
    return out


def group_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """Returns (prefix_len, period, n_groups); prefix layers are unscanned."""
    period = _lcm(len(cfg.attn_pattern) if cfg.ssm_kind is None else 1,
                  cfg.moe_period if cfg.moe else 1,
                  cfg.hybrid_attn_period or 1)
    prefix = cfg.first_dense
    rest = cfg.n_layers - prefix
    if rest % period:
        prefix += rest % period
        rest = cfg.n_layers - prefix
    # slot signatures must not depend on the group index
    for s in range(period):
        sigs = {layer_sig(cfg, prefix + g * period + s)
                for g in range(rest // period)}
        assert len(sigs) <= 1, f"slot {s} not scan-invariant: {sigs}"
    return prefix, period, rest // period


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ModelConfig, i: int):
    mixer, akind, ffn, shared = layer_sig(cfg, i)
    ks = L.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.rmsnorm_init(cfg.d_model,
                                                 cfg.jparam_dtype())}
    if mixer == "attn":
        p["mixer"] = L.attn_init(ks[0], cfg)
    elif mixer == "mla":
        p["mixer"] = mla_lib.mla_init(ks[0], cfg)
    elif mixer == "mamba1":
        p["mixer"] = ssm_lib.mamba1_init(ks[0], cfg)
    elif mixer == "mamba2":
        p["mixer"] = ssm_lib.mamba2_init(ks[0], cfg)
    if ffn != "none":
        p["norm2"] = L.rmsnorm_init(cfg.d_model, cfg.jparam_dtype())
        if ffn == "dense":
            p["ffn"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.jparam_dtype())
        else:
            p["ffn"] = moe_lib.moe_init(ks[1], cfg)
    return p


def shared_attn_init(key, cfg: ModelConfig):
    """Zamba-style weight-shared attention+MLP block (simplified: single
    shared block, concat with the initial embedding, no LoRA adapters)."""
    ks = L.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], 2 * cfg.d_model, cfg.d_model,
                                cfg.jparam_dtype()),
        "norm1": L.rmsnorm_init(cfg.d_model, cfg.jparam_dtype()),
        "attn": L.attn_init(ks[1], cfg),
        "norm2": L.rmsnorm_init(cfg.d_model, cfg.jparam_dtype()),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.jparam_dtype()),
    }


def _shared_block(sp, h, h0, cfg, positions, mode, cache=None, pos=None):
    u = jnp.concatenate([h, h0], axis=-1) @ sp["in_proj"].astype(h.dtype)
    un = L.rmsnorm(sp["norm1"], u, cfg.norm_eps)
    new_cache = None
    if mode == "train":
        a = L.attn_block(sp["attn"], un, cfg, "global", positions)
    elif mode == "prefill":
        a, new_cache = L.attn_block_prefill(sp["attn"], un, cfg, "global",
                                            positions)
    else:
        a, new_cache = L.attn_block_decode(sp["attn"], un, cfg, "global",
                                           cache, pos)
    u = u + a
    u = u + L.mlp(sp["mlp"], L.rmsnorm(sp["norm2"], u, cfg.norm_eps),
                  megatron_sp=cfg.megatron_sp)
    return h + u, new_cache


def _pad_seq(x, axis, max_len):
    if max_len is None or x.shape[axis] >= max_len:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, max_len - x.shape[axis])
    return jnp.pad(x, pad)


def apply_layer(p, h, sig, cfg, positions, *, mode="train", cache=None,
                pos=None, h0=None, shared_params=None, max_len=None):
    """Returns (h, aux, new_cache)."""
    mixer, akind, ffn, shared = sig
    aux = jnp.zeros((), jnp.float32)
    hn = L.rmsnorm(p["norm1"], h, cfg.norm_eps)
    new_cache: Dict[str, Any] = {}
    cache = cache or {}

    if mixer == "attn":
        if mode == "train":
            out = L.attn_block(p["mixer"], hn, cfg, akind, positions)
        elif mode == "prefill":
            out, c = L.attn_block_prefill(p["mixer"], hn, cfg, akind,
                                          positions)
            new_cache["mixer"] = tuple(_pad_seq(t, 2, max_len) for t in c)
        else:
            out, c = L.attn_block_decode(p["mixer"], hn, cfg, akind,
                                         cache["mixer"], pos)
            new_cache["mixer"] = c
    elif mixer == "mla":
        if mode == "train":
            out = mla_lib.mla_block(p["mixer"], hn, cfg, positions)
        elif mode == "prefill":
            out, c = mla_lib.mla_block(p["mixer"], hn, cfg, positions,
                                       return_cache=True)
            new_cache["mixer"] = tuple(_pad_seq(t, 1, max_len) for t in c)
        else:
            out, c = mla_lib.mla_decode(p["mixer"], hn, cfg,
                                        cache["mixer"], pos)
            new_cache["mixer"] = c
    elif mixer in ("mamba1", "mamba2"):
        blk = (ssm_lib.mamba1_block if mixer == "mamba1"
               else ssm_lib.mamba2_block)
        dec = (ssm_lib.mamba1_decode if mixer == "mamba1"
               else ssm_lib.mamba2_decode)
        if mode == "train":
            out = blk(p["mixer"], hn, cfg)
        elif mode == "prefill":
            out, c = blk(p["mixer"], hn, cfg, return_cache=True)
            new_cache["mixer"] = c
        else:
            out, c = dec(p["mixer"], hn, cfg, cache["mixer"])
            new_cache["mixer"] = c
    else:
        raise ValueError(mixer)
    h = h + out
    h = constrain(h, "residual")

    if ffn != "none":
        hn = L.rmsnorm(p["norm2"], h, cfg.norm_eps)
        if ffn == "dense":
            h = h + L.mlp(p["ffn"], hn, megatron_sp=cfg.megatron_sp)
        else:
            out, a = moe_lib.moe_block(p["ffn"], hn, cfg)
            h = h + out
            aux = aux + a
        h = constrain(h, "residual")

    if shared:
        h, c = _shared_block(shared_params, h, h0, cfg, positions, mode,
                             cache=cache.get("shared"), pos=pos)
        if mode == "prefill":
            new_cache["shared"] = tuple(_pad_seq(t, 2, max_len) for t in c)
        elif mode == "decode":
            new_cache["shared"] = c
        h = constrain(h, "residual")
    return h, aux, (new_cache if mode != "train" else None)


# ---------------------------------------------------------------------------
# full model: init
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig):
    prefix, period, n_groups = group_layout(cfg)
    keys = L.split(key, 6)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = L.embed_init(keys[0], cfg.padded_vocab,
                                       cfg.d_model, cfg.jparam_dtype())
    for i in range(prefix):
        params[f"prefix_{i}"] = layer_init(
            jax.random.fold_in(keys[1], i), cfg, i)
    if n_groups:
        blocks = {}
        for s in range(period):
            gkeys = jnp.stack([jax.random.fold_in(keys[2], g * period + s)
                               for g in range(n_groups)])
            blocks[f"slot_{s}"] = jax.vmap(
                lambda kk, s=s: layer_init(kk, cfg, prefix + s))(gkeys)
        params["blocks"] = blocks
    if cfg.hybrid_attn_period:
        params["shared_attn"] = shared_attn_init(keys[3], cfg)
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, cfg.jparam_dtype())
    params["lm_head"] = L.lm_head_init(keys[4], cfg.d_model,
                                       cfg.padded_vocab,
                                       cfg.jparam_dtype())
    return params


def abstract_init(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(params, inputs, cfg):
    if cfg.input_mode == "tokens":
        return L.embed(params["embed"], inputs, cfg.jdtype())
    return inputs.astype(cfg.jdtype())


def forward(params, inputs, cfg: ModelConfig):
    """Full-sequence forward -> (hidden (B,S,D), aux_loss)."""
    prefix, period, n_groups = group_layout(cfg)
    h = _embed_inputs(params, inputs, cfg)
    h = constrain(h, "residual")
    s = h.shape[1]
    positions = jnp.arange(s)
    h0 = h
    aux = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")

    for i in range(prefix):
        h, a, _ = apply_layer(params[f"prefix_{i}"], h, layer_sig(cfg, i),
                              cfg, positions, h0=h0, shared_params=shared)
        aux = aux + a

    if n_groups:
        sigs = [layer_sig(cfg, prefix + s_) for s_ in range(period)]

        def body(carry, xs):
            h, aux = carry
            for s_ in range(period):
                h, a, _ = apply_layer(xs[f"slot_{s_}"], h, sigs[s_], cfg,
                                      positions, h0=h0,
                                      shared_params=shared)
                aux = aux + a
            return (h, aux), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["blocks"])

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, aux


def logits_fn(params, inputs, cfg):
    h, aux = forward(params, inputs, cfg)
    return L.lm_head(params["lm_head"], h), aux


def _xent(logits, labels):
    """f32 cross entropy; logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: {"inputs": (B,S) tokens | (B,S,D) embeds, "labels": (B,S)}"""
    h, aux = forward(params, batch["inputs"], cfg)
    labels = batch["labels"]
    w = params["lm_head"]["w"]
    if cfg.logit_chunk and h.shape[1] % cfg.logit_chunk == 0:
        nc = h.shape[1] // cfg.logit_chunk
        hc = h.reshape(h.shape[0], nc, cfg.logit_chunk, h.shape[2])
        lc = labels.reshape(labels.shape[0], nc, cfg.logit_chunk)

        def chunk_ce(args):
            hh, ll = args
            return _xent(hh @ w.astype(hh.dtype), ll)

        ce = jax.lax.map(chunk_ce, (hc.transpose(1, 0, 2, 3),
                                    lc.transpose(1, 0, 2)))
        loss = jnp.mean(ce)
    else:
        logits = L.lm_head(params["lm_head"], h)
        loss = jnp.mean(_xent(logits, labels))
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": jnp.asarray(labels.size, jnp.float32)}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Zero caches for decode-from-scratch (or shapes for the dry run)."""
    prefix, period, n_groups = group_layout(cfg)
    dt = cfg.jdtype()

    def one(i):
        mixer, akind, ffn, shared = layer_sig(cfg, i)
        c: Dict[str, Any] = {}
        if mixer == "attn":
            kv = (jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
                  jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.hd), dt))
            c["mixer"] = kv
        elif mixer == "mla":
            c["mixer"] = (
                jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
                jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt))
        elif mixer == "mamba1":
            c["mixer"] = (
                jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
                jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dt))
        elif mixer == "mamba2":
            c["mixer"] = (
                jnp.zeros((batch, cfg.ssd_heads, cfg.d_state,
                           cfg.ssd_head_dim), jnp.float32),
                jnp.zeros((batch, cfg.conv_kernel - 1,
                           cfg.d_inner + 2 * cfg.d_state), dt))
        if shared:
            c["shared"] = (
                jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
                jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.hd), dt))
        return c

    cache: Dict[str, Any] = {}
    for i in range(prefix):
        cache[f"prefix_{i}"] = one(i)
    if n_groups:
        blocks = {}
        for s in range(period):
            blocks[f"slot_{s}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape),
                one(prefix + s))
        cache["blocks"] = blocks
    return cache


def decode_step(params, inputs, cache, pos, cfg: ModelConfig):
    """One token for the whole batch.  inputs: (B,1) tokens or (B,1,D).
    pos: () int32 current position.  Returns (logits (B,1,V), cache)."""
    prefix, period, n_groups = group_layout(cfg)
    h = _embed_inputs(params, inputs, cfg)
    h0 = h
    shared = params.get("shared_attn")
    new_cache: Dict[str, Any] = {}

    for i in range(prefix):
        h, _, c = apply_layer(params[f"prefix_{i}"], h, layer_sig(cfg, i),
                              cfg, None, mode="decode",
                              cache=cache[f"prefix_{i}"], pos=pos, h0=h0,
                              shared_params=shared)
        new_cache[f"prefix_{i}"] = c

    if n_groups:
        sigs = [layer_sig(cfg, prefix + s_) for s_ in range(period)]

        def body(h, xs):
            pslots, cslots = xs
            out_c = {}
            for s_ in range(period):
                h, _, c = apply_layer(pslots[f"slot_{s_}"], h, sigs[s_],
                                      cfg, None, mode="decode",
                                      cache=cslots[f"slot_{s_}"], pos=pos,
                                      h0=h0, shared_params=shared)
                out_c[f"slot_{s_}"] = c
            return h, out_c

        h, blocks_cache = jax.lax.scan(
            body, h, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = blocks_cache

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return L.lm_head(params["lm_head"], h), new_cache


def prefill(params, inputs, cfg: ModelConfig, max_len: int | None = None):
    """Full-sequence forward returning last-position logits + caches.
    ``max_len`` pre-pads the KV caches so decode can continue in place."""
    prefix, period, n_groups = group_layout(cfg)
    h = _embed_inputs(params, inputs, cfg)
    s = h.shape[1]
    positions = jnp.arange(s)
    h0 = h
    shared = params.get("shared_attn")
    caches: Dict[str, Any] = {}

    for i in range(prefix):
        h, _, c = apply_layer(params[f"prefix_{i}"], h, layer_sig(cfg, i),
                              cfg, positions, mode="prefill", h0=h0,
                              shared_params=shared, max_len=max_len)
        caches[f"prefix_{i}"] = c

    if n_groups:
        sigs = [layer_sig(cfg, prefix + s_) for s_ in range(period)]

        def body(h, pslots):
            out_c = {}
            for s_ in range(period):
                h, _, c = apply_layer(pslots[f"slot_{s_}"], h, sigs[s_],
                                      cfg, positions, mode="prefill",
                                      h0=h0, shared_params=shared,
                                      max_len=max_len)
                out_c[f"slot_{s_}"] = c
            return h, out_c

        h, blocks_cache = jax.lax.scan(body, h, params["blocks"])
        caches["blocks"] = blocks_cache

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_head(params["lm_head"], h[:, -1:])
    return logits, caches


# ---------------------------------------------------------------------------
# serving: paged KV (continuous batching)
# ---------------------------------------------------------------------------

def _check_paged(cfg: ModelConfig) -> None:
    """Paged serving covers plain-attention stacks (every mixer 'attn',
    no shared block): MLA/SSM caches are not (K, V) pages."""
    for i in range(cfg.n_layers):
        mixer, _, _, shared = layer_sig(cfg, i)
        if mixer != "attn" or shared:
            raise ValueError(
                f"paged serving needs an attention-only stack; layer "
                f"{i} is {mixer!r}" + (" + shared block" if shared
                                       else ""))


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    """Per-layer fused-KV page pools, the paged analogue of
    :func:`init_cache`.  One *shared* (B, max_pages) page table (built
    by the scheduler) addresses every layer's pool: the layers hold
    different values at identical page indices."""
    from repro.core import paged as paged_lib

    _check_paged(cfg)
    prefix, period, n_groups = group_layout(cfg)
    dt = cfg.jdtype()

    def one():
        return {"mixer": paged_lib.init_pool(
            num_pages, cfg.n_kv_heads, page_size, cfg.hd, dt)}

    cache: Dict[str, Any] = {}
    for i in range(prefix):
        cache[f"prefix_{i}"] = one()
    if n_groups:
        cache["blocks"] = {
            f"slot_{s}": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (n_groups,) + x.shape), one())
            for s in range(period)}
    return cache


def scatter_prefill_pages(pools, caches, pages, cfg: ModelConfig):
    """Admission: scatter one request's prefill KV (a batch-1
    :func:`prefill` cache pytree, S tokens) into its allocated pages
    across every layer pool.  ``pages``: (n,) i32 physical page ids,
    ``n * page_size >= S`` (tail pages zero-padded, masked by seq_pos
    at read time).  Returns the updated pools pytree."""
    from repro.core import paged as paged_lib

    prefix, period, n_groups = group_layout(cfg)
    out: Dict[str, Any] = {}
    for i in range(prefix):
        k, v = caches[f"prefix_{i}"]["mixer"]
        out[f"prefix_{i}"] = {"mixer": paged_lib.write_prefill_pages(
            pools[f"prefix_{i}"]["mixer"], pages, k[0], v[0])}
    if n_groups:
        blocks: Dict[str, Any] = {}
        for s in range(period):
            k, v = caches["blocks"][f"slot_{s}"]["mixer"]
            blocks[f"slot_{s}"] = {"mixer": jax.vmap(
                lambda p, kk, vv: paged_lib.write_prefill_pages(
                    p, pages, kk[0], vv[0]))(
                pools["blocks"][f"slot_{s}"]["mixer"], k, v)}
        out["blocks"] = blocks
    return out


def _paged_layer(p, h, sig, cfg, pool, page_table, pos, active):
    mixer, akind, ffn, shared = sig
    hn = L.rmsnorm(p["norm1"], h, cfg.norm_eps)
    out, pool = L.attn_block_decode_paged(
        p["mixer"], hn, cfg, akind, pool, page_table, pos, active)
    h = h + out
    h = constrain(h, "residual")
    if ffn != "none":
        hn = L.rmsnorm(p["norm2"], h, cfg.norm_eps)
        if ffn == "dense":
            h = h + L.mlp(p["ffn"], hn, megatron_sp=cfg.megatron_sp)
        else:
            out, _ = moe_lib.moe_block(p["ffn"], hn, cfg)
            h = h + out
        h = constrain(h, "residual")
    return h, pool


def decode_step_paged(params, inputs, pools, page_table, pos, active,
                      cfg: ModelConfig):
    """One token for every serving slot against the paged pools.

    inputs: (B,1) tokens; page_table: (B, max_pages) i32; pos: (B,)
    per-slot positions; active: (B,) bool (inactive slots write to the
    null page and their logits are garbage the scheduler ignores).
    Returns (logits (B,1,V), updated pools)."""
    _check_paged(cfg)
    prefix, period, n_groups = group_layout(cfg)
    h = _embed_inputs(params, inputs, cfg)
    new_pools: Dict[str, Any] = {}

    for i in range(prefix):
        h, pool = _paged_layer(
            params[f"prefix_{i}"], h, layer_sig(cfg, i), cfg,
            pools[f"prefix_{i}"]["mixer"], page_table, pos, active)
        new_pools[f"prefix_{i}"] = {"mixer": pool}

    if n_groups:
        sigs = [layer_sig(cfg, prefix + s_) for s_ in range(period)]

        def body(h, xs):
            pslots, cslots = xs
            out_c = {}
            for s_ in range(period):
                h, pool = _paged_layer(
                    pslots[f"slot_{s_}"], h, sigs[s_], cfg,
                    cslots[f"slot_{s_}"]["mixer"], page_table, pos,
                    active)
                out_c[f"slot_{s_}"] = {"mixer": pool}
            return h, out_c

        h, blocks_cache = jax.lax.scan(
            body, h, (params["blocks"], pools["blocks"]))
        new_pools["blocks"] = blocks_cache

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return L.lm_head(params["lm_head"], h), new_pools
