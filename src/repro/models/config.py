"""Model configuration covering all assigned architecture families:
dense GQA transformers, local:global interleave, MLA, MoE (uniform and
interleaved, with shared experts), Mamba-1, Mamba-2/SSD hybrids, and
embedding-input (audio/vlm backbone) variants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | audio | vlm

    # trunk dims
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # inputs: "tokens" (LM) or "embeddings" (stub modality frontend)
    input_mode: str = "tokens"

    # attention
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over layers
    local_window: int = 1024
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # MLA (DeepSeek-V2)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_period: int = 1            # MoE FFN every `period` layers ...
    moe_offset: int = 0            # ... at layer indices i % period == offset
    first_dense: int = 0           # first K layers use dense FFN regardless
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # SSM
    ssm_kind: Optional[str] = None  # None | mamba1 | mamba2
    d_state: int = 16
    expand: int = 2
    conv_kernel: int = 4
    dt_rank: int = 0               # mamba1; 0 -> ceil(d_model/16)
    ssd_head_dim: int = 64         # mamba2
    ssd_chunk: int = 128
    # hybrid: apply a weight-shared attention block every `period` layers
    hybrid_attn_period: int = 0

    # numerics / compute
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-6
    attn_chunk: int = 1024          # kv-chunk for the flash path
    attn_schedule: str = "dense"    # dense (bounding-box) | triangular (compact)
    # GridPlan lowering knob (repro.core.plan): "closed_form" |
    # "prefetch_lut" | "bounding" | "" (= derive from attn_schedule).
    # When set it wins over attn_schedule for the XLA flash path; call
    # sites that invoke the Pallas kernels directly read it as
    # grid_mode via the accessor below.
    grid_lowering: str = ""
    # decode attention path: "xla" (full masked decode_attention) or
    # "blockspace" (the Pallas flash kernel with the run-time seq_pos
    # block skip; shards continuous-batching slot groups over the
    # registered serving mesh)
    attn_decode_kernel: str = "xla"
    flash_threshold: int = 8192     # use flash custom-vjp above this seq len
    remat: bool = True
    logit_chunk: int = 0            # 0 = unchunked cross-entropy
    # force the Megatron TP/SP collective pattern (activation gathers,
    # never weight gathers) via explicit intermediate constraints
    megatron_sp: bool = False

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables padded to a multiple of 16 so the vocab
        dim shards evenly over the model axis (Megatron practice).
        Logical vocab_size is unchanged (labels/tokens < vocab_size)."""
        return -(-self.vocab_size // 16) * 16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def ssd_heads(self) -> int:
        return self.d_inner // self.ssd_head_dim

    @property
    def attn_schedule_resolved(self) -> str:
        """The XLA flash schedule, honoring grid_lowering when set."""
        if self.grid_lowering:
            from repro.core.plan import xla_schedule
            return xla_schedule(self.grid_lowering)
        return self.attn_schedule

    @property
    def grid_mode(self) -> str:
        """grid_mode for call sites that invoke repro.kernels.ops
        directly (the model stack itself routes through the XLA path
        via attn_schedule_resolved)."""
        return self.grid_lowering or "closed_form"

    def attn_kind(self, layer: int) -> str:
        return self.attn_pattern[layer % len(self.attn_pattern)]

    def layer_mixer(self, layer: int) -> str:
        if self.ssm_kind is not None:
            return self.ssm_kind
        return "mla" if self.use_mla else "attn"

    def layer_ffn(self, layer: int) -> str:
        if not self.moe or layer < self.first_dense:
            return "dense"
        return "moe" if layer % self.moe_period == self.moe_offset else "dense"

    def has_shared_attn(self, layer: int) -> bool:
        p = self.hybrid_attn_period
        return bool(p) and layer % p == p - 1

    def jdtype(self):
        return jnp.dtype(self.dtype)

    def jparam_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # number of parameters (analytic; used for MODEL_FLOPS roofline term)
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if self.input_mode != "embeddings":
            pass  # tied output head (we keep separate head below)
        total += v * d  # lm head
        for i in range(self.n_layers):
            total += 2 * d  # norms
            mixer = self.layer_mixer(i)
            if mixer == "attn":
                hq = self.n_heads * self.hd
                hkv = self.n_kv_heads * self.hd
                total += d * hq + 2 * d * hkv + hq * d
                if self.qkv_bias:
                    total += hq + 2 * hkv
            elif mixer == "mla":
                ql = self.q_lora_rank or d
                qdim = self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                total += (d * ql if self.q_lora_rank else 0) + ql * qdim
                total += d * (self.kv_lora_rank + self.qk_rope_dim)
                total += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim)
                total += self.n_heads * self.v_head_dim * d
            elif mixer == "mamba1":
                di, n, dtr = self.d_inner, self.d_state, self.dt_rank_
                total += d * 2 * di + di * self.conv_kernel
                total += di * (dtr + 2 * n) + dtr * di + di * n + 2 * di
                total += di * d
            elif mixer == "mamba2":
                di, n, nh = self.d_inner, self.d_state, self.ssd_heads
                total += d * (2 * di + 2 * n + nh)  # in_proj(x,z,B,C,dt)
                total += (di + 2 * n) * self.conv_kernel
                total += 2 * nh + di  # A, D, dt_bias... (approx)
                total += di * d
            ffn = self.layer_ffn(i)
            if self.family == "hybrid":
                ffn = "none"  # zamba-style: MLP lives in the shared block
            if ffn == "none":
                pass
            elif ffn == "dense":
                total += 3 * d * self.d_ff
            else:
                fe = self.d_ff_expert or self.d_ff
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * fe
                total += self.n_shared_experts * 3 * d * fe
        if self.hybrid_attn_period:
            hq = self.n_heads * self.hd
            hkv = self.n_kv_heads * self.hd
            total += 2 * d * d  # concat in-proj
            total += d * hq + 2 * d * hkv + hq * d + 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        dense_cfg = self.param_count()
        fe = self.d_ff_expert or self.d_ff
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.layer_ffn(i) == "moe")
        inactive = (self.n_experts - self.top_k) * 3 * self.d_model * fe
        return dense_cfg - n_moe_layers * inactive
