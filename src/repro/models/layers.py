"""Shared building blocks: norms, RoPE, SwiGLU MLP, GQA attention block,
embeddings, and initialization helpers.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every init
function takes an explicit PRNG key and returns (params, None); shapes
are kept in one place so the sharding rules in repro.distributed can be
name-pattern based.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from . import attention as attn_lib


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype) * scale)


def split(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}

def rmsnorm(params, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (rotate-half convention)
# ---------------------------------------------------------------------------

def rope(x, positions, theta=10000.0):
    """x: (B,H,S,D) with even D; positions: (S,) int."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d // 2, dtype=jnp.float32) / (d // 2))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[None, None]        # (1,1,S,D/2)
    sin = jnp.sin(angles)[None, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def rope_rows(x, positions, theta=10000.0):
    """Per-batch-row RoPE for single-token decode: x (B,H,1,D) with even
    D; positions (B,) int, one decode position per slot.  Equals
    :func:`rope` broadcast when every row sits at the same position
    (same elementwise ops, so bitwise equal)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d // 2, dtype=jnp.float32) / (d // 2))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[:, None, None, :]      # (B,1,1,D/2)
    sin = jnp.sin(angles)[:, None, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d, f, dtype):
    k1, k2, k3 = split(key, 3)
    return {"wi": dense_init(k1, d, f, dtype),
            "wg": dense_init(k2, d, f, dtype),
            "wo": dense_init(k3, f, d, dtype, scale=1.0 / np.sqrt(f))}

def mlp(params, x, megatron_sp=False):
    h = jax.nn.silu(x @ params["wg"].astype(x.dtype)) * (
        x @ params["wi"].astype(x.dtype))
    if megatron_sp:
        # pin the hidden to TP-sharded: XLA must gather activations
        # (small) instead of the F-sharded weights (big)
        h = constrain(h, "mlp_hidden")
    return h @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype=None):
    dtype = dtype or cfg.jparam_dtype()
    d, hd = cfg.d_model, cfg.hd
    hq, hkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    k1, k2, k3, k4 = split(key, 4)
    p = {"wq": dense_init(k1, d, hq, dtype),
         "wk": dense_init(k2, d, hkv, dtype),
         "wv": dense_init(k3, d, hkv, dtype),
         "wo": dense_init(k4, hq, d, dtype, scale=1.0 / np.sqrt(hq))}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq,), dtype)
        p["bk"] = jnp.zeros((hkv,), dtype)
        p["bv"] = jnp.zeros((hkv,), dtype)
    return p


def _qkv(params, x, cfg):
    b, s, _ = x.shape
    hd = cfg.hd
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.megatron_sp:
        q = constrain(q, "attn_heads")
        k = constrain(k, "attn_heads")
        v = constrain(v, "attn_heads")
    return q, k, v


def attn_block(params, x, cfg, kind, positions):
    """Self-attention over the full sequence (train / prefill, no cache)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = attn_lib.attention(
        q, k, v, kind=("local" if kind == "local" else "causal"),
        window=cfg.local_window, chunk=cfg.attn_chunk,
        schedule=cfg.attn_schedule_resolved, flash_threshold=cfg.flash_threshold)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype)


def attn_block_prefill(params, x, cfg, kind, positions):
    """Like attn_block but also returns the (k, v) cache."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = attn_lib.attention(
        q, k, v, kind=("local" if kind == "local" else "causal"),
        window=cfg.local_window, chunk=cfg.attn_chunk,
        schedule=cfg.attn_schedule_resolved, flash_threshold=cfg.flash_threshold)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype), (k, v)


def attn_block_decode(params, x, cfg, kind, cache, pos):
    """One-token step.  cache: (k, v) each (B,Hkv,Smax,hd); pos: ()."""
    b, s, _ = x.shape  # s == 1
    q, k_new, v_new = _qkv(params, x, cfg)
    posv = jnp.full((1,), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k_new = rope(k_new, posv, cfg.rope_theta)
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=2)
    decode = (attn_lib.decode_attention_flash
              if cfg.attn_decode_kernel == "blockspace"
              else attn_lib.decode_attention)
    o = decode(
        q, k_cache, v_cache, pos,
        kind=("local" if kind == "local" else "causal"),
        window=cfg.local_window)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype), (k_cache, v_cache)


def attn_block_decode_paged(params, x, cfg, kind, pool, page_table, pos,
                            active=None):
    """One-token step against a paged fused-KV pool (continuous
    batching: every slot at its own position).

    pool: (P, 2*Hkv, page_size, hd) head-interleaved pages
    (:mod:`repro.core.paged`); page_table: (B, max_pages) i32; pos: (B,)
    per-slot decode positions; active: optional (B,) bool -- inactive
    slots write their new KV to the null page and their outputs are
    garbage the scheduler must ignore.  Returns (out, updated pool)."""
    from repro.core import paged as paged_lib

    b, s, _ = x.shape  # s == 1
    q, k_new, v_new = _qkv(params, x, cfg)
    q = rope_rows(q, pos, cfg.rope_theta)
    k_new = rope_rows(k_new, pos, cfg.rope_theta)
    pool = paged_lib.append_token(pool, page_table, pos, k_new, v_new,
                                  active)
    decode = (attn_lib.decode_attention_paged
              if cfg.attn_decode_kernel == "blockspace"
              else attn_lib.decode_attention_paged_xla)
    o = decode(q, pool, page_table, pos,
               window=(cfg.local_window if kind == "local" else 0))
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype), pool


# ---------------------------------------------------------------------------
# embedding / lm head
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d, dtype):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.01}

def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]

def lm_head_init(key, d, vocab, dtype):
    return {"w": dense_init(key, d, vocab, dtype)}

def lm_head(params, x):
    return x @ params["w"].astype(x.dtype)
