"""Multi-head Latent Attention (DeepSeek-V2): low-rank compressed KV with
decoupled RoPE key, plus the absorbed-matmul decode path that attends
directly over the compressed cache (the reason MLA caches are ~512+64
floats per token instead of 2 * H * hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn_lib
from .layers import dense_init, rmsnorm, rope, split


def mla_init(key, cfg, dtype=None):
    dtype = dtype or cfg.jparam_dtype()
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = split(key, 6)
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, cfg.q_lora_rank, dtype)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(ks[1], cfg.q_lora_rank, h * (dn + dr), dtype)
    else:
        p["wq"] = dense_init(ks[0], d, h * (dn + dr), dtype)
    p["wkv_a"] = dense_init(ks[2], d, cfg.kv_lora_rank + dr, dtype)
    p["kv_norm"] = jnp.ones((cfg.kv_lora_rank,), dtype)
    p["wkv_b"] = dense_init(ks[3], cfg.kv_lora_rank, h * (dn + dv), dtype)
    p["wo"] = dense_init(ks[4], h * dv, d, dtype,
                         scale=1.0 / np.sqrt(h * dv))
    return p


def _queries(p, x, cfg, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = rmsnorm({"scale": p["q_norm"]},
                     x @ p["wq_a"].astype(x.dtype), cfg.norm_eps)
        q = cq @ p["wq_b"].astype(x.dtype)
    else:
        q = x @ p["wq"].astype(x.dtype)
    q = q.reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, x, cfg, positions):
    """Compressed kv latent + roped shared key.  c_kv: (B,S,L); k_rope
    (B,1,S,dr)."""
    kv_a = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    c_kv = rmsnorm({"scale": p["kv_norm"]}, c_kv, cfg.norm_eps)
    k_rope = rope(k_rope[:, None], positions, cfg.rope_theta)  # (B,1,S,dr)
    return c_kv, k_rope


def mla_block(p, x, cfg, positions, *, return_cache=False):
    """Train/prefill: materialize per-head K/V from the latent."""
    b, s, _ = x.shape
    h, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latents(p, x, cfg, positions)

    kv = c_kv @ p["wkv_b"].astype(x.dtype)
    kv = kv.reshape(b, s, h, dn + dv).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, h, s, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    o = attn_lib.attention(
        q, k, v, kind="causal", scale=1.0 / np.sqrt(dn + dr),
        chunk=cfg.attn_chunk, schedule=cfg.attn_schedule_resolved,
        flash_threshold=cfg.flash_threshold)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dv)
    out = o @ p["wo"].astype(x.dtype)
    if return_cache:
        return out, (c_kv, k_rope[:, 0])
    return out


def mla_decode(p, x, cfg, cache, pos):
    """Absorbed decode: scores = (q_nope W_uk) c_kv^T + q_rope k_rope^T.
    cache: (c_kv (B,Smax,L), k_rope (B,Smax,dr))."""
    b = x.shape[0]
    h, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    L = cfg.kv_lora_rank
    posv = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _queries(p, x, cfg, posv)       # (B,H,1,dn/dr)
    c_new, kr_new = _latents(p, x, cfg, posv)        # (B,1,L), (B,1,1,dr)

    c_cache, r_cache = cache
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        c_cache, c_new.astype(c_cache.dtype), pos, axis=1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(
        r_cache, kr_new[:, 0].astype(r_cache.dtype), pos, axis=1)

    wkv_b = p["wkv_b"].astype(x.dtype).reshape(L, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    # absorb W_uk into q:  (B,H,1,dn) x (L,H,dn) -> (B,H,1,L)
    q_abs = jnp.einsum("bhqd,lhd->bhql", q_nope, w_uk)
    s = jnp.einsum("bhql,bsl->bhqs", q_abs.astype(jnp.float32),
                   c_cache.astype(jnp.float32))
    s += jnp.einsum("bhqd,bsd->bhqs", q_rope.astype(jnp.float32),
                    r_cache.astype(jnp.float32))
    s *= 1.0 / np.sqrt(dn + dr)
    kpos = jnp.arange(c_cache.shape[1])[None, None, None, :]
    s = jnp.where(kpos <= pos, s, attn_lib.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsl->bhql", pr.astype(c_cache.dtype), c_cache)
    o = jnp.einsum("bhql,lhd->bhqd", ctx, w_uv)      # (B,H,1,dv)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dv)
    return o @ p["wo"].astype(x.dtype), (c_cache, r_cache)
