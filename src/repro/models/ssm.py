"""State-space blocks: Mamba-1 (falcon-mamba) selective scan and
Mamba-2 / SSD (zamba2), both in chunked forms that keep the TPU MXU busy
(SSD intra-chunk is pure matmul) and bound memory to O(B * chunk * d * N).

Each scan has a naive sequential reference (`*_scan_ref`) used by the
tests; decode steps carry (ssm_state, conv_state) caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, split


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b):
    """x: (B,S,C); w: (C,K); b: (C,).  Causal: output t sees x[t-K+1..t]."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # K shifted views contracted against the per-channel taps
    views = jnp.stack([xp[:, i:i + x.shape[1], :] for i in range(k)], -1)
    out = jnp.einsum("bsck,ck->bsc", views, w)
    return out + b[None, None, :]


def conv_step(conv_state, x_new, w, b):
    """Decode: conv_state (B, K-1, C), x_new (B, 1, C) -> (y, new_state)."""
    k = w.shape[1]
    window = jnp.concatenate([conv_state, x_new], axis=1)      # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window, w) + b[None, :]
    return y[:, None, :], window[:, -(k - 1):, :] if k > 1 else window[:, :0]


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------

def selective_scan_ref(x, dt, A, B, C):
    """Sequential oracle.  x,dt: (b,s,di); A: (di,n); B,C: (b,s,n).
    Returns y (b,s,di) in f32."""
    x, dt, B, C = (t.astype(jnp.float32) for t in (x, dt, B, C))
    A = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (b,di) (b,di) (b,n) (b,n)
        da = jnp.exp(dtt[..., None] * A[None])            # (b,di,n)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.sum(h * ct[:, None, :], -1)               # (b,di)
        return h, y

    b, s, di = x.shape
    h0 = jnp.zeros((b, di, A.shape[1]), jnp.float32)
    xs = (x.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2), C.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2)


def selective_scan(x, dt, A, B, C, *, chunk=128, h0=None, return_state=False):
    """Chunked selective scan: within-chunk associative scan, across-chunk
    lax.scan.  Shapes as in selective_scan_ref."""
    b, s, di = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError("seq len must be divisible by chunk")
    nc = s // chunk
    x, dt, B, C = (t.astype(jnp.float32) for t in (x, dt, B, C))
    A = A.astype(jnp.float32)

    # per-step decay a_t = exp(dt_t * A) and input b_t = dt_t * B_t * x_t
    xs = x.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)
    dts = dt.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)
    Bs = B.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    Cs = C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp                       # (b,L,di) ... (b,L,n)
        a = jnp.exp(dtc[..., None] * A[None, None])           # (b,L,di,n)
        u = (dtc * xc)[..., None] * bc[:, :, None, :]         # (b,L,di,n)

        def combine(e1, e2):
            a1, u1 = e1
            a2, u2 = e2
            return a1 * a2, a2 * u1 + u2

        acc_a, acc_u = jax.lax.associative_scan(combine, (a, u), axis=1)
        hs = acc_a * h[:, None] + acc_u                       # (b,L,di,n)
        y = jnp.sum(hs * cc[:, :, None, :], -1)               # (b,L,di)
        return hs[:, -1], y

    h = h0 if h0 is not None else jnp.zeros((b, di, n), jnp.float32)
    h, ys = jax.lax.scan(chunk_step, h, (xs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    return (y, h) if return_state else y


def mamba1_init(key, cfg, dtype=None):
    dtype = dtype or cfg.jparam_dtype()
    d, di, n, dtr, k = (cfg.d_model, cfg.d_inner, cfg.d_state,
                        cfg.dt_rank_, cfg.conv_kernel)
    ks = split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": jax.random.normal(ks[1], (di, k), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.full((di,), 0.01, jnp.float32))).astype(dtype),
        "A_log": jnp.log(a).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _mamba1_inner(p, x1, z, cfg):
    """Common post-conv computation. x1: (B,S,di) already conv+silu'd."""
    n, dtr = cfg.d_state, cfg.dt_rank_
    dbl = x1 @ p["x_proj"].astype(x1.dtype)
    dt, Bc, Cc = jnp.split(dbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(x1.dtype)
                         + p["dt_bias"].astype(x1.dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    return dt, A, Bc, Cc


def mamba1_block(p, x, cfg, *, return_cache=False):
    """x: (B,S,D) -> (B,S,D).  Train/prefill (no incoming state)."""
    b, s, _ = x.shape
    xz = x @ p["in_proj"].astype(x.dtype)
    x1, z = jnp.split(xz, 2, axis=-1)
    if return_cache:
        k = cfg.conv_kernel
        conv_cache = x1[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            x1, ((0, 0), (k - 1 - s, 0), (0, 0)))
    x1 = jax.nn.silu(causal_conv1d(x1, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    dt, A, Bc, Cc = _mamba1_inner(p, x1, z, cfg)
    y, h = selective_scan(x1, dt, A, Bc, Cc, chunk=cfg.ssd_chunk,
                          return_state=True)
    y = y + x1.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_cache:
        return out, (h, conv_cache.astype(x.dtype))
    return out


def mamba1_decode(p, x, cfg, cache):
    """x: (B,1,D); cache: (h (B,di,n) f32, conv (B,K-1,di))."""
    h, conv_cache = cache
    xz = x @ p["in_proj"].astype(x.dtype)
    x1, z = jnp.split(xz, 2, axis=-1)
    x1c, conv_cache = conv_step(conv_cache, x1, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype))
    x1c = jax.nn.silu(x1c)
    dt, A, Bc, Cc = _mamba1_inner(p, x1c, z, cfg)
    xt, dtt = x1c[:, 0].astype(jnp.float32), dt[:, 0].astype(jnp.float32)
    bt, ct = Bc[:, 0].astype(jnp.float32), Cc[:, 0].astype(jnp.float32)
    da = jnp.exp(dtt[..., None] * A[None])
    h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
    y = jnp.sum(h * ct[:, None, :], -1) + xt * p["D"].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype), (h, conv_cache)


# ---------------------------------------------------------------------------
# Mamba-2 / SSD
# ---------------------------------------------------------------------------

def ssd_scan_ref(x, dt, A, B, C):
    """Sequential oracle.  x: (b,s,nh,P); dt: (b,s,nh); A: (nh,);
    B,C: (b,s,n).  Returns y (b,s,nh,P) f32."""
    x, dt, B, C = (t.astype(jnp.float32) for t in (x, dt, B, C))
    A = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp      # (b,nh,P) (b,nh) (b,n) (b,n)
        da = jnp.exp(dtt * A[None])                      # (b,nh)
        upd = jnp.einsum("bn,bhp,bh->bhnp", bt, xt, dtt)
        h = da[..., None, None] * h + upd
        y = jnp.einsum("bhnp,bn->bhp", h, ct)
        return h, y

    b, s, nh, pdim = x.shape
    n = B.shape[-1]
    h0 = jnp.zeros((b, nh, n, pdim), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2), C.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3)


def ssd_scan(x, dt, A, B, C, *, chunk=128, h0=None, return_state=False):
    """Chunked SSD (Mamba-2): intra-chunk is an (L,L) masked-decay matmul
    (MXU-friendly), inter-chunk state is carried by lax.scan."""
    b, s, nh, pdim = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError("seq len must be divisible by chunk")
    nc = s // chunk
    x, dt, B, C = (t.astype(jnp.float32) for t in (x, dt, B, C))
    A = A.astype(jnp.float32)

    xs = x.reshape(b, nc, chunk, nh, pdim).transpose(1, 0, 2, 3, 4)
    dts = dt.reshape(b, nc, chunk, nh).transpose(1, 0, 2, 3)
    Bs = B.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    Cs = C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp
        da = dtc * A[None, None]                    # (b,L,nh)
        cum = jnp.cumsum(da, axis=1)                # (b,L,nh)
        # intra-chunk: scores_ij = (C_i . B_j) * exp(cum_i - cum_j) * dt_j
        cb = jnp.einsum("bin,bjn->bij", cc, bc)     # (b,L,L)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (b,i,j,nh)
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        w = cb[..., None] * decay * dtc[:, None, :, :]            # (b,i,j,nh)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xc)
        # inter-chunk: contribution of incoming state
        y_inter = jnp.einsum("bin,bhnp->bihp", cc, h) * \
            jnp.exp(cum)[..., None]
        # state update
        edge = jnp.exp(cum[:, -1:, :] - cum)        # (b,L,nh)
        upd = jnp.einsum("bjn,bjhp,bjh->bhnp", bc, xc, edge * dtc)
        h_new = h * jnp.exp(cum[:, -1])[..., None, None] + upd
        return h_new, y_intra + y_inter

    h = h0 if h0 is not None else jnp.zeros((b, nh, n, pdim), jnp.float32)
    h, ys = jax.lax.scan(chunk_step, h, (xs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, pdim)
    return (y, h) if return_state else y


def mamba2_init(key, cfg, dtype=None):
    dtype = dtype or cfg.jparam_dtype()
    d, di, n, nh, k = (cfg.d_model, cfg.d_inner, cfg.d_state,
                       cfg.ssd_heads, cfg.conv_kernel)
    ks = split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + nh, dtype),
        "conv_w": jax.random.normal(ks[1], (di + 2 * n, k), dtype) * 0.1,
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.full((nh,), 0.01, jnp.float32))).astype(dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _mamba2_split(p, x, cfg):
    di, n, nh = cfg.d_inner, cfg.d_state, cfg.ssd_heads
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    return jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)  # z, xBC, dt


def _gated_norm(p, y, z, eps):
    y = y * jax.nn.silu(z)
    return rmsnorm({"scale": p["norm_scale"]}, y, eps)


def mamba2_block(p, x, cfg, *, return_cache=False):
    b, s, _ = x.shape
    di, n, nh, pdim = cfg.d_inner, cfg.d_state, cfg.ssd_heads, cfg.ssd_head_dim
    z, xBC, dt = _mamba2_split(p, x, cfg)
    if return_cache:
        k = cfg.conv_kernel
        conv_cache = xBC[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            xBC, ((0, 0), (k - 1 - s, 0), (0, 0)))
    xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"].astype(x.dtype),
                                    p["conv_b"].astype(x.dtype)))
    x1, Bc, Cc = jnp.split(xBC, [di, di + n], axis=-1)
    xh = x1.reshape(b, s, nh, pdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = ssd_scan(xh, dt, A, Bc, Cc, chunk=cfg.ssd_chunk,
                    return_state=True)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_cache:
        return out, (h, conv_cache.astype(x.dtype))
    return out


def mamba2_decode(p, x, cfg, cache):
    b = x.shape[0]
    di, n, nh, pdim = cfg.d_inner, cfg.d_state, cfg.ssd_heads, cfg.ssd_head_dim
    h, conv_cache = cache
    z, xBC, dt = _mamba2_split(p, x, cfg)
    xBCc, conv_cache = conv_step(conv_cache, xBC,
                                 p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype))
    xBCc = jax.nn.silu(xBCc)
    x1, Bc, Cc = jnp.split(xBCc, [di, di + n], axis=-1)
    xt = x1[:, 0].reshape(b, nh, pdim).astype(jnp.float32)
    dtt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    bt, ct = Bc[:, 0].astype(jnp.float32), Cc[:, 0].astype(jnp.float32)
    da = jnp.exp(dtt * A[None])
    h = da[..., None, None] * h + jnp.einsum("bn,bhp,bh->bhnp", bt, xt, dtt)
    y = jnp.einsum("bhnp,bn->bhp", h, ct) + xt * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype), (h, conv_cache)
