"""The paper's SS IV microbenchmark as Pallas TPU kernels, lowered
through the unified :class:`~repro.core.plan.GridPlan` engine.

Three lowerings, extending the paper's A/B to the LUT variant of the
follow-up work:

* ``closed_form`` (alias ``compact``) -- the lambda(w) map: the grid has
  ``domain.num_blocks`` steps and ``BlockSpec.index_map`` computes
  lambda inline on the scalar core (the TPU-native realization of the
  paper's per-block map).
* ``prefetch_lut`` -- the same enumeration shipped as a host-built
  coordinate table via scalar prefetch: the decode becomes an O(1)
  table read instead of the O(r) digit unrolling.
* ``bounding`` -- the bounding-box baseline: n_b x n_b grid steps, with
  the run-time discard ``pl.when(block is member)``.

Intra-block threads use the paper's *bounding sub-boxes* option: a VPU
mask from ``broadcasted_iota`` evaluating the domain's cell-membership
test (the gasket's ``x & (n-1-y) == 0`` bit test, or the generalized
base-m digit test for carpet / Vicsek / any registered FractalSpec).

The written matrix is passed in and aliased to the output so that blocks
never visited by the compact grid keep their previous contents (the
embedded non-fractal region), matching the CUDA semantics of writing
in-place into global memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.domain import BlockDomain, make_fractal_domain
from repro.core.plan import GridPlan


def _cell_mask(domain: BlockDomain, bx, by, block: int, n: int):
    """VPU cell-membership mask for the (bx, by) tile (bounding
    sub-boxes intra-block option)."""
    iy = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    gx = bx * block + ix
    gy = by * block + iy
    return domain.cell_member(gx, gy, n)


def _write_kernel(coords, m_ref, o_ref, *, value, block, n, domain):
    def body():
        mask = _cell_mask(domain, coords.bx, coords.by, block, n)
        o_ref[...] = jnp.where(mask, jnp.asarray(value, o_ref.dtype),
                               m_ref[...])

    coords.when_valid(body)


@functools.partial(jax.jit,
                   static_argnames=("value", "block", "grid_mode",
                                    "fractal", "interpret"))
def sierpinski_write(m: jnp.ndarray, value: float = 1.0, *,
                     block: int = 128, grid_mode: str = "compact",
                     fractal: str = "sierpinski-gasket",
                     interpret: bool | None = None) -> jnp.ndarray:
    """Write ``value`` to every fractal cell of the embedded (n, n)
    matrix.  grid_mode: closed_form (alias compact) | prefetch_lut |
    bounding; fractal: any registered FractalSpec name."""
    n = m.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, n)
    n_b = n // block
    domain = make_fractal_domain(fractal, n_b)
    plan = GridPlan(domain, grid_mode)

    spec = plan.block_spec((block, block), lambda bx, by: (by, bx))
    call = plan.pallas_call(
        functools.partial(_write_kernel, value=value, block=block, n=n,
                          domain=domain),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(m.shape, m.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )
    return call(m)


def _sum_kernel(coords, m_ref, o_ref, *, block, n, domain):
    @pl.when(coords.first_step)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    def body():
        mask = _cell_mask(domain, coords.bx, coords.by, block, n)
        tile = jnp.where(mask, m_ref[...], 0).astype(jnp.float32)
        o_ref[0, 0] += jnp.sum(tile)

    coords.when_valid(body)


@functools.partial(jax.jit, static_argnames=("block", "grid_mode",
                                             "fractal", "interpret"))
def sierpinski_sum(m: jnp.ndarray, *, block: int = 128,
                   grid_mode: str = "compact",
                   fractal: str = "sierpinski-gasket",
                   interpret: bool | None = None) -> jnp.ndarray:
    """f32 sum over fractal cells, sequential accumulate over the plan's
    grid (any lowering; the output block is revisited every step)."""
    n = m.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, n)
    n_b = n // block
    domain = make_fractal_domain(fractal, n_b)
    plan = GridPlan(domain, grid_mode)

    call = plan.pallas_call(
        functools.partial(_sum_kernel, block=block, n=n, domain=domain),
        in_specs=[plan.block_spec((block, block),
                                  lambda bx, by: (by, bx))],
        out_specs=plan.block_spec((1, 1), lambda bx, by: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )
    return call(m)[0, 0]
