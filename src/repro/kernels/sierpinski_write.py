"""The paper's SS IV microbenchmark as Pallas TPU kernels.

Two grid modes, exactly mirroring the paper's A/B:

* ``compact``  -- the lambda(w) map: the grid has 3**r_b steps and
  ``BlockSpec.index_map`` computes lambda on the scalar core
  (the TPU-native realization of the paper's per-block map; the
  O(log log n) warp reduction is replaced by pipelined scalar math).
* ``bounding`` -- the bounding-box baseline: n_b x n_b grid steps, with
  the run-time discard ``pl.when(block is member)``.

Intra-block threads use the paper's *bounding sub-boxes* option: a VPU
mask from ``broadcasted_iota`` evaluating the membership bit test
``x & (n-1-y) == 0``.

The written matrix is passed in and aliased to the output so that blocks
never visited by the compact grid keep their previous contents (the
embedded non-fractal region), matching the CUDA semantics of writing
in-place into global memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fractal as F


def _member_mask(bx, by, block: int, n: int):
    """VPU membership mask for the (bx, by) tile (bounding sub-boxes)."""
    iy = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    gx = bx * block + ix
    gy = by * block + iy
    return (gx & (n - 1 - gy)) == 0


def _write_kernel_compact(m_ref, o_ref, *, value, block, n, r_b):
    i = pl.program_id(0)
    bx, by = F.lambda_map_linear(i, r_b)
    mask = _member_mask(bx, by, block, n)
    o_ref[...] = jnp.where(mask, jnp.asarray(value, o_ref.dtype), m_ref[...])


def _write_kernel_bounding(m_ref, o_ref, *, value, block, n, n_b):
    by = pl.program_id(0)
    bx = pl.program_id(1)
    # run-time discard: the whole block returns if outside the fractal
    @pl.when((bx & (n_b - 1 - by)) == 0)
    def _():
        mask = _member_mask(bx, by, block, n)
        o_ref[...] = jnp.where(mask, jnp.asarray(value, o_ref.dtype),
                               m_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("value", "block", "grid_mode",
                                    "interpret"))
def sierpinski_write(m: jnp.ndarray, value: float = 1.0, *,
                     block: int = 128, grid_mode: str = "compact",
                     interpret: bool | None = None) -> jnp.ndarray:
    """Write ``value`` to every gasket cell of the embedded (n, n) matrix."""
    n = m.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, n)
    n_b = n // block
    r_b = F.scale_level(n_b)

    if grid_mode == "compact":
        kernel = functools.partial(_write_kernel_compact, value=value,
                                   block=block, n=n, r_b=r_b)
        grid = (3 ** r_b,)

        def idx(i):
            lx, ly = F.lambda_map_linear(i, r_b)
            return (ly, lx)  # (row block, col block)
    elif grid_mode == "bounding":
        kernel = functools.partial(_write_kernel_bounding, value=value,
                                   block=block, n=n, n_b=n_b)
        grid = (n_b, n_b)

        def idx(i, j):
            return (i, j)
    else:
        raise ValueError(grid_mode)

    spec = pl.BlockSpec((block, block), idx)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(m.shape, m.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(m)


def _sum_kernel_compact(m_ref, o_ref, *, block, n, r_b):
    i = pl.program_id(0)
    bx, by = F.lambda_map_linear(i, r_b)
    mask = _member_mask(bx, by, block, n)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = jnp.where(mask, m_ref[...], 0).astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(tile)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sierpinski_sum(m: jnp.ndarray, *, block: int = 128,
                   interpret: bool | None = None) -> jnp.ndarray:
    """f32 sum over gasket cells, compact lambda grid, sequential accumulate."""
    n = m.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, n)
    n_b = n // block
    r_b = F.scale_level(n_b)

    def idx(i):
        lx, ly = F.lambda_map_linear(i, r_b)
        return (ly, lx)

    out = pl.pallas_call(
        functools.partial(_sum_kernel_compact, block=block, n=n, r_b=r_b),
        grid=(3 ** r_b,),
        in_specs=[pl.BlockSpec((block, block), idx)],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(m)
    return out[0, 0]
