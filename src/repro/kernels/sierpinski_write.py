"""The paper's SS IV microbenchmark as Pallas kernels, lowered through
the unified :class:`~repro.core.plan.GridPlan` engine on any
:mod:`~repro.core.backend` target (TPU Mosaic, GPU Triton, or either
under the interpreter).

Three lowerings, extending the paper's A/B to the LUT variant of the
follow-up work:

* ``closed_form`` (alias ``compact``) -- the lambda(w) map: the grid has
  ``domain.num_blocks`` steps and ``BlockSpec.index_map`` computes
  lambda inline on the scalar core (the TPU-native realization of the
  paper's per-block map).
* ``prefetch_lut`` -- the same enumeration shipped as a host-built
  coordinate table via scalar prefetch: the decode becomes an O(1)
  table read instead of the O(r) digit unrolling.
* ``bounding`` -- the bounding-box baseline: n_b x n_b grid steps, with
  the run-time discard ``pl.when(block is member)``.
* ``auto`` -- resolve the lowering (and coarsening, when left at
  ``"auto"``) from the :mod:`~repro.core.tune` cache for this problem
  and backend; falls back to ``closed_form`` when never tuned.

Two storages (the ``storage=`` axis of GridPlan):

* ``embedded`` -- the state array is the dense n x n bounding-box
  layout (O(n^2) memory); blocks never visited by a compact grid keep
  their previous contents via input/output aliasing.
* ``compact`` -- the state array lives in the packed orthotope layout
  of Lemma 2 (O(n^H) memory, ``CompactLayout``); the same kernels run
  with their storage-operand index maps rewritten to packed slots.

Superblock coarsening (the ``coarsen=`` axis): each grid step owns an
s x s tile of fine blocks -- lambda decoded once per superblock, the
per-cell embedded offsets baked into the (static) supertile offset
grids -- amortizing the decode by the tile's member count.

Intra-block threads use the paper's *bounding sub-boxes* option: a VPU
mask from ``broadcasted_iota`` (or, under packed coarsening, the static
offset grids) evaluating the domain's cell-membership test (the
gasket's ``x & (n-1-y) == 0`` bit test, or the generalized base-m digit
test for carpet / Vicsek / any registered FractalSpec).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import backend as backend_lib
from repro.core.backend import full_spec
from repro.core.domain import BlockDomain, make_fractal_domain
from repro.core.plan import GridPlan, normalize_storage


def resolve_fractal_domain(fractal: str, n: int, block: int) -> BlockDomain:
    """Validated block-grid domain for an embedded n x n fractal state.

    Raises a clear ValueError when ``block`` does not divide ``n`` (a
    truncated block grid would silently drop fractal coverage: e.g. a
    16 x 16 gasket at block=6 only reaches 45 of its 81 member cells) or
    when the resulting blocks-per-side is not a power of the fractal's
    subdivision factor.
    """
    if n % block:
        raise ValueError(
            f"block={block} must divide n={n} (remainder {n % block}): "
            f"the {n // block}-block grid would silently truncate "
            f"fractal coverage")
    n_b = n // block
    try:
        return make_fractal_domain(fractal, n_b)
    except ValueError as e:
        raise ValueError(
            f"n/block = {n_b} blocks per side is not a valid scale level "
            f"of fractal {fractal!r}: {e}") from None


def resolve_storage_args(m, block, fractal, storage, n, domain):
    """Shared entry-point validation for the fractal-state kernels.

    Returns (domain, n, block, storage) with the state array ``m``
    checked against the storage layout's expected shape.  ``n`` (the
    embedded side length) must be passed explicitly under compact
    storage when no ``domain`` is given, since the packed array's shape
    no longer determines it.
    """
    storage = normalize_storage(storage)
    if domain is None:
        if n is None:
            if storage == "compact":
                raise ValueError(
                    "storage='compact' needs the embedded size n= (or an "
                    "explicit domain=): the packed array shape does not "
                    "determine it")
            if m.ndim != 2 or m.shape[0] != m.shape[1]:
                raise ValueError(f"expected square 2-D state, got {m.shape}")
            n = m.shape[0]
        block = min(block, n)
        domain = resolve_fractal_domain(fractal, n, block)
    else:
        nbx, nby = domain.bounding_box
        if n is None:
            n = nby * block
    plan = GridPlan(domain, storage=storage)
    want = plan.layout.array_shape(block) if storage == "compact" \
        else plan.layout.embedded_shape(block)
    if tuple(m.shape) != want:
        raise ValueError(
            f"{storage} state shape {tuple(m.shape)} does not match the "
            f"expected {want} for block={block}")
    return domain, n, block, storage


def resolve_auto_schedule(kernel: str, params: dict, **knobs):
    """Resolve ``"auto"`` scheduling knobs from the tune cache.

    ``knobs`` maps knob name -> (current value, config key, default);
    returns the knob values with every ``"auto"`` replaced by the tuned
    value (or the default when this problem was never tuned).  Values
    the caller fixed explicitly are passed through untouched, so a
    tuned lowering never overrides an explicit ``coarsen=``.
    """
    if not any(v == "auto" for v, _, _ in knobs.values()):
        return tuple(v for v, _, _ in knobs.values())
    from repro.core import tune
    cfg = tune.best(kernel, params) or {}
    return tuple(cfg.get(key, default) if value == "auto" else value
                 for value, key, default in knobs.values())


def _cell_mask(domain: BlockDomain, bx, by, block: int, n: int):
    """VPU cell-membership mask for the (bx, by) fine tile (bounding
    sub-boxes intra-block option); (bx, by) are embedded block coords
    under either storage."""
    iy = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    gx = bx * block + ix
    gy = by * block + iy
    return domain.cell_member(gx, gy, n)


def _tile_mask(plan: GridPlan, bx, by, block: int, n: int):
    """Cell-membership mask over one storage supertile of the plan.

    (bx, by) are the *scheduled* (coarse) block coords.  For the
    trivial layouts this is exactly :func:`_cell_mask`; under packed
    coarsening the static offset grids bake the fine-block permutation
    in, so the mask is evaluated directly in packed arrangement."""
    span = plan.coarsen * block
    tm = plan.tile_map()
    th, tw = plan.supertile_shape((block, block))
    if tm is None:
        oy = jax.lax.broadcasted_iota(jnp.int32, (th, tw), 0)
        ox = jax.lax.broadcasted_iota(jnp.int32, (th, tw), 1)
        return plan.domain.cell_member(bx * span + ox, by * span + oy, n)
    # packed coarsening: evaluate per fine sub-block (static loop over
    # the tile permutation -- Pallas kernels cannot capture host array
    # constants, so the offsets enter as scalar adds on iota)
    iy = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    mask = jnp.zeros((th, tw), jnp.bool_)
    for (py, px), (ey, ex) in tm:
        sub = plan.domain.cell_member(bx * span + ex * block + ix,
                                      by * span + ey * block + iy, n)
        mask = jax.lax.dynamic_update_slice(mask, sub,
                                            (py * block, px * block))
    return mask


def _write_kernel(coords, m_ref, o_ref, *, value, block, n, plan):
    def body():
        mask = _tile_mask(plan, coords.bx, coords.by, block, n)
        o_ref[...] = jnp.where(mask, jnp.asarray(value, o_ref.dtype),
                               m_ref[...])

    coords.when_valid(body)


def _stream_storage_tile(coords, m_ref, bufs_ref, sems, plan, stages):
    """This grid step's storage supertile, streamed out of the
    ``pltpu.ANY``-resident state through the rotating async-copy
    buffers (the copy for step t+stages-1 starts before this step's
    compute; see :func:`repro.core.backend.stream_tiles`)."""
    lin = plan.linear_step(coords.grid_ids)

    def srcs_for(step):
        return [plan.storage_index(plan.grid_ids_at(step), coords.refs)]

    return backend_lib.stream_tiles(
        m_ref, bufs_ref, sems, srcs_for=srcs_for, lin=lin,
        total=plan.steps_per_launch, stages=stages)[0]


def _write_kernel_dma(coords, m_ref, alias_ref, o_ref, bufs_ref, sems,
                      *, value, block, n, plan, stages):
    """Async-copy pipelined write (TPU structure, ``num_stages`` >= 2):
    the state is parked in ``pltpu.ANY`` and each step's input tile
    streams through rotating VMEM DMA buffers while the next step's
    copy is in flight.  ``alias_ref`` is the same state routed as a
    BlockSpec operand purely to alias the unwritten remainder to the
    output; the kernel never reads it."""
    del alias_ref
    tile = _stream_storage_tile(coords, m_ref, bufs_ref, sems, plan,
                                stages)

    def body():
        mask = _tile_mask(plan, coords.bx, coords.by, block, n)
        o_ref[...] = jnp.where(mask, jnp.asarray(value, o_ref.dtype),
                               tile.astype(o_ref.dtype))

    coords.when_valid(body)


def _write_kernel_gpu(coords, m_ref, o_ref, *, value, block, n, plan):
    """gpu-structured write: the state arrives whole; the kernel
    resolves its supertile offset itself (the plan's storage index,
    reading the HBM LUT operand under ``prefetch_lut``) and
    loads/stores with computed offsets."""
    th, tw = plan.supertile_shape((block, block))

    def body():
        iy, ix = plan.storage_index(coords.grid_ids, coords.refs)
        idx = (pl.ds(iy * th, th), pl.ds(ix * tw, tw))
        tile = pl.load(m_ref, idx)
        mask = _tile_mask(plan, coords.bx, coords.by, block, n)
        pl.store(o_ref, idx,
                 jnp.where(mask, jnp.asarray(value, o_ref.dtype), tile))

    coords.when_valid(body)


def _emit_write(plan: GridPlan, shape, dtype, *, value, block, n,
                stages=1):
    """The write pallas_call for either emission structure: BlockSpec
    tiles on block-indexed (TPU) targets, whole-array refs + in-kernel
    addressing on GPU targets.  The unwritten remainder keeps the input
    through the output alias either way.  ``stages >= 2`` on an
    async-copy target streams the input tiles through rotating DMA
    buffers instead (:func:`_write_kernel_dma`); on the GPU structure
    it only feeds the Triton scheduler."""
    target = plan.target
    stages = target.resolve_stages(stages)
    if target.block_indexed and stages > 1:
        spec = plan.storage_spec((block, block))
        th, tw = plan.supertile_shape((block, block))
        call = plan.pallas_call(
            functools.partial(_write_kernel_dma, value=value,
                              block=block, n=n, plan=plan,
                              stages=stages),
            in_specs=[target.any_spec(), spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct(shape, dtype),
            scratch_shapes=[
                target.scratch((stages, 1, th, tw), dtype),
                target.dma_sems((stages, 1)),
            ],
            input_output_aliases={1: 0},
        )
        # the state rides twice: ANY (DMA source) + BlockSpec (alias)
        return lambda *args: call(*args[:-1], args[-1], args[-1])
    if target.block_indexed:
        spec = plan.storage_spec((block, block))
        return plan.pallas_call(
            functools.partial(_write_kernel, value=value, block=block,
                              n=n, plan=plan),
            in_specs=[spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct(shape, dtype),
            input_output_aliases={0: 0},
        )
    return plan.pallas_call(
        functools.partial(_write_kernel_gpu, value=value, block=block,
                          n=n, plan=plan),
        in_specs=[full_spec(shape)],
        out_specs=full_spec(shape),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        input_output_aliases={0: 0},
        num_stages=stages if stages > 1 else None,
    )


@functools.partial(jax.jit,
                   static_argnames=("value", "block", "grid_mode",
                                    "fractal", "storage", "n", "domain",
                                    "coarsen", "backend", "stages",
                                    "verify"))
def _write_impl(m, value, *, block, grid_mode, fractal, storage, n,
                domain, coarsen, backend, stages=1, verify=False):
    domain, n, block, storage = resolve_storage_args(
        m, block, fractal, storage, n, domain)
    plan = GridPlan(domain, grid_mode, storage=storage, coarsen=coarsen,
                    backend=backend)
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(plan, kernel="write")
    call = _emit_write(plan, m.shape, m.dtype, value=value, block=block,
                       n=n, stages=stages)
    return call(m)


def _sharded_setup(m, *, block, grid_mode, fractal, storage, n, domain,
                   coarsen, mesh, shard_axis, backend):
    """Shared ShardedPlan + per-device-table construction for the
    sharded write/sum drivers."""
    from repro.core.shard import ShardedPlan, device_tables

    domain, n, block, storage = resolve_storage_args(
        m, block, fractal, storage, n, domain)
    plan = ShardedPlan(domain, grid_mode, storage=storage,
                       coarsen=coarsen, backend=backend, mesh=mesh,
                       axis=shard_axis)
    tbl, luts = device_tables(plan)
    return plan, domain, n, block, storage, tbl, luts


@functools.partial(jax.jit,
                   static_argnames=("value", "block", "grid_mode",
                                    "fractal", "storage", "n", "domain",
                                    "coarsen", "backend", "mesh",
                                    "shard_axis", "stages", "verify"))
def _write_sharded_impl(m, value, *, block, grid_mode, fractal, storage,
                        n, domain, coarsen, backend, mesh, shard_axis,
                        stages=1, verify=False):
    """Sharded write: each device writes its share of the domain.
    Compact storage writes its orthotope row slab in place; embedded
    storage combines the replicated per-device results with a disjoint
    ownership-mask psum (member blocks have exactly one owner, the rest
    pass the input through)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    plan, domain, n, block, storage, tbl, luts = _sharded_setup(
        m, block=block, grid_mode=grid_mode, fractal=fractal,
        storage=storage, n=n, domain=domain, coarsen=coarsen, mesh=mesh,
        shard_axis=shard_axis, backend=backend)
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(plan, kernel="write")
    call = _emit_write(plan, plan.local_storage_shape(block), m.dtype,
                       value=value, block=block, n=n, stages=stages)
    axis = shard_axis
    lut_specs = tuple(P(axis, None) for _ in luts)
    if storage == "compact":
        a = plan.pad_rows(m, block)
        out = shard_map(
            lambda tbl, luts, a: call(tbl.reshape(-1), *luts, a),
            mesh=mesh,
            in_specs=(P(axis, None), lut_specs, P(axis, None)),
            out_specs=P(axis, None), check_rep=False)(tbl, luts, a)
        return plan.unpad_rows(out, block)

    def device_fn(tbl, luts, a):
        tbl1 = tbl.reshape(-1)
        part = call(tbl1, *luts, a)
        owned = plan.owned_cell_mask(tbl1, n, block)
        member = plan.member_cell_block_mask(n, block)
        return jax.lax.psum(jnp.where(owned, part, 0), axis) \
            + jnp.where(member, 0, a).astype(part.dtype)

    return shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis, None), lut_specs, P(None, None)),
        out_specs=P(None, None), check_rep=False)(tbl, luts, m)


def sierpinski_write(m: jnp.ndarray, value: float = 1.0, *,
                     block: int = 128, grid_mode: str = "compact",
                     fractal: str = "sierpinski-gasket",
                     storage: str = "embedded", n: int | None = None,
                     domain: BlockDomain | None = None,
                     coarsen: int | str = 1,
                     num_stages: int | str = "auto", backend=None,
                     interpret: bool | None = None, mesh=None,
                     shard_axis: str = "data",
                     verify: bool = False) -> jnp.ndarray:
    """Write ``value`` to every fractal cell of the (n, n) state.

    grid_mode: closed_form (alias compact) | prefetch_lut | bounding |
    mma (digit-basis matmul decode, :mod:`repro.core.mma`) |
    auto (tune-cache lookup); fractal: any registered FractalSpec name;
    storage: embedded (m is the dense n x n array) | compact (m is the
    packed orthotope array, pass n= or domain=); coarsen: superblock
    side in fine blocks (or "auto"); backend: emission target
    ("tpu" | "gpu" | "*-interpret" | None = platform default, see
    :mod:`repro.core.backend`); num_stages: software-pipeline depth
    (">= 2" streams input tiles through async-copy DMA buffers on
    capable targets, "auto" = tuned; bit-identical either way);
    mesh/shard_axis: shard the write across
    a mesh axis (embarrassing: disjoint block ownership, psum combine
    under embedded storage); verify: statically verify the emitted plan
    (coverage / races / tables / bounds, :mod:`repro.analysis`) at
    trace time, raising on any violation -- a debug flag, off by
    default."""
    target = backend_lib.resolve(backend, interpret)
    from repro.core import tune
    grid_mode, coarsen, num_stages = resolve_auto_schedule(
        "write",
        tune.target_params(
            tune.shard_params(
                {"fractal": fractal, "n": n or m.shape[0],
                 "block": block},
                mesh, shard_axis),
            target),
        grid_mode=(grid_mode, "lowering", "closed_form"),
        coarsen=(coarsen, "coarsen", 1),
        num_stages=(num_stages, "stages", 1))
    kw = dict(block=block, grid_mode=grid_mode, fractal=fractal,
              storage=storage, n=n, domain=domain, coarsen=coarsen,
              backend=target, stages=target.resolve_stages(num_stages),
              verify=verify)
    if mesh is not None:
        return _write_sharded_impl(m, value, mesh=mesh,
                                   shard_axis=shard_axis, **kw)
    return _write_impl(m, value, **kw)


def _sum_kernel(coords, m_ref, o_ref, *, block, n, plan):
    @pl.when(coords.first_step)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    def body():
        mask = _tile_mask(plan, coords.bx, coords.by, block, n)
        tile = jnp.where(mask, m_ref[...], 0).astype(jnp.float32)
        o_ref[0, 0] += jnp.sum(tile)

    coords.when_valid(body)


def _sum_kernel_dma(coords, m_ref, o_ref, bufs_ref, sems, *, block, n,
                    plan, stages):
    """Async-copy pipelined sum: the sequential accumulate of
    :func:`_sum_kernel` with the input tile streamed through rotating
    DMA buffers, so the next tile's copy flies during this tile's
    reduction.  Same grid, same accumulation order: bit-identical."""
    tile = _stream_storage_tile(coords, m_ref, bufs_ref, sems, plan,
                                stages)

    @pl.when(coords.first_step)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    def body():
        mask = _tile_mask(plan, coords.bx, coords.by, block, n)
        o_ref[0, 0] += jnp.sum(
            jnp.where(mask, tile, 0).astype(jnp.float32))

    coords.when_valid(body)


def _sum_kernel_gpu(coords, m_ref, o_ref, *, block, n, plan):
    """gpu-structured sum: a parallel grid cannot revisit one
    accumulator, so each step stores its per-tile partial at its step
    slot; the driver reduces the slots *in step order*, reproducing the
    sequential grid's accumulation bit-for-bit."""
    th, tw = plan.supertile_shape((block, block))
    t = plan.linear_step(coords.grid_ids)
    out_idx = (pl.ds(t, 1), pl.ds(0, 1))
    pl.store(o_ref, out_idx, jnp.zeros((1, 1), jnp.float32))

    def body():
        iy, ix = plan.storage_index(coords.grid_ids, coords.refs)
        tile = pl.load(m_ref, (pl.ds(iy * th, th), pl.ds(ix * tw, tw)))
        mask = _tile_mask(plan, coords.bx, coords.by, block, n)
        part = jnp.sum(jnp.where(mask, tile, 0).astype(jnp.float32))
        pl.store(o_ref, out_idx, part.reshape(1, 1))

    coords.when_valid(body)


def _emit_sum(plan: GridPlan, shape, *, block, n, stages=1,
              dtype=jnp.float32):
    """The sum pallas_call for either structure.  Returns
    ``(call, finish)`` where ``finish`` maps the raw kernel output to
    the (1, 1) f32 total: identity on sequential-grid targets (the
    kernel accumulated in place), an in-step-order partials reduction
    on parallel-grid targets.  ``stages >= 2`` streams the input tiles
    through async-copy DMA buffers on capable targets."""
    target = plan.target
    stages = target.resolve_stages(stages)
    if target.sequential_grid and stages > 1 and target.async_copy:
        th, tw = plan.supertile_shape((block, block))
        call = plan.pallas_call(
            functools.partial(_sum_kernel_dma, block=block, n=n,
                              plan=plan, stages=stages),
            in_specs=[target.any_spec()],
            out_specs=plan.block_spec((1, 1), lambda bx, by: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
            scratch_shapes=[
                target.scratch((stages, 1, th, tw), dtype),
                target.dma_sems((stages, 1)),
            ],
        )
        return call, lambda out: out
    if target.sequential_grid:
        call = plan.pallas_call(
            functools.partial(_sum_kernel, block=block, n=n, plan=plan),
            in_specs=[plan.storage_spec((block, block))],
            out_specs=plan.block_spec((1, 1), lambda bx, by: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        )
        return call, lambda out: out
    steps = plan.steps_per_launch
    call = plan.pallas_call(
        functools.partial(_sum_kernel_gpu, block=block, n=n, plan=plan),
        in_specs=[full_spec(shape)],
        out_specs=full_spec((steps, 1)),
        out_shape=jax.ShapeDtypeStruct((steps, 1), jnp.float32),
        num_stages=stages if stages > 1 else None,
    )

    def finish(partials):
        total = jax.lax.fori_loop(
            0, steps, lambda i, acc: acc + partials[i, 0],
            jnp.float32(0))
        return total.reshape(1, 1)
    return call, finish


@functools.partial(jax.jit, static_argnames=("block", "grid_mode",
                                             "fractal", "storage", "n",
                                             "domain", "coarsen",
                                             "backend", "stages",
                                             "verify"))
def _sum_impl(m, *, block, grid_mode, fractal, storage, n, domain,
              coarsen, backend, stages=1, verify=False):
    domain, n, block, storage = resolve_storage_args(
        m, block, fractal, storage, n, domain)
    plan = GridPlan(domain, grid_mode, storage=storage, coarsen=coarsen,
                    backend=backend)
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(plan, kernel="sum")
    call, finish = _emit_sum(plan, m.shape, block=block, n=n,
                             stages=stages, dtype=m.dtype)
    return finish(call(m))[0, 0]


@functools.partial(jax.jit, static_argnames=("block", "grid_mode",
                                             "fractal", "storage", "n",
                                             "domain", "coarsen",
                                             "backend", "mesh",
                                             "shard_axis", "stages",
                                             "verify"))
def _sum_sharded_impl(m, *, block, grid_mode, fractal, storage, n,
                      domain, coarsen, backend, mesh, shard_axis,
                      stages=1, verify=False):
    """Sharded sum: each device accumulates its owned blocks, one psum
    reduces across the axis.  The per-device accumulation order differs
    from the single-device grid order, so results agree to float
    tolerance (exactly, for integer-valued states)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    plan, domain, n, block, storage, tbl, luts = _sharded_setup(
        m, block=block, grid_mode=grid_mode, fractal=fractal,
        storage=storage, n=n, domain=domain, coarsen=coarsen, mesh=mesh,
        shard_axis=shard_axis, backend=backend)
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(plan, kernel="sum")
    local_shape = plan.local_storage_shape(block)
    call, finish = _emit_sum(plan, local_shape, block=block, n=n,
                             stages=stages, dtype=m.dtype)
    axis = shard_axis
    lut_specs = tuple(P(axis, None) for _ in luts)
    state_spec = P(axis, None) if storage == "compact" else P(None, None)
    a = plan.pad_rows(m, block) if storage == "compact" else m

    def device_fn(tbl, luts, a):
        part = finish(call(tbl.reshape(-1), *luts, a))
        return jax.lax.psum(part, axis)

    out = shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis, None), lut_specs, state_spec),
        out_specs=P(None, None), check_rep=False)(tbl, luts, a)
    return out[0, 0]


def sierpinski_sum(m: jnp.ndarray, *, block: int = 128,
                   grid_mode: str = "compact",
                   fractal: str = "sierpinski-gasket",
                   storage: str = "embedded", n: int | None = None,
                   domain: BlockDomain | None = None,
                   coarsen: int | str = 1,
                   num_stages: int | str = "auto", backend=None,
                   interpret: bool | None = None, mesh=None,
                   shard_axis: str = "data",
                   verify: bool = False) -> jnp.ndarray:
    """f32 sum over fractal cells, sequential accumulate over the plan's
    grid (any lowering; the output block is revisited every step).  The
    grid enumeration -- and therefore the accumulation order -- depends
    only on (domain, grid_mode), so compact and embedded storage are
    bit-identical per lowering.  ``coarsen`` changes the per-step
    reduction tile, so coarsened sums agree to float tolerance, not
    bit-exactly."""
    target = backend_lib.resolve(backend, interpret)
    from repro.core import tune
    grid_mode, coarsen, num_stages = resolve_auto_schedule(
        "write",
        tune.target_params(
            tune.shard_params(
                {"fractal": fractal, "n": n or m.shape[0],
                 "block": block},
                mesh, shard_axis),
            target),
        grid_mode=(grid_mode, "lowering", "closed_form"),
        coarsen=(coarsen, "coarsen", 1),
        num_stages=(num_stages, "stages", 1))
    kw = dict(block=block, grid_mode=grid_mode, fractal=fractal,
              storage=storage, n=n, domain=domain, coarsen=coarsen,
              backend=target, stages=target.resolve_stages(num_stages),
              verify=verify)
    if mesh is not None:
        return _sum_sharded_impl(m, mesh=mesh, shard_axis=shard_axis,
                                 **kw)
    return _sum_impl(m, **kw)
