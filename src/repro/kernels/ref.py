"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret
mode on CPU, real Mosaic lowering on TPU).  They are deliberately naive:
full materialization, no tiling, no online softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F


# ---------------------------------------------------------------------------
# Paper SS IV microbenchmark: write a constant to every fractal cell
# ---------------------------------------------------------------------------

def sierpinski_write_ref(m: jnp.ndarray, value) -> jnp.ndarray:
    """Write ``value`` at every gasket cell of the embedded n x n matrix."""
    n = m.shape[0]
    mask = jnp.asarray(F.membership_grid(n))
    return jnp.where(mask, jnp.asarray(value, m.dtype), m)


def sierpinski_sum_ref(m: jnp.ndarray) -> jnp.ndarray:
    """f32 sum over the gasket cells of the embedded matrix."""
    n = m.shape[0]
    mask = jnp.asarray(F.membership_grid(n))
    return jnp.sum(jnp.where(mask, m, 0).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Cellular automaton / diffusion on the embedded gasket
# ---------------------------------------------------------------------------

def _neighbor_shift(a: jnp.ndarray, dy: int, dx: int) -> jnp.ndarray:
    """Value of the (dy, dx)-neighbor at each cell, 0 outside the matrix."""
    n = a.shape[0]
    out = jnp.roll(a, shift=(dy, dx), axis=(0, 1))
    if dy == 1:
        out = out.at[0, :].set(0)
    if dy == -1:
        out = out.at[n - 1, :].set(0)
    if dx == 1:
        out = out.at[:, 0].set(0)
    if dx == -1:
        out = out.at[:, n - 1].set(0)
    return out


def ca_step_ref(state: jnp.ndarray, rule: str = "parity",
                alpha: float = 0.25) -> jnp.ndarray:
    """One CA / diffusion step restricted to gasket cells.

    parity:    s' = (s + N + S + W + E) mod 2           (Wolfram-style)
    diffusion: s' = s + alpha * sum_{nbr in gasket}(nbr - s)   (graph heat eq)
    Non-member cells stay 0 in both rules.
    """
    n = state.shape[0]
    member = jnp.asarray(F.membership_grid(n))
    nb = [_neighbor_shift(state, dy, dx)
          for dy, dx in ((1, 0), (-1, 0), (0, 1), (0, -1))]
    nsum = nb[0] + nb[1] + nb[2] + nb[3]
    if rule == "parity":
        new = jnp.mod(state + nsum, 2)
    elif rule == "diffusion":
        nbm = [_neighbor_shift(member.astype(state.dtype), dy, dx)
               for dy, dx in ((1, 0), (-1, 0), (0, 1), (0, -1))]
        deg = nbm[0] + nbm[1] + nbm[2] + nbm[3]
        new = state + alpha * (nsum - deg * state)
    else:
        raise ValueError(rule)
    return jnp.where(member, new, 0).astype(state.dtype)


# ---------------------------------------------------------------------------
# Attention (causal / local / full), GQA-aware
# ---------------------------------------------------------------------------

def attention_mask(kind: str, sq: int, sk: int, window: int = 0):
    """(sq, sk) boolean mask. ``window`` is in tokens for kind="local".

    For causal/local with sq != sk the queries are assumed to be the
    *last* sq positions of the sk-long key sequence (decode convention).
    """
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    if kind == "full":
        return jnp.ones((sq, sk), bool)
    if kind == "causal":
        return kpos <= qpos
    if kind == "local":
        return (kpos <= qpos) & (kpos > qpos - window)
    raise ValueError(kind)


def attention_ref(q, k, v, kind: str = "causal", window: int = 0,
                  scale: float | None = None) -> jnp.ndarray:
    """Naive softmax attention. q: (B,H,Sq,D); k,v: (B,Hkv,Sk,D), Hkv | H."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    sk = k.shape[2]
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    group = h // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    mask = attention_mask(kind, sq, sk, window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> 0
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)
