"""Public jit'd entry points for the Pallas kernels.

``interpret=None`` (default) auto-selects: interpret on CPU (validation),
compiled Mosaic on TPU.  All wrappers are thin -- the kernels themselves
live in their own modules with their oracles in ``ref.py``.
"""
from .flash_attention import flash_attention
from .sierpinski_ca import ca_run, ca_step, launch_schedule
from .sierpinski_write import sierpinski_sum, sierpinski_write

__all__ = ["flash_attention", "ca_run", "ca_step", "launch_schedule",
           "sierpinski_sum", "sierpinski_write"]
