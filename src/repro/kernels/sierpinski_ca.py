"""Cellular-automaton / diffusion step on an embedded fractal, as a
block-space Pallas kernel (the application class the paper motivates:
nearest-neighbour data-parallel simulation over the fractal).

Halo exchange: the kernel receives five views of the state array (center
+ N/S/W/E neighbour tiles) via five BlockSpecs emitted by the plan.
Under ``storage="embedded"`` the neighbour index_maps are the decoded
block coordinate shifted by +-1 (clamped); under ``storage="compact"``
the state lives in the packed orthotope layout and each neighbour
index_map resolves the *embedded* neighbour's packed slot through
lambda^-1 (inline for closed_form / bounding, or as an O(1) read of the
host-built neighbour-slot table shipped through the scalar-prefetch LUT).
Out-of-range and non-member neighbour tiles are masked in-kernel.

All three GridPlan lowerings apply: the compact ones visit only member
blocks; a *stale* buffer (zeros outside the fractal) is aliased to the
output so unvisited blocks stay zero -- the classic double-buffer CA
scheme, which is what keeps the compact grids applicable to stencils,
not just pointwise writes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.domain import BlockDomain
from repro.core.plan import GridPlan
from .sierpinski_write import _cell_mask, resolve_storage_args


def _ca_kernel(coords, c_ref, n_ref, s_ref, w_ref, e_ref, buf_ref, o_ref,
               *, rule, alpha, block, n, domain):
    bx, by = coords.bx, coords.by
    nbx, nby = domain.bounding_box
    nx, ny = nbx * block, nby * block

    def nbr_ok(dx, dy):
        # halo contributions need the neighbour *block* to be in range
        # AND a domain member: under compact storage a non-member
        # neighbour has no slot (its spec was clamped to slot (0, 0)),
        # and under embedded storage its tile is all zero by the CA
        # invariant -- the mask makes both storages read identically.
        x, y = bx + dx, by + dy
        inr = (x >= 0) & (x < nbx) & (y >= 0) & (y < nby)
        return inr & domain.contains(jnp.clip(x, 0, nbx - 1),
                                     jnp.clip(y, 0, nby - 1))

    def body():
        c = c_ref[...]
        north = jnp.where(nbr_ok(0, -1), n_ref[block - 1:block, :], 0)
        south = jnp.where(nbr_ok(0, 1), s_ref[0:1, :], 0)
        west = jnp.where(nbr_ok(-1, 0), w_ref[:, block - 1:block], 0)
        east = jnp.where(nbr_ok(1, 0), e_ref[:, 0:1], 0)

        up = jnp.concatenate([north, c[:-1, :]], axis=0)
        down = jnp.concatenate([c[1:, :], south], axis=0)
        left = jnp.concatenate([west, c[:, :-1]], axis=1)
        right = jnp.concatenate([c[:, 1:], east], axis=1)
        nsum = up + down + left + right

        member = _cell_mask(domain, bx, by, block, n)
        if rule == "parity":
            new = jnp.mod(c + nsum, 2)
        else:  # diffusion: graph Laplacian over member neighbours
            iy = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
            ix = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
            gx = bx * block + ix
            gy = by * block + iy

            def nbr_member(dx, dy):
                x, y = gx + dx, gy + dy
                inside = (x >= 0) & (x < nx) & (y >= 0) & (y < ny)
                return (inside & domain.cell_member(x, y, n)).astype(c.dtype)

            deg = (nbr_member(0, -1) + nbr_member(0, 1) +
                   nbr_member(-1, 0) + nbr_member(1, 0))
            new = c + jnp.asarray(alpha, c.dtype) * (nsum - deg * c)
        o_ref[...] = jnp.where(member, new, 0).astype(o_ref.dtype)

    coords.when_valid(body)


@functools.partial(jax.jit, static_argnames=("rule", "alpha", "block",
                                             "grid_mode", "fractal",
                                             "storage", "n", "domain",
                                             "interpret"))
def ca_step(state: jnp.ndarray, stale_buf: jnp.ndarray, *,
            rule: str = "parity", alpha: float = 0.25, block: int = 128,
            grid_mode: str = "compact",
            fractal: str = "sierpinski-gasket",
            storage: str = "embedded", n: int | None = None,
            domain: BlockDomain | None = None,
            interpret: bool | None = None) -> jnp.ndarray:
    """One CA step.  ``stale_buf`` must be zero outside the fractal (e.g.
    the state from two steps ago, or zeros); it is donated as the output
    buffer so unvisited blocks remain valid.  Under storage="compact"
    both arrays are packed orthotope-resident (pass n= or domain=)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    domain, n, block, storage = resolve_storage_args(
        state, block, fractal, storage, n, domain)
    plan = GridPlan(domain, grid_mode, storage=storage)

    center = plan.storage_spec((block, block))
    in_specs = [center]
    in_specs += [plan.neighbor_spec((block, block), j) for j in range(4)]
    in_specs += [center]                               # stale double buffer
    call = plan.pallas_call(
        functools.partial(_ca_kernel, rule=rule, alpha=alpha, block=block,
                          n=n, domain=domain),
        in_specs=in_specs,
        out_specs=center,
        out_shape=jax.ShapeDtypeStruct(state.shape, state.dtype),
        input_output_aliases={5: 0},
        interpret=interpret,
    )
    return call(state, state, state, state, state, stale_buf)
