"""Cellular-automaton / diffusion step on an embedded fractal, as a
block-space Pallas kernel (the application class the paper motivates:
nearest-neighbour data-parallel simulation over the fractal).

Halo exchange: the kernel receives five views of the state array (center
+ N/S/W/E neighbour tiles) via five BlockSpecs whose index_maps are the
plan-decoded block coordinate shifted by +-1 (clamped; contributions
from clamped-out-of-range tiles are masked in-kernel).  All three
GridPlan lowerings apply: the compact ones visit only member blocks; a
*stale* buffer (zeros outside the fractal) is aliased to the output so
unvisited blocks stay zero -- the classic double-buffer CA scheme, which
is what keeps the compact grids applicable to stencils, not just
pointwise writes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.domain import make_fractal_domain
from repro.core.plan import GridPlan
from .sierpinski_write import _cell_mask


def _ca_kernel(coords, c_ref, n_ref, s_ref, w_ref, e_ref, buf_ref, o_ref,
               *, rule, alpha, block, n, n_b, domain):
    bx, by = coords.bx, coords.by

    def body():
        c = c_ref[...]
        # halo rows/cols, zeroed when the neighbour tile is out of range
        north = jnp.where(by > 0, n_ref[block - 1:block, :], 0)
        south = jnp.where(by < n_b - 1, s_ref[0:1, :], 0)
        west = jnp.where(bx > 0, w_ref[:, block - 1:block], 0)
        east = jnp.where(bx < n_b - 1, e_ref[:, 0:1], 0)

        up = jnp.concatenate([north, c[:-1, :]], axis=0)
        down = jnp.concatenate([c[1:, :], south], axis=0)
        left = jnp.concatenate([west, c[:, :-1]], axis=1)
        right = jnp.concatenate([c[:, 1:], east], axis=1)
        nsum = up + down + left + right

        member = _cell_mask(domain, bx, by, block, n)
        if rule == "parity":
            new = jnp.mod(c + nsum, 2)
        else:  # diffusion: graph Laplacian over member neighbours
            iy = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
            ix = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
            gx = bx * block + ix
            gy = by * block + iy

            def nbr_member(dx, dy):
                x, y = gx + dx, gy + dy
                inside = (x >= 0) & (x < n) & (y >= 0) & (y < n)
                return (inside & domain.cell_member(x, y, n)).astype(c.dtype)

            deg = (nbr_member(0, -1) + nbr_member(0, 1) +
                   nbr_member(-1, 0) + nbr_member(1, 0))
            new = c + jnp.asarray(alpha, c.dtype) * (nsum - deg * c)
        o_ref[...] = jnp.where(member, new, 0).astype(o_ref.dtype)

    coords.when_valid(body)


@functools.partial(jax.jit, static_argnames=("rule", "alpha", "block",
                                             "grid_mode", "fractal",
                                             "interpret"))
def ca_step(state: jnp.ndarray, stale_buf: jnp.ndarray, *,
            rule: str = "parity", alpha: float = 0.25, block: int = 128,
            grid_mode: str = "compact",
            fractal: str = "sierpinski-gasket",
            interpret: bool | None = None) -> jnp.ndarray:
    """One CA step.  ``stale_buf`` must be zero outside the fractal (e.g.
    the state from two steps ago, or zeros); it is donated as the output
    buffer so unvisited blocks remain valid."""
    n = state.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, n)
    n_b = n // block
    domain = make_fractal_domain(fractal, n_b)
    plan = GridPlan(domain, grid_mode)

    def _clamp(v):
        return jnp.clip(v, 0, n_b - 1)

    bs = functools.partial(plan.block_spec, (block, block))
    center = bs(lambda bx, by: (by, bx))
    in_specs = [
        center,
        bs(lambda bx, by: (_clamp(by - 1), bx)),   # north
        bs(lambda bx, by: (_clamp(by + 1), bx)),   # south
        bs(lambda bx, by: (by, _clamp(bx - 1))),   # west
        bs(lambda bx, by: (by, _clamp(bx + 1))),   # east
        center,                                    # stale double buffer
    ]
    call = plan.pallas_call(
        functools.partial(_ca_kernel, rule=rule, alpha=alpha, block=block,
                          n=n, n_b=n_b, domain=domain),
        in_specs=in_specs,
        out_specs=center,
        out_shape=jax.ShapeDtypeStruct(state.shape, state.dtype),
        input_output_aliases={5: 0},
        interpret=interpret,
    )
    return call(state, state, state, state, state, stale_buf)
