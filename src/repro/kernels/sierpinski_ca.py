"""Cellular-automaton / diffusion step on the embedded gasket, as a
block-space Pallas kernel (the application class the paper motivates:
nearest-neighbour data-parallel simulation over the fractal).

Halo exchange: the kernel receives five views of the state array (center
+ N/S/W/E neighbour tiles) via five BlockSpecs whose index_maps are the
lambda-mapped block coordinate shifted by +-1 (clamped; contributions
from clamped-out-of-range tiles are masked in-kernel).  The compact grid
visits only member blocks; a *stale* buffer (zeros outside the fractal)
is aliased to the output so unvisited blocks stay zero -- the classic
double-buffer CA scheme, which is what keeps the lambda grid applicable
to stencils, not just pointwise writes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fractal as F
from .sierpinski_write import _member_mask


def _ca_kernel(c_ref, n_ref, s_ref, w_ref, e_ref, buf_ref, o_ref, *,
               rule, alpha, block, n, n_b, r_b, grid_mode):
    if grid_mode == "compact":
        i = pl.program_id(0)
        bx, by = F.lambda_map_linear(i, r_b)
        is_member_block = True
    else:
        by = pl.program_id(0)
        bx = pl.program_id(1)
        is_member_block = (bx & (n_b - 1 - by)) == 0

    def body():
        c = c_ref[...]
        # halo rows/cols, zeroed when the neighbour tile is out of range
        north = jnp.where(by > 0, n_ref[block - 1:block, :], 0)
        south = jnp.where(by < n_b - 1, s_ref[0:1, :], 0)
        west = jnp.where(bx > 0, w_ref[:, block - 1:block], 0)
        east = jnp.where(bx < n_b - 1, e_ref[:, 0:1], 0)

        up = jnp.concatenate([north, c[:-1, :]], axis=0)
        down = jnp.concatenate([c[1:, :], south], axis=0)
        left = jnp.concatenate([west, c[:, :-1]], axis=1)
        right = jnp.concatenate([c[:, 1:], east], axis=1)
        nsum = up + down + left + right

        member = _member_mask(bx, by, block, n)
        if rule == "parity":
            new = jnp.mod(c + nsum, 2)
        else:  # diffusion: graph Laplacian over member neighbours
            iy = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
            ix = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
            gx = bx * block + ix
            gy = by * block + iy

            def nbr_member(dx, dy):
                x, y = gx + dx, gy + dy
                inside = (x >= 0) & (x < n) & (y >= 0) & (y < n)
                return (inside & ((x & (n - 1 - y)) == 0)).astype(c.dtype)

            deg = (nbr_member(0, -1) + nbr_member(0, 1) +
                   nbr_member(-1, 0) + nbr_member(1, 0))
            new = c + jnp.asarray(alpha, c.dtype) * (nsum - deg * c)
        o_ref[...] = jnp.where(member, new, 0).astype(o_ref.dtype)

    if grid_mode == "compact":
        body()
    else:
        pl.when(is_member_block)(body)


@functools.partial(jax.jit, static_argnames=("rule", "alpha", "block",
                                             "grid_mode", "interpret"))
def ca_step(state: jnp.ndarray, stale_buf: jnp.ndarray, *,
            rule: str = "parity", alpha: float = 0.25, block: int = 128,
            grid_mode: str = "compact",
            interpret: bool | None = None) -> jnp.ndarray:
    """One CA step.  ``stale_buf`` must be zero outside the fractal (e.g.
    the state from two steps ago, or zeros); it is donated as the output
    buffer so unvisited blocks remain valid."""
    n = state.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, n)
    n_b = n // block
    r_b = F.scale_level(n_b)

    if grid_mode == "compact":
        grid = (3 ** r_b,)

        def blk(i):
            lx, ly = F.lambda_map_linear(i, r_b)
            return lx, ly
    elif grid_mode == "bounding":
        grid = (n_b, n_b)

        def blk(i, j):
            return j, i
    else:
        raise ValueError(grid_mode)

    def _clamp(v, lo, hi):
        return jnp.clip(v, lo, hi)

    def idx_center(*a):
        bx, by = blk(*a)
        return (by, bx)

    def idx_north(*a):
        bx, by = blk(*a)
        return (_clamp(by - 1, 0, n_b - 1), bx)

    def idx_south(*a):
        bx, by = blk(*a)
        return (_clamp(by + 1, 0, n_b - 1), bx)

    def idx_west(*a):
        bx, by = blk(*a)
        return (by, _clamp(bx - 1, 0, n_b - 1))

    def idx_east(*a):
        bx, by = blk(*a)
        return (by, _clamp(bx + 1, 0, n_b - 1))

    bs = functools.partial(pl.BlockSpec, (block, block))
    kernel = functools.partial(_ca_kernel, rule=rule, alpha=alpha,
                               block=block, n=n, n_b=n_b, r_b=r_b,
                               grid_mode=grid_mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[bs(idx_center), bs(idx_north), bs(idx_south),
                  bs(idx_west), bs(idx_east), bs(idx_center)],
        out_specs=bs(idx_center),
        out_shape=jax.ShapeDtypeStruct(state.shape, state.dtype),
        input_output_aliases={5: 0},
        interpret=interpret,
    )(state, state, state, state, state, stale_buf)
