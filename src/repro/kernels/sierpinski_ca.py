"""Cellular-automaton / diffusion stepping on an embedded fractal, as a
temporally-fused block-space Pallas kernel (the application class the
paper motivates: nearest-neighbour data-parallel simulation over the
fractal).

One launch advances a (super)block by up to ``fuse`` steps: the kernel
assembles the block plus a ``fuse``-cell halo ring from the 8 neighbour
tiles (corners matter from the second step on, when the dependency
footprint grows past the von-Neumann cross), then advances the classic
*shrinking trapezoid* in an in-kernel ``fori_loop`` -- after k
iterations the outer k rings of the working array are stale, and after
``fuse`` iterations the interior block is exact.  The per-launch step
count is a run-time SMEM scalar, so the final partial launch of a
``steps % fuse`` remainder reuses the same trace.

:func:`ca_run` drives T steps as ``ceil(T / fuse)`` such launches
inside a single jitted ``lax.scan`` with rotating double buffers: one
trace and ceil(T/fuse) launches total, where the old driver paid T
launches and (first call) T Python dispatches.  :func:`ca_step` is the
``steps=1`` special case and keeps its original signature.

Halo exchange: the kernel receives nine views of the state array
(center + 8 neighbour supertiles) via BlockSpecs emitted by the plan.
Under ``storage="embedded"`` the neighbour index_maps are the decoded
block coordinate shifted (clamped); under ``storage="compact"`` the
state lives in the packed orthotope layout and each neighbour index_map
resolves the *embedded* neighbour's packed slot through lambda^-1
(inline for closed_form / bounding, or as an O(1) read of the
host-built 8-neighbour slot table shipped through the scalar-prefetch
LUT).  Out-of-range and non-member neighbour tiles are masked
in-kernel at fine-block granularity (matching the unfused kernel's
semantics exactly, so fused and per-step runs are bit-identical).

Superblock coarsening composes: ``coarsen=s`` makes the center tile an
s x s superblock (lambda decoded once per superblock); under compact
storage the supertile arrives in packed fine-block arrangement and the
kernel permutes it through the plan's static ``tile_map`` before
stencilling.

All three GridPlan lowerings apply: the compact ones visit only member
blocks; a *stale* buffer (zeros outside the fractal) is aliased to the
output so unvisited blocks stay zero -- the double-buffer CA scheme
that keeps the compact grids applicable to stencils, not just
pointwise writes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import backend as backend_lib
from repro.core.backend import full_spec
from repro.core.compact import NEIGHBOR_OFFSETS8
from repro.core.domain import BlockDomain
from repro.core.plan import GridPlan
from .sierpinski_write import resolve_auto_schedule, resolve_storage_args

#: trace/build telemetry the schedule-equivalence tests read: "kernel"
#: counts fused-kernel body traces, "build" counts pallas_call
#: constructions.  A T-step ca_run must bump each exactly once.
TRACE_COUNTER = {"kernel": 0, "build": 0}


def auto_schedule(*, fractal: str = "sierpinski-gasket", n: int,
                  block: int, rule: str = "parity",
                  grid_mode: str = "auto", fuse: int | str = "auto",
                  coarsen: int | str = "auto",
                  num_stages: int | str = "auto", mesh=None,
                  shard_axis: str = "data", target=None):
    """Resolve the (grid_mode, fuse, coarsen, num_stages) schedule for
    a CA problem from the tune cache -- the exact lookup
    :func:`ca_run` / :func:`ca_step` perform, exposed so drivers can
    report the schedule they are about to run without re-deriving the
    cache key.  A sharded run (``mesh=``) consults the
    shard-count-qualified key; a non-default emission ``target``
    consults the target-qualified key."""
    from repro.core import tune
    return resolve_auto_schedule(
        "ca",
        tune.target_params(
            tune.shard_params(
                {"fractal": fractal, "n": n, "block": block,
                 "rule": rule},
                mesh, shard_axis),
            target),
        grid_mode=(grid_mode, "lowering", "closed_form"),
        fuse=(fuse, "fuse", 1),
        coarsen=(coarsen, "coarsen", 1),
        num_stages=(num_stages, "stages", 1))


def effective_fuse(fuse: int, steps: int, block: int,
                   coarsen: int = 1) -> int:
    """The fuse depth :func:`ca_run` actually executes: clamped so the
    halo ring fits one neighbour supertile (``coarsen * block``) and
    never exceeds the step count."""
    return max(1, min(int(fuse), coarsen * block,
                      steps if steps else 1))


def launch_schedule(steps: int, fuse: int) -> list:
    """Per-launch step counts for T steps at fuse depth k:
    ``ceil(T/k)`` launches of k steps, the last carrying the
    remainder."""
    steps, fuse = int(steps), int(fuse)
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    if fuse < 1:
        raise ValueError(f"fuse must be >= 1, got {fuse}")
    full, rem = divmod(steps, fuse)
    return [fuse] * full + ([rem] if rem else [])


def _trapezoid_update(tiles, bx, by, steps, *, rule, alpha, block, n,
                      plan, halo):
    """The fused-CA math, shared by both emission structures: assemble
    the working array from the center + 8 neighbour supertiles
    (embedded-storage arrangement; packed fine-block arrangement under
    compact coarsening), advance the shrinking trapezoid ``steps``
    times, and return the output supertile in storage arrangement.

    ``tiles``: 9 arrays in [center] + NEIGHBOR_OFFSETS8 order, each the
    plan's storage-supertile shape.  ``(bx, by)``: scheduled (coarse)
    block coords."""
    domain = plan.domain
    span = plan.coarsen * block        # embedded superblock side, cells
    h = halo
    wid = span + 2 * h                 # working (trapezoid base) side
    tm = plan.tile_map()

    def embed(t):
        """Packed supertile -> embedded arrangement (identity when the
        storage tile is already embedded-ordered)."""
        if tm is None:
            return t
        e = jnp.zeros((span, span), t.dtype)
        for (py, px), (ey, ex) in tm:
            e = jax.lax.dynamic_update_slice(
                e, t[py * block:(py + 1) * block,
                     px * block:(px + 1) * block],
                (ey * block, ex * block))
        return e

    def unembed(e):
        if tm is None:
            return e
        p = jnp.zeros(plan.supertile_shape((block, block)), e.dtype)
        for (py, px), (ey, ex) in tm:
            p = jax.lax.dynamic_update_slice(
                p, e[ey * block:(ey + 1) * block,
                     ex * block:(ex + 1) * block],
                (py * block, px * block))
        return p

    # strip geometry: which rows/cols of a neighbour's embedded view
    # land where in the padded working array (relative offset -1/0/+1)
    _SPANS = {-1: (span - h, 0, h), 0: (0, h, span), 1: (0, span + h, h)}

    P = jnp.zeros((wid, wid), tiles[0].dtype)
    P = jax.lax.dynamic_update_slice(P, embed(tiles[0]), (h, h))
    for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS8):
        e = embed(tiles[1 + j])
        r_src, r_dst, nr = _SPANS[dy]
        c_src, c_dst, nc = _SPANS[dx]
        P = jax.lax.dynamic_update_slice(
            P, e[r_src:r_src + nr, c_src:c_src + nc], (r_dst, c_dst))

    iy = jax.lax.broadcasted_iota(jnp.int32, (wid, wid), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (wid, wid), 1)
    gx = bx * span - h + ix
    gy = by * span - h + iy
    inr = (gx >= 0) & (gx < n) & (gy >= 0) & (gy < n)
    gxc = jnp.clip(gx, 0, n - 1)
    gyc = jnp.clip(gy, 0, n - 1)
    # contributions are discarded at fine-*block* granularity (the
    # unfused kernel's nbr_ok), values at *cell* granularity: a
    # member block's non-member cells pass raw into the first
    # neighbour sum (zero by the CA invariant) and are re-zeroed by
    # the output mask every step.
    cell_ok = inr & domain.cell_member(gxc, gyc, n)
    block_ok = inr & domain.contains(gxc // block, gyc // block)
    P = jnp.where(block_ok, P, 0)

    zrow = jnp.zeros((1, wid), P.dtype)
    zcol = jnp.zeros((wid, 1), P.dtype)

    def nsum_of(a):
        up = jnp.concatenate([zrow.astype(a.dtype), a[:-1, :]], 0)
        down = jnp.concatenate([a[1:, :], zrow.astype(a.dtype)], 0)
        left = jnp.concatenate([zcol.astype(a.dtype), a[:, :-1]], 1)
        right = jnp.concatenate([a[:, 1:], zcol.astype(a.dtype)], 1)
        return up + down + left + right

    if rule == "parity":
        def one(pv):
            return jnp.where(cell_ok, jnp.mod(pv + nsum_of(pv), 2), 0)
    else:  # diffusion: graph Laplacian over member neighbours
        deg = nsum_of(cell_ok.astype(P.dtype))
        al = jnp.asarray(alpha, P.dtype)

        def one(pv):
            new = pv + al * (nsum_of(pv) - deg * pv)
            return jnp.where(cell_ok, new, 0)

    P2 = jax.lax.fori_loop(0, steps, lambda i, pv: one(pv), P)
    return unembed(P2[h:h + span, h:h + span])


def _ca_fused_kernel(coords, c_ref, n_ref, s_ref, w_ref, e_ref, nw_ref,
                     ne_ref, sw_ref, se_ref, buf_ref, steps_ref, o_ref,
                     *, rule, alpha, block, n, plan, halo):
    """Advance one (super)block by ``steps_ref[0] <= halo`` CA steps
    (block-indexed structure: the 9 supertiles arrive as BlockSpec-fed
    operand views)."""
    TRACE_COUNTER["kernel"] += 1
    nbr_refs = (n_ref, s_ref, w_ref, e_ref, nw_ref, ne_ref, sw_ref,
                se_ref)

    def body():
        tiles = [c_ref[...]] + [r[...] for r in nbr_refs]
        o_ref[...] = _trapezoid_update(
            tiles, coords.bx, coords.by, steps_ref[0], rule=rule,
            alpha=alpha, block=block, n=n, plan=plan,
            halo=halo).astype(o_ref.dtype)

    coords.when_valid(body)


def _ca_fused_kernel_dma(coords, c_ref, buf_ref, steps_ref, o_ref,
                         bufs_ref, sems, *, rule, alpha, block, n, plan,
                         halo, stages):
    """Async-copy pipelined fused CA (TPU structure, ``num_stages`` >=
    2): the state is parked whole in ``pltpu.ANY`` and the kernel
    streams each step's 9 supertiles (center + 8 lambda^-1-resolved
    neighbours) into rotating VMEM buffers with explicit DMA -- the
    copies for grid step t+stages-1 start before step t's trapezoid
    runs, hiding the tile fetches behind compute.  Tile addressing,
    visit order and the trapezoid math are exactly the synchronous
    kernel's, so results are bit-identical."""
    TRACE_COUNTER["kernel"] += 1
    refs = coords.refs
    total = plan.steps_per_launch
    lin = plan.linear_step(coords.grid_ids)

    def srcs_for(step):
        gi = plan.grid_ids_at(step)
        srcs = [plan.storage_index(gi, refs)]
        for j in range(8):
            srcs.append(plan.neighbor_index(j, gi, refs))
        return srcs

    tiles = backend_lib.stream_tiles(
        c_ref, bufs_ref, sems, srcs_for=srcs_for, lin=lin, total=total,
        stages=stages)

    def body():
        o_ref[...] = _trapezoid_update(
            tiles, coords.bx, coords.by, steps_ref[0], rule=rule,
            alpha=alpha, block=block, n=n, plan=plan,
            halo=halo).astype(o_ref.dtype)

    coords.when_valid(body)


def _ca_fused_kernel_gpu(coords, c_ref, buf_ref, steps_ref, o_ref, *,
                         rule, alpha, block, n, plan, halo):
    """gpu-structured fused CA: the state arrives whole; the kernel
    gathers the center + 8 lambda^-1-resolved neighbour supertiles with
    computed offsets (slot indices from the plan -- an O(1) read of the
    HBM LUT operand under ``prefetch_lut``) and stores the advanced
    interior back at the center slot."""
    TRACE_COUNTER["kernel"] += 1
    th, tw = plan.supertile_shape((block, block))
    gi, refs = coords.grid_ids, coords.refs

    def load_at(iy, ix):
        return pl.load(c_ref, (pl.ds(iy * th, th), pl.ds(ix * tw, tw)))

    def body():
        cy, cx = plan.storage_index(gi, refs)
        tiles = [load_at(cy, cx)]
        for j in range(8):
            ny, nx = plan.neighbor_index(j, gi, refs)
            tiles.append(load_at(ny, nx))
        out = _trapezoid_update(
            tiles, coords.bx, coords.by, steps_ref[0], rule=rule,
            alpha=alpha, block=block, n=n, plan=plan, halo=halo)
        pl.store(o_ref, (pl.ds(cy * th, th), pl.ds(cx * tw, tw)),
                 out.astype(o_ref.dtype))

    coords.when_valid(body)


def _build_launch(plan, *, rule, alpha, block, n, halo, shape, dtype,
                  in_shape=None, stages=1):
    """One fused pallas_call: (state, stale, steps[1]) -> new state.

    Block-indexed targets receive nine BlockSpec views of the state;
    with ``stages >= 2`` on an async-copy target the state instead
    arrives whole (``pltpu.ANY``) and the kernel streams the nine tiles
    through rotating VMEM DMA buffers (:func:`_ca_fused_kernel_dma`).
    gpu targets receive it whole (``in_shape``, which may be the
    halo-extended local array under sharding) plus the stale buffer and
    the step count as a regular scalar operand; their per-step tile
    gather is already load-then-compute, so ``stages`` only feeds the
    Triton scheduler on real GPUs."""
    TRACE_COUNTER["build"] += 1
    target = plan.target
    stages = target.resolve_stages(stages)
    kernel_kw = dict(rule=rule, alpha=alpha, block=block, n=n, plan=plan,
                     halo=halo)
    if target.block_indexed and stages > 1:
        tile = plan.storage_spec((block, block))
        th, tw = plan.supertile_shape((block, block))
        call = plan.pallas_call(
            functools.partial(_ca_fused_kernel_dma, **kernel_kw,
                              stages=stages),
            in_specs=[target.any_spec(), tile, target.scalar_spec()],
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct(shape, dtype),
            scratch_shapes=[
                target.scratch((stages, 9, th, tw), dtype),
                target.dma_sems((stages, 9)),
            ],
            input_output_aliases={1: 0},
        )

        def launch(a, b, steps_scalar, prefetch=()):
            return call(*prefetch, a, b, steps_scalar)
        return launch

    if target.block_indexed:
        tile = plan.storage_spec((block, block))
        in_specs = [tile]
        in_specs += [plan.neighbor_spec((block, block), j)
                     for j in range(8)]
        in_specs += [tile]                       # stale buffer
        in_specs += [plan.target.scalar_spec()]  # step count
        call = plan.pallas_call(
            functools.partial(_ca_fused_kernel, **kernel_kw),
            in_specs=in_specs,
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct(shape, dtype),
            input_output_aliases={9: 0},
        )

        def launch(a, b, steps_scalar, prefetch=()):
            return call(*prefetch, a, a, a, a, a, a, a, a, a, b,
                        steps_scalar)
        return launch

    call = plan.pallas_call(
        functools.partial(_ca_fused_kernel_gpu, **kernel_kw),
        in_specs=[full_spec(in_shape or shape), full_spec(shape),
                  plan.target.scalar_spec()],
        out_specs=full_spec(shape),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        input_output_aliases={1: 0},
        num_stages=stages if stages > 1 else None,
    )

    def launch(a, b, steps_scalar, prefetch=()):
        return call(*prefetch, a, b, steps_scalar)
    return launch


def _ca_run_impl(state, stale_buf, *, steps, fuse, rule, alpha, block,
                 grid_mode, fractal, storage, n, domain, coarsen,
                 backend, stages=1, verify=False):
    domain, n, block, storage = resolve_storage_args(
        state, block, fractal, storage, n, domain)
    plan = GridPlan(domain, grid_mode, storage=storage, coarsen=coarsen,
                    backend=backend)
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(plan, kernel="ca")
    fuse = effective_fuse(fuse, steps, block, plan.coarsen)
    sched = launch_schedule(steps, fuse)
    if not sched:
        return state
    launch = _build_launch(plan, rule=rule, alpha=alpha, block=block,
                           n=n, halo=fuse, shape=state.shape,
                           dtype=state.dtype, stages=stages)

    def body(carry, per_launch):
        a, b = carry
        new = launch(a, b, jnp.reshape(per_launch, (1,)))
        return (new, a), None

    (a, _), _ = jax.lax.scan(body, (state, stale_buf),
                             jnp.asarray(sched, jnp.int32))
    return a


_CA_STATIC = ("steps", "fuse", "rule", "alpha", "block", "grid_mode",
              "fractal", "storage", "n", "domain", "coarsen", "backend",
              "stages", "verify")
_CA_RUN_JIT = {
    False: jax.jit(_ca_run_impl, static_argnames=_CA_STATIC),
    True: jax.jit(_ca_run_impl, static_argnames=_CA_STATIC,
                  donate_argnums=(0, 1)),
}


def _ca_run_sharded_impl(state, stale_buf, *, steps, fuse, rule, alpha,
                         block, grid_mode, fractal, storage, n, domain,
                         coarsen, backend, mesh, shard_axis, stages=1,
                         verify=False):
    """ca_run across a mesh axis: each device advances its share of the
    domain; compact storage is slab-sharded with a ppermute ghost-row
    exchange before every launch, embedded storage is replicated and
    combined by a disjoint-ownership-mask psum after every launch.
    Bit-identical to the single-device scan (every block is computed by
    exactly one device with the same operands)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.shard import ShardedPlan, device_tables

    domain, n, block, storage = resolve_storage_args(
        state, block, fractal, storage, n, domain)
    plan = ShardedPlan(domain, grid_mode, storage=storage,
                       coarsen=coarsen, backend=backend, mesh=mesh,
                       axis=shard_axis, halo=(storage == "compact"))
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(plan, kernel="ca")
    fuse = effective_fuse(fuse, steps, block, plan.coarsen)
    sched = launch_schedule(steps, fuse)
    if not sched:
        return state
    local_shape = plan.local_storage_shape(block)
    if storage == "compact":
        # the center operand is the halo-extended local array
        rpd, ru = plan.rpd, plan.row_unit
        ext_rows = (rpd + plan.halo.h_max + 1) * ru
        in_shape = (ext_rows, local_shape[1])
    else:
        in_shape = local_shape
    launch = _build_launch(plan, rule=rule, alpha=alpha, block=block,
                           n=n, halo=fuse, shape=local_shape,
                           dtype=state.dtype, in_shape=in_shape,
                           stages=stages)
    tbl, luts = device_tables(plan)
    sched_arr = jnp.asarray(sched, jnp.int32)
    axis = shard_axis
    tbl_spec = P(axis, None)
    lut_specs = tuple(P(axis, None) for _ in luts)

    if storage == "compact":
        halo = plan.halo
        sr = tuple(tuple(jnp.asarray(t) for t in tabs)
                   for tabs in halo.send_recv_host())
        sr_specs = tuple(tuple(P(axis, None) for _ in tabs)
                         for tabs in sr)
        a = plan.pad_rows(state, block)
        b = plan.pad_rows(stale_buf, block)
        # halo/compute overlap: with pipelining on and a step-indexed
        # lowering, split each launch into an interior phase (no ghost
        # reads -- runs while the ppermute is in flight) and a boundary
        # phase that waits for the exchanged ghost rows.  Falls back to
        # the synchronous single launch when a phase is empty.
        phases = plan.phase_tables_host() \
            if stages > 1 and plan.lowering != "bounding" else None
        if phases is not None:
            int_h, bnd_h = phases
            launch_int = _build_launch(
                plan.phase_view("interior"), rule=rule, alpha=alpha,
                block=block, n=n, halo=fuse, shape=local_shape,
                dtype=state.dtype, in_shape=in_shape, stages=stages)
            launch_bnd = _build_launch(
                plan.phase_view("boundary"), rule=rule, alpha=alpha,
                block=block, n=n, halo=fuse, shape=local_shape,
                dtype=state.dtype, in_shape=in_shape, stages=stages)
            itb, btb = jnp.asarray(int_h), jnp.asarray(bnd_h)

            def device_fn(tbl, luts, itb, btb, sr, a, b):
                pre = (tbl.reshape(-1),) + luts
                pi = pre + (itb.reshape(-1),)
                pb = pre + (btb.reshape(-1),)

                def body(carry, per_launch):
                    x, y = carry
                    s = jnp.reshape(per_launch, (1,))
                    ghost = halo.exchange(plan, x, sr, h=fuse)
                    ext0 = halo.cat(plan, x, jnp.zeros_like(ghost))
                    mid = launch_int(ext0, y, s, pi)
                    new = launch_bnd(halo.cat(plan, x, ghost), mid, s,
                                     pb)
                    return (new, x), None

                (xa, _), _ = jax.lax.scan(body, (a, b), sched_arr)
                return xa

            out = shard_map(
                device_fn, mesh=mesh,
                in_specs=(tbl_spec, lut_specs, P(axis, None),
                          P(axis, None), sr_specs, P(axis, None),
                          P(axis, None)),
                out_specs=P(axis, None), check_rep=False)(
                    tbl, luts, itb, btb, sr, a, b)
            return plan.unpad_rows(out, block)

        def device_fn(tbl, luts, sr, a, b):
            pre = (tbl.reshape(-1),) + luts

            def body(carry, per_launch):
                x, y = carry
                ext = halo.extend(plan, x, sr, h=fuse)
                new = launch(ext, y, jnp.reshape(per_launch, (1,)), pre)
                return (new, x), None

            (xa, _), _ = jax.lax.scan(body, (a, b), sched_arr)
            return xa

        out = shard_map(
            device_fn, mesh=mesh,
            in_specs=(tbl_spec, lut_specs, sr_specs, P(axis, None),
                      P(axis, None)),
            out_specs=P(axis, None), check_rep=False)(tbl, luts, sr, a, b)
        return plan.unpad_rows(out, block)

    def device_fn(tbl, luts, a, b):
        tbl1 = tbl.reshape(-1)
        pre = (tbl1,) + luts
        mask = plan.owned_cell_mask(tbl1, n, block)

        def body(carry, per_launch):
            x, y = carry
            part = launch(x, y, jnp.reshape(per_launch, (1,)), pre)
            new = jax.lax.psum(jnp.where(mask, part, 0), axis)
            return (new, x), None

        (xa, _), _ = jax.lax.scan(body, (a, b), sched_arr)
        return xa

    return shard_map(
        device_fn, mesh=mesh,
        in_specs=(tbl_spec, lut_specs, P(None, None), P(None, None)),
        out_specs=P(None, None), check_rep=False)(
            tbl, luts, state, stale_buf)


_CA_SHARD_STATIC = _CA_STATIC + ("mesh", "shard_axis")
_CA_RUN_SHARD_JIT = {
    False: jax.jit(_ca_run_sharded_impl, static_argnames=_CA_SHARD_STATIC),
    True: jax.jit(_ca_run_sharded_impl, static_argnames=_CA_SHARD_STATIC,
                  donate_argnums=(0, 1)),
}


def ca_run(state: jnp.ndarray, stale_buf: jnp.ndarray, steps: int, *,
           fuse: int | str = "auto", rule: str = "parity",
           alpha: float = 0.25, block: int = 128,
           grid_mode: str = "compact",
           fractal: str = "sierpinski-gasket",
           storage: str = "embedded", n: int | None = None,
           domain: BlockDomain | None = None, coarsen: int | str = 1,
           num_stages: int | str = "auto", backend=None,
           interpret: bool | None = None, donate: bool | None = None,
           mesh=None, shard_axis: str = "data",
           verify: bool = False) -> jnp.ndarray:
    """Advance the CA ``steps`` steps and return the final state.

    ``fuse=k`` executes k steps per kernel launch (one in-kernel
    trapezoid loop), so the whole run costs ceil(steps/k) launches
    driven by a single jitted ``lax.scan`` -- bit-identical to
    ``steps`` sequential :func:`ca_step` calls.  ``fuse`` is clamped to
    ``coarsen * block`` (the halo ring must fit one neighbour
    supertile) and to ``steps`` -- see :func:`effective_fuse`.
    ``fuse="auto"`` / ``grid_mode="auto"`` / ``coarsen="auto"`` resolve
    from the :mod:`~repro.core.tune` cache (defaults: 1 / closed_form /
    1; see :func:`auto_schedule`).

    ``stale_buf`` must be zero outside the fractal (the double-buffer
    invariant); both buffers are donated on accelerators unless
    ``donate=False``.  Under ``storage="compact"`` both arrays are
    packed orthotope-resident (pass ``n=`` or ``domain=``).

    ``mesh=`` (a ``jax.sharding.Mesh``) shards the run over
    ``shard_axis``: compact state splits into orthotope row slabs
    (per-device memory O(n^H / D) + halo) with a lambda^-1-resolved
    ppermute ghost exchange between launches (trimmed to the fuse-deep
    strip and the occupied column window of each ghost row; see
    :class:`repro.core.shard.HaloPlan`); embedded state stays
    replicated and devices psum their disjoint block shares.  Both are
    bit-identical to the single-device run.

    ``num_stages`` >= 2 ("auto" = tuned) software-pipelines each
    launch on capable targets (see README "Pipelining"): the TPU
    structure streams the 9 halo supertiles through rotating
    async-copy VMEM buffers so step t+1's fetches overlap step t's
    trapezoid; under a sharded compact run the scan also splits each
    launch into interior and boundary phases so the ppermute ghost
    exchange overlaps interior compute.  Bit-identical to the
    synchronous path.

    ``backend`` selects the emission target ("tpu" | "gpu" |
    "*-interpret" | None = platform default; see
    :mod:`repro.core.backend`).  ``verify=True`` statically verifies
    the emitted plan (coverage / races / tables / bounds / aliasing;
    :mod:`repro.analysis`) at trace time and raises on any
    violation."""
    target = backend_lib.resolve(backend, interpret)
    grid_mode, fuse, coarsen, num_stages = auto_schedule(
        fractal=fractal, n=n or state.shape[0], block=block, rule=rule,
        grid_mode=grid_mode, fuse=fuse, coarsen=coarsen,
        num_stages=num_stages, mesh=mesh, shard_axis=shard_axis,
        target=target)
    if donate is None:
        donate = not target.interpret and jax.default_backend() != "cpu"
    kw = dict(steps=int(steps), fuse=fuse, rule=rule, alpha=alpha,
              block=block, grid_mode=grid_mode, fractal=fractal,
              storage=storage, n=n, domain=domain, coarsen=coarsen,
              backend=target, stages=target.resolve_stages(num_stages),
              verify=verify)
    if mesh is not None:
        return _CA_RUN_SHARD_JIT[bool(donate)](
            state, stale_buf, mesh=mesh, shard_axis=shard_axis, **kw)
    return _CA_RUN_JIT[bool(donate)](state, stale_buf, **kw)


def ca_step(state: jnp.ndarray, stale_buf: jnp.ndarray, *,
            rule: str = "parity", alpha: float = 0.25, block: int = 128,
            grid_mode: str = "compact",
            fractal: str = "sierpinski-gasket",
            storage: str = "embedded", n: int | None = None,
            domain: BlockDomain | None = None, coarsen: int | str = 1,
            num_stages: int | str = "auto", backend=None,
            interpret: bool | None = None, mesh=None,
            shard_axis: str = "data",
            verify: bool = False) -> jnp.ndarray:
    """One CA step (the ``steps=1`` slice of :func:`ca_run`).

    ``stale_buf`` must be zero outside the fractal (e.g. the state from
    two steps ago, or zeros); it is aliased to the output buffer so
    blocks a compact grid never visits remain valid."""
    target = backend_lib.resolve(backend, interpret)
    grid_mode, _, coarsen, num_stages = auto_schedule(
        fractal=fractal, n=n or state.shape[0], block=block, rule=rule,
        grid_mode=grid_mode, fuse=1, coarsen=coarsen,
        num_stages=num_stages, mesh=mesh, shard_axis=shard_axis,
        target=target)
    kw = dict(steps=1, fuse=1, rule=rule, alpha=alpha, block=block,
              grid_mode=grid_mode, fractal=fractal, storage=storage,
              n=n, domain=domain, coarsen=coarsen, backend=target,
              stages=target.resolve_stages(num_stages), verify=verify)
    if mesh is not None:
        return _CA_RUN_SHARD_JIT[False](
            state, stale_buf, mesh=mesh, shard_axis=shard_axis, **kw)
    return _CA_RUN_JIT[False](state, stale_buf, **kw)
