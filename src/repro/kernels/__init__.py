# Pallas TPU kernels for the paper's compute hot-spots:
#   sierpinski_write -- the paper's SS IV microbenchmark (lambda vs BB grid)
#   sierpinski_ca    -- nearest-neighbour CA/diffusion on the gasket
#   flash_attention  -- block-space (compact triangular/band grid) attention
# Each kernel module has its pure-jnp oracle in ref.py and its public
# jit'd wrapper re-exported via ops.py.
from . import ref
from .ops import (ca_run, ca_step, flash_attention, launch_schedule,
                  sierpinski_sum, sierpinski_write)
