"""Block-space flash attention: the paper's compact-grid technique applied
to the dominant kernel of the assigned LM architectures.

The (q_block, k_block) pairs of causal attention form a lower-triangular
block domain -- the 2-simplex case of the authors' block-space program
[Navarro et al. 2014/2016].  Instead of launching the bounding-box grid
``m_q x m_k`` and discarding invalid blocks at run time (the standard
masked-flash formulation), the compact grid launches exactly
``T(m) = m(m+1)/2`` (causal) or ``T(w) + (m-w)w`` (local window) steps
and decodes ``t -> (q_block, k_block)`` either in closed form (the
integer-sqrt inverse of the triangular enumeration -- the m=2 case of
the "order-m equation" map of related work [18]) or through the
scalar-prefetch lookup table, both emitted by the shared
:class:`~repro.core.plan.GridPlan` engine.  ``grid_mode`` selects the
lowering: ``closed_form`` (alias ``compact``) | ``prefetch_lut`` |
``bounding`` | ``mma`` (digit-basis matmul decode on the MXU / tensor
cores; the gpu structure consumes a device-built row-extents operand).

Grid layout: ``(batch*heads, T)``; the compact enumerations are
row-major in q, so all k-steps of one q row are consecutive: the online
softmax state lives in VMEM scratch and the output block is written once
per row (standard flash revisiting pattern).  GQA folds the kv-head
index inside the BlockSpec index_map.

Compact KV (the ``storage=`` axis): ``kind="local"`` also accepts
``sq < sk`` with the decode convention (queries are the last sq
positions of the key sequence -- chunked prefill / decode against a long
cache).  The rectangular BandDomain then touches only the *last*
``sq + window`` key positions, and ``storage="compact"`` reads K/V
packed to exactly that support (the sliding-window KV-cache truncation:
O(window) cache instead of O(sk)); the kv BlockSpec index maps are
rewritten to packed slots.  For causal / full / square-local the column
support is all of sk, so compact and embedded KV coincide -- the packing
is the 1-D analogue of the fractal orthotope packing.

Forward only (training uses the custom-vjp jnp path in
``repro.models.attention``; this kernel is the serving/TPU fast path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import backend as backend_lib
from repro.core.backend import full_spec
from repro.core.compact import key_block_support
from repro.core.domain import make_attention_domain
from repro.core.plan import GridPlan, normalize_storage

NEG_INF = float(-1e30)  # avoid true -inf so exp() stays nan-free


def _row_bounds(kind, qb, m_k, wb, off_b):
    if kind == "causal":
        return 0 * qb, qb
    if kind == "local":
        return jnp.maximum(qb + off_b - (wb - 1), 0), qb + off_b
    return 0 * qb, qb * 0 + (m_k - 1)  # full


def _attn_tile_update(q, k, v, acc, m_prev, l_prev, *, kind, window, qb,
                      kb, block_q, block_k, off, seq_pos=None):
    """One online-softmax step over the (qb, kb) tile -- the kernel
    math shared by both emission structures (TPU scratch refs, GPU loop
    carries).  ``q`` is pre-scaled f32; k/v are f32 tiles.  ``seq_pos``
    (run-time scalar) additionally masks keys beyond the current decode
    position."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = None
    kpos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    if kind in ("causal", "local"):
        # decode convention: query row qb covers embedded token
        # positions off + qb*block_q + [0, block_q)
        qpos = off + qb * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        mask = kpos <= qpos
        if kind == "local":
            mask &= kpos > qpos - window
    if seq_pos is not None:
        pm = kpos <= seq_pos
        if kind == "full" and window:
            # run-time sliding window anchored at the decode position
            pm &= kpos > seq_pos - window
        mask = pm if mask is None else mask & pm
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)

    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    return acc_new, m_new, l_new


def _attn_kernel(coords, *refs, kind, window, scale, block_q, block_k,
                 m_k, wb, off, h, has_pos):
    """Block-indexed (TPU) attention kernel: one (qb, kb) tile per grid
    step, online-softmax state in VMEM scratch across the sequential
    grid.  ``pos_ref`` (when present) is the whole (B,) decode-position
    vector in SMEM; the batch row of this program is the leading grid
    id divided by the head count ``h``."""
    if has_pos:
        q_ref, k_ref, v_ref, pos_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    kb, qb = coords.bx, coords.by
    start, end = _row_bounds(kind, qb, m_k, wb, off // block_q)
    pos = None
    if has_pos:
        pos = pos_ref[coords.batch[0] // h]
        end = jnp.minimum(end, pos // block_k)
        if kind == "full" and window:
            start = jnp.maximum(
                start, jnp.maximum(pos - window + 1, 0) // block_k)

    def body():
        @pl.when(kb == start)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        acc_new, m_new, l_new = _attn_tile_update(
            q, k, v, acc_ref[...], m_ref[...], l_ref[...], kind=kind,
            window=window, qb=qb, kb=kb, block_q=block_q,
            block_k=block_k, off=off, seq_pos=pos)
        acc_ref[...] = acc_new
        m_ref[...] = m_new
        l_ref[...] = l_new

        @pl.when(kb == end)
        def _():
            l = l_ref[...]
            l = jnp.where(l == 0, 1.0, l)
            o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)

    live = None if pos is None else ((kb <= end) & (kb >= start))
    if coords.valid is None and live is None:
        body()
    elif coords.valid is None:
        pl.when(live)(body)
    elif live is None:
        pl.when(coords.valid)(body)
    else:
        pl.when(coords.valid & live)(body)


def _gpu_flash_call(*, target, domain, lowering, b, h, group, m_q, m_k,
                    wb, off, block_q, block_k, d, kind, window, scale,
                    out_shape, dtype, s0, sk_arr, has_pos,
                    row_extents=None, sharded=False, rows_local=None,
                    zigzag=False, num_shards=1,
                    num_warps=None, num_stages=None):
    """gpu-structured flash attention: grid ``(batch*heads, q_rows)``,
    one program per query-block row, an in-kernel ``fori_loop`` over
    that row's key-block extent with the online-softmax state in loop
    carries (parallel grids cannot persist scratch across steps).  The
    lowering picks the extent source: ``closed_form`` computes the row
    bounds inline, ``prefetch_lut`` reads the host-built row-extents
    table as an HBM operand indexed by the program id, ``mma`` reads an
    extents operand produced on device by the digit-basis matmul chain
    (:func:`repro.core.mma.row_extents_chain`, bit-identical to the
    host table), ``bounding``
    walks the full key range and where-guards non-member tiles --
    visiting exactly the tiles (in exactly the order) the block-indexed
    structure visits, so results are bit-identical per lowering.

    ``num_stages`` >= 2 software-pipelines the key loop: the loads for
    key blocks k+1 .. k+stages-1 ride the loop carry as a FIFO, so each
    iteration issues the load for block k+stages-1 *before* the softmax
    consumes block k and the tile fetches overlap the dot-products of
    earlier blocks (on a real GPU the same knob also reaches the Triton
    scheduler via compiler params).  The FIFO rotation consumes tiles
    in exactly the synchronous order, so results stay bit-identical;
    loads past the row extent clamp to the last key block and are
    discarded unread.

    Returns ``call(*tables, q, k, v[, pos])`` where ``tables`` is the
    row-extents operand under ``prefetch_lut``/``mma`` plus the
    per-device shard-table row when ``sharded`` (global query row =
    local row + ``tbl[SHARD_ROWLO]``, or the snake row rebuilt from the
    device id at ``tbl[SHARD_DEV]`` under ``zigzag``)."""
    from repro.core.shard import SHARD_DEV, SHARD_ROWLO

    n_ext = 1 if lowering in ("prefetch_lut", "mma") else 0
    n_tbl = 1 if sharded else 0
    rows = rows_local if rows_local is not None else m_q
    kv_blocks = m_k - s0
    stages = target.resolve_stages(num_stages)

    def kern(*refs):
        i = 0
        ext_ref = refs[0] if n_ext else None
        i += n_ext
        tbl_ref = refs[i] if n_tbl else None
        i += n_tbl
        q_ref, k_ref, v_ref = refs[i:i + 3]
        i += 3
        pos_ref = refs[i] if has_pos else None
        o_ref = refs[-1]

        qb = pl.program_id(1)
        if sharded and zigzag:
            two_d = 2 * num_shards
            dev = tbl_ref[SHARD_DEV]
            qb = (qb // 2) * two_d + jnp.where(
                qb % 2 == 0, dev, two_d - 1 - dev)
        elif sharded:
            qb = qb + tbl_ref[SHARD_ROWLO]
        if lowering in ("prefetch_lut", "mma"):
            start, end = ext_ref[qb, 0], ext_ref[qb, 1]
        elif lowering == "bounding":
            start, end = 0 * qb, 0 * qb + (m_k - 1)
        else:
            start, end = _row_bounds(kind, qb, m_k, wb, off // block_q)
        pos = None
        if has_pos:
            pos = pos_ref[pl.program_id(0) // h]
            end = jnp.minimum(end, pos // block_k)
            if kind == "full" and window:
                start = jnp.maximum(
                    start, jnp.maximum(pos - window + 1, 0) // block_k)

        q = q_ref[0, 0].astype(jnp.float32) * scale

        def load_kv(ref, kb):
            # clamp unconditionally: in-range reads (all the loop ever
            # consumes) are unchanged, and pipelined prefetches past
            # the row extent stay in bounds
            kv = jnp.clip(kb - s0, 0, kv_blocks - 1)
            t = pl.load(ref, (pl.ds(0, 1), pl.ds(0, 1),
                              pl.ds(kv * block_k, block_k),
                              pl.ds(0, d)))
            return t.reshape(block_k, d).astype(jnp.float32)

        def load_tiles(kb):
            return load_kv(k_ref, kb), load_kv(v_ref, kb)

        def update(carry, kb, tiles):
            k_t, v_t = tiles
            new = _attn_tile_update(
                q, k_t, v_t, *carry, kind=kind, window=window, qb=qb,
                kb=kb, block_q=block_q, block_k=block_k, off=off,
                seq_pos=pos)
            if lowering == "bounding" and not getattr(
                    domain, "always_member", False):
                ok = domain.contains(kb, qb)
                new = tuple(jnp.where(ok, nw, old)
                            for nw, old in zip(new, carry))
            return new

        acc0 = (jnp.zeros((block_q, d), jnp.float32),
                jnp.full((block_q, 1), NEG_INF, jnp.float32),
                jnp.zeros((block_q, 1), jnp.float32))
        n_steps = end - start + 1
        if stages <= 1:
            def step(j, carry):
                kb = start + j
                return update(carry, kb, load_tiles(kb))

            acc, _, l = jax.lax.fori_loop(0, n_steps, step, acc0)
        else:
            # software-pipelined KV FIFO: the prologue issues the loads
            # for key blocks start .. start+stages-2; each iteration
            # then loads block j+stages-1 *before* the softmax consumes
            # block j, keeping stages-1 tile fetches in flight past the
            # compute.  Consumption order equals the synchronous order.
            fifo0 = tuple(load_tiles(start + i) for i in range(stages - 1))

            def step(j, carry):
                fifo, state = carry
                nxt = load_tiles(start + j + (stages - 1))
                state = update(state, start + j, fifo[0])
                return fifo[1:] + (nxt,), state

            _, (acc, _, l) = jax.lax.fori_loop(
                0, n_steps, step, (fifo0, acc0))
        l = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0, ...] = (acc / l).astype(o_ref.dtype)

    def q_spec():
        return pl.BlockSpec((1, 1, block_q, d),
                            lambda bh, qb: (bh // h, bh % h, qb, 0))

    kv_spec = pl.BlockSpec(
        (1, 1, sk_arr, d),
        lambda bh, qb: (bh // h, (bh % h) // group, 0, 0))
    in_specs = []
    if n_ext:
        in_specs.append(full_spec(row_extents.shape))
    if n_tbl:
        in_specs.append(None)  # placeholder: shape known at call time
    in_specs += [q_spec(), kv_spec, kv_spec]
    if has_pos:
        in_specs.append(full_spec((b,)))

    interp = target.interpret
    extra = target.call_kwargs(num_warps, num_stages)

    def call(*args):
        specs = list(in_specs)
        if n_tbl:
            specs[n_ext] = full_spec(args[n_ext].shape)
        c = pl.pallas_call(
            kern, grid=(b * h, rows), in_specs=specs,
            out_specs=q_spec(),
            out_shape=jax.ShapeDtypeStruct(out_shape, dtype),
            interpret=interp, **extra)
        return c(*args)

    if n_ext:
        ext = jnp.asarray(row_extents)
        return lambda *args: call(ext, *args)
    return call


@functools.partial(jax.jit, static_argnames=(
    "kind", "window", "scale", "block_q", "block_k", "grid_mode",
    "storage", "kv_seq_len", "backend", "num_warps", "num_stages",
    "mesh", "shard_axis", "shard_balance", "verify"))
def _flash_impl(q, k, v, seq_pos=None, *, kind, window, scale, block_q,
                block_k, grid_mode, storage, kv_seq_len, backend,
                num_warps=None, num_stages=None, mesh=None,
                shard_axis="data", shard_balance="contiguous",
                verify=False):
    b, h, sq, d = q.shape
    _, hkv, sk_arr, _ = k.shape
    group = h // hkv
    target = backend
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    storage = normalize_storage(storage)
    sk = kv_seq_len if kv_seq_len is not None else sk_arr
    if kind == "local":
        # rectangular local (sq < sk) still needs square blocks: clamp
        # both to one value instead of letting min(.., sq) / min(.., sk)
        # diverge
        block_q = block_k = min(block_q, block_k, sq, sk)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError("sequence must be divisible by block size")
    m_q, m_k = sq // block_q, sk // block_k

    wb = 0
    if kind == "causal" and (sq != sk or block_q != block_k):
        raise ValueError("causal requires a square block grid")
    if kind == "local":
        if block_q != block_k or window % block_k:
            raise ValueError("local: need block_q == block_k | window")
        if (sk - sq) % block_k:
            raise ValueError("local: Sk - Sq must be block-aligned")
        wb = window // block_k + 1
    off = sk - sq if kind == "local" else 0
    has_pos = seq_pos is not None
    if has_pos and kind != "full":
        # a band row wholly beyond seq_pos would have start > end: no
        # step initializes the output on the sequential structure and
        # the gpu loop runs empty -- garbage, not a defined result.
        # Decode rides kind="full"; window= gives the run-time sliding
        # window anchored at seq_pos.
        raise ValueError(
            f"seq_pos requires kind='full' (got kind={kind!r}); pass "
            f"window= for a run-time sliding window anchored at "
            f"seq_pos")
    if has_pos and mesh is not None:
        raise ValueError(
            "seq_pos (decode) does not combine with the query-row mesh "
            "partition; shard the batch axis instead (see "
            "repro.models.attention.decode_attention_flash)")

    domain = make_attention_domain(kind, m_q, m_k, wb)
    zz_perm = None
    if mesh is not None:
        from repro.core.shard import ShardedPlan, zigzag_row_order
        D = int(mesh.shape[shard_axis])
        if m_q % D:
            raise ValueError(
                f"sharded flash needs the query-block grid divisible by "
                f"the mesh axis: m_q={m_q} blocks over {D} devices")
        partition = "rows"
        if shard_balance == "zigzag":
            if kind != "causal":
                raise ValueError(
                    "shard_balance='zigzag' balances the causal "
                    "triangle; contiguous bands already balance "
                    f"kind={kind!r}")
            if m_q % (2 * D):
                raise ValueError(
                    f"zigzag needs the query-block grid ({m_q}) "
                    f"divisible by 2 * mesh axis ({2 * D}) for an "
                    f"exactly balanced snake")
            if target.block_indexed and grid_mode in ("closed_form",
                                                      "compact"):
                # the snake's owned rows are scattered: the sequential
                # structure decodes them through the LUT (bit-identical
                # to the closed form by the engine's contract)
                grid_mode = "prefetch_lut"
            partition = "zigzag"
            zz_perm = zigzag_row_order(m_q, D)
        elif shard_balance != "contiguous":
            raise ValueError(
                f"unknown shard_balance {shard_balance!r}; expected "
                f"'contiguous' or 'zigzag'")
        plan = ShardedPlan(domain, grid_mode, batch_dims=(b * h,),
                           backend=target, mesh=mesh, axis=shard_axis,
                           partition=partition)
        out_shape = (b, h, sq // D, d)
    else:
        plan = GridPlan(domain, grid_mode, batch_dims=(b * h,),
                        backend=target)
        out_shape = q.shape
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(plan, kernel="flash")

    # compact KV: k/v hold only the key blocks in [s0, m_k)
    s0 = key_block_support(domain)[0] if storage == "compact" else 0
    if sk_arr != sk - s0 * block_k:
        raise ValueError(
            f"{storage} storage expects k/v of {sk - s0 * block_k} key "
            f"positions (support blocks [{s0}, {m_k}) of sk={sk}), got "
            f"{sk_arr}")

    pos_operand = ()
    if has_pos:
        # normalize to a per-batch-row (B,) vector: a scalar broadcasts
        # (back-compat), a vector carries one decode position per slot.
        sp = jnp.asarray(seq_pos, jnp.int32)
        if sp.ndim == 0 or sp.shape == (1,):
            sp = jnp.broadcast_to(sp.reshape(()), (b,))
        elif sp.shape != (b,):
            raise ValueError(
                f"seq_pos must be a scalar or a ({b},) per-row vector, "
                f"got shape {sp.shape}")
        pos_operand = (sp,)

    if not target.block_indexed:
        lowering = plan.lowering
        if lowering == "prefetch_lut":
            extents = plan.row_extents()
        elif lowering == "mma":
            from repro.core import mma
            extents = mma.row_extents_chain(domain)
        else:
            extents = None
        call = _gpu_flash_call(
            target=target, domain=domain, lowering=lowering, b=b, h=h,
            group=group, m_q=m_q, m_k=m_k, wb=wb, off=off,
            block_q=block_q, block_k=block_k, d=d, kind=kind,
            window=window, scale=scale, out_shape=out_shape,
            dtype=q.dtype, s0=s0, sk_arr=sk_arr, has_pos=has_pos,
            row_extents=extents, sharded=mesh is not None,
            rows_local=(m_q // int(mesh.shape[shard_axis])
                        if mesh is not None else None),
            zigzag=zz_perm is not None,
            num_shards=(int(mesh.shape[shard_axis])
                        if mesh is not None else 1),
            num_warps=num_warps, num_stages=num_stages)
        if mesh is None:
            return call(q, k, v, *pos_operand)
    else:
        def q_place(bx, by, bh):
            return (bh // h, bh % h, by, 0)

        def kv_place(bx, by, bh):
            kb = jnp.clip(bx - s0, 0, m_k - s0 - 1) if s0 else bx
            return (bh // h, (bh % h) // group, kb, 0)

        kernel = functools.partial(
            _attn_kernel, kind=kind, window=window, scale=scale,
            block_q=block_q, block_k=block_k, m_k=m_k, wb=wb, off=off,
            h=h, has_pos=has_pos)

        in_specs = [
            plan.block_spec((1, 1, block_q, d), q_place),
            plan.block_spec((1, 1, block_k, d), kv_place),
            plan.block_spec((1, 1, block_k, d), kv_place),
        ]
        if has_pos:
            in_specs.append(target.scalar_spec())
        call = plan.pallas_call(
            kernel,
            in_specs=in_specs,
            out_specs=plan.block_spec((1, 1, block_q, d), q_place),
            out_shape=jax.ShapeDtypeStruct(out_shape, q.dtype),
            scratch_shapes=[
                target.scratch((block_q, d), jnp.float32),
                target.scratch((block_q, 1), jnp.float32),
                target.scratch((block_q, 1), jnp.float32),
            ],
            num_warps=num_warps, num_stages=num_stages,
        )
        if mesh is None:
            return call(q, k, v, *pos_operand)

    # shard the query-block axis: q/o split along the sequence dim,
    # k/v replicated; each device runs its contiguous query-row band
    # (whole rows, so the online-softmax state never crosses devices).
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.shard import device_tables

    axis = shard_axis
    if target.block_indexed:
        tbl, luts = device_tables(plan)
    else:
        # gpu structure reads only the shard-table row in-kernel (the
        # prefetch_lut/mma extents table is bound inside the call), so
        # skip building/transferring the chunked decode LUT entirely
        tbl, luts = jnp.asarray(plan.shard_table_host()), ()
    qkv_specs = (P(None, None, axis, None), P(None, None, None, None),
                 P(None, None, None, None))

    def device_fn(tbl, luts, q, k, v):
        return call(tbl.reshape(-1), *luts, q, k, v)

    if zz_perm is not None:
        # shard_map splits contiguous chunks: gather the Q block rows
        # into device-concatenated snake order first, and scatter the
        # output back through the inverse permutation after.
        qr = q.reshape(b, h, m_q, block_q, d)
        q = qr[:, :, zz_perm].reshape(b, h, sq, d)
    out = shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis, None), tuple(P(axis, None) for _ in luts))
        + qkv_specs,
        out_specs=P(None, None, axis, None), check_rep=False)(
            tbl, luts, q, k, v)
    if zz_perm is not None:
        inv = np.argsort(zz_perm)
        out = out.reshape(b, h, m_q, block_q, d)[:, :, inv]
        out = out.reshape(b, h, sq, d)
    return out


def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    scale: float | None = None,
                    block_q: int | str = 128, block_k: int | str = 128,
                    grid_mode: str = "compact",
                    storage: str = "embedded",
                    kv_seq_len: int | None = None, seq_pos=None,
                    backend=None, num_warps: int | str | None = None,
                    num_stages: int | str | None = None,
                    interpret: bool | None = None, mesh=None,
                    shard_axis: str = "data",
                    shard_balance: str = "contiguous",
                    verify: bool = False):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) with Hkv | H.

    kind:      "causal" | "local" (window tokens) | "full"
    grid_mode: "closed_form" (alias "compact": the paper's block-space
               map) | "prefetch_lut" (scalar-prefetch table decode) |
               "bounding" (baseline full grid + run-time discard) |
               "mma" (digit-basis matmul decode on the matrix units;
               see :mod:`repro.core.mma`) |
               "auto" (resolve the tuned lowering -- and tuned block
               geometry, when block_q/block_k are left at "auto" --
               from the :mod:`~repro.core.tune` cache)
    storage:   "embedded" (k/v hold the full key sequence) | "compact"
               (k/v hold only the domain's key-block support, packed;
               see :func:`repro.core.compact.pack_kv`).  When the
               support is a strict suffix (rectangular local), pass the
               true key length as ``kv_seq_len``.
    seq_pos:   run-time int32 decode position -- a () scalar (every
               batch row at the same position) or a (B,) vector with
               one position per batch row (continuous batching;
               requires ``kind="full"``; combine with ``window=`` for
               a run-time sliding window): keys at ``kpos > seq_pos``
               are masked and key blocks beyond ``seq_pos // block_k``
               are predicated off (an SMEM vector on TPU, a regular
               operand on GPU).  The gpu structure's loop bound
               truncates the tile *reads* too; the TPU structure's
               static grid still pipelines every tile and skips only
               their compute.
    backend:   emission target ("tpu" | "gpu" | "*-interpret" | None =
               platform default; see :mod:`repro.core.backend`).  The
               gpu structure runs one program per query-block row with
               an in-kernel loop over its key extent; ``num_stages``
               >= 2 ("auto" = tuned) software-pipelines that loop (a
               KV-tile FIFO in the loop carry prefetches key block
               k+stages-1 while the softmax consumes block k;
               bit-identical to the synchronous loop) and, on a real
               GPU, also reaches the Triton scheduler together with
               ``num_warps``.  The TPU structure accepts the knob but
               keeps it at the grid level: Mosaic already
               double-buffers BlockSpec operand copies across the
               sequential grid.
    causal requires Sq == Sk; local accepts Sq < Sk with the decode
    convention (queries are the last Sq positions) when
    Sk - Sq >= window (full window per query block).

    ``mesh=`` shards the query-block axis of the block domain over
    ``shard_axis``: q and the output split along the sequence dim into
    contiguous query-row bands (one owner per row, so the online
    softmax never crosses devices and results are bit-identical); k/v
    stay replicated.  Requires Sq/block_q divisible by the axis size.

    ``shard_balance="zigzag"`` (causal only) replaces the contiguous
    bands with the snake assignment: device ``d`` owns query-block rows
    ``{j : min(j mod 2D, 2D-1-(j mod 2D)) == d}``, pairing light and
    heavy triangle rows so every device runs exactly the same number of
    key blocks (requires Sq/block_q divisible by 2D).  Q is permuted
    into snake order before the sharded launch and O inverse-permuted
    after, so results stay bit-identical to the contiguous split.
    """
    target = backend_lib.resolve(backend, interpret)
    from repro.core import tune

    from .sierpinski_write import resolve_auto_schedule
    b, h, sq, d = q.shape
    _, hkv, _, _ = k.shape
    sk = kv_seq_len if kv_seq_len is not None else k.shape[2]
    grid_mode, block_q, block_k, num_warps, num_stages = \
        resolve_auto_schedule(
            "flash",
            tune.target_params(
                tune.shard_params(
                    {"kind": kind, "batch": b, "heads": h,
                     "kv_heads": hkv, "sq": sq, "sk": sk, "d": d,
                     "window": window},
                    mesh, shard_axis),
                target),
            grid_mode=(grid_mode, "lowering", "closed_form"),
            block_q=(block_q, "block_q", 128),
            block_k=(block_k, "block_k", 128),
            num_warps=(num_warps, "num_warps", None),
            num_stages=(num_stages, "num_stages", None))
    return _flash_impl(q, k, v, seq_pos, kind=kind, window=window,
                       scale=scale, block_q=block_q, block_k=block_k,
                       grid_mode=grid_mode, storage=storage,
                       kv_seq_len=kv_seq_len, backend=target,
                       num_warps=num_warps, num_stages=num_stages,
                       mesh=mesh, shard_axis=shard_axis,
                       shard_balance=shard_balance, verify=verify)


# ---------------------------------------------------------------------------
# paged decode: the page table rides the scalar-prefetch LUT mechanism
# ---------------------------------------------------------------------------

def _paged_attn_kernel(coords, *refs, window, scale, page_size, h,
                       has_window):
    """Block-indexed (TPU) paged decode kernel.  One grid step per
    (slot*head, logical key block); the *physical* page was already
    resolved by the KV BlockSpec index map reading the prefetched page
    table, so the kernel sees a ``(1, 2, page_size, d)`` fused tile:
    row 0 of the head-pair axis is K, row 1 is V.  Masking uses the
    *logical* block id (``coords.bx``), so results are bit-identical to
    the contiguous ``seq_pos`` path."""
    q_ref, kv_ref, pos_ref, o_ref, acc_ref, m_ref, l_ref = refs
    kb = coords.bx
    pos = pos_ref[coords.batch[0] // h]
    start = 0 * kb
    end = pos // page_size
    if has_window:
        start = jnp.maximum(pos - window + 1, 0) // page_size

    def body():
        @pl.when(kb == start)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = kv_ref[0, 0].astype(jnp.float32)
        v = kv_ref[0, 1].astype(jnp.float32)
        acc_new, m_new, l_new = _attn_tile_update(
            q, k, v, acc_ref[...], m_ref[...], l_ref[...], kind="full",
            window=window if has_window else 0, qb=0 * kb, kb=kb,
            block_q=1, block_k=page_size, off=0, seq_pos=pos)
        acc_ref[...] = acc_new
        m_ref[...] = m_new
        l_ref[...] = l_new

        @pl.when(kb == end)
        def _():
            l = l_ref[...]
            l = jnp.where(l == 0, 1.0, l)
            o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)

    live = (kb <= end) & (kb >= start)
    if coords.valid is None:
        pl.when(live)(body)
    else:
        pl.when(coords.valid & live)(body)


def _gpu_paged_call(*, target, b, h, group, m_k, page_size, d, window,
                    scale, out_shape, dtype, num_warps=None,
                    num_stages=None):
    """gpu-structured paged decode: one program per (slot, head), the
    whole pool and page table as HBM operands, an in-kernel loop over
    the slot's logical key blocks that resolves each physical page with
    a table read and ``pl.load``\\ s the fused ``(2, page_size, d)``
    head tile at its offset.  The loop bound comes from the slot's
    ``seq_pos``, so only O(pos / page_size) pages are *read* -- the
    block-space work saving at run time."""

    def kern(pt_ref, q_ref, kv_ref, pos_ref, o_ref):
        bh = pl.program_id(0)
        slot = bh // h
        kvh = (bh % h) // group
        pos = pos_ref[slot]
        start = 0 * pos
        end = pos // page_size
        if window:
            start = jnp.maximum(pos - window + 1, 0) // page_size
        q = q_ref[0, 0].astype(jnp.float32) * scale

        def load_tiles(kb):
            page = pt_ref[slot, kb]
            t = pl.load(kv_ref, (pl.ds(page, 1), pl.ds(2 * kvh, 2),
                                 pl.ds(0, page_size), pl.ds(0, d)))
            t = t.reshape(2, page_size, d).astype(jnp.float32)
            return t[0], t[1]

        def step(j, carry):
            kb = start + j
            k_t, v_t = load_tiles(kb)
            return _attn_tile_update(
                q, k_t, v_t, *carry, kind="full", window=window,
                qb=0 * kb, kb=kb, block_q=1, block_k=page_size, off=0,
                seq_pos=pos)

        acc0 = (jnp.zeros((1, d), jnp.float32),
                jnp.full((1, 1), NEG_INF, jnp.float32),
                jnp.zeros((1, 1), jnp.float32))
        acc, _, l = jax.lax.fori_loop(0, end - start + 1, step, acc0)
        l = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0, ...] = (acc / l).astype(o_ref.dtype)

    q_spec = pl.BlockSpec((1, 1, 1, d), lambda bh: (bh // h, bh % h, 0, 0))
    extra = target.call_kwargs(num_warps, num_stages)

    def call(pt, q, kv_pool, pos):
        c = pl.pallas_call(
            kern, grid=(b * h,),
            in_specs=[full_spec(pt.shape), q_spec,
                      full_spec(kv_pool.shape), full_spec((b,))],
            out_specs=q_spec,
            out_shape=jax.ShapeDtypeStruct(out_shape, dtype),
            interpret=target.interpret, **extra)
        return c(pt, q, kv_pool, pos)

    return call


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "grid_mode", "backend", "num_warps",
    "num_stages", "verify"))
def _paged_impl(q, kv_pool, page_table, seq_pos, *, window, scale,
                grid_mode, backend, num_warps=None, num_stages=None,
                verify=False):
    from repro.core.paged import PagedPlan

    b, h, sq, d = q.shape
    if sq != 1:
        raise ValueError(f"paged decode is single-token: Sq={sq}")
    num_pages, h2, page_size, dp = kv_pool.shape
    if h2 % 2 or dp != d:
        raise ValueError(
            f"kv_pool must be (P, 2*Hkv, page_size, {d}), got "
            f"{kv_pool.shape}")
    hkv = h2 // 2
    group = h // hkv
    m_k = page_table.shape[1]
    if page_table.shape[0] != b:
        raise ValueError(
            f"page_table rows ({page_table.shape[0]}) != slots ({b})")
    target = backend
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    page_table = page_table.astype(jnp.int32)
    pos = jnp.broadcast_to(
        jnp.asarray(seq_pos, jnp.int32).reshape(-1), (b,))

    domain = make_attention_domain("full", 1, m_k, 0)
    if verify:
        from repro.analysis import verify_or_raise
        verify_or_raise(GridPlan(domain, grid_mode, batch_dims=(b * h,),
                                 backend=target), kernel="flash")

    if not target.block_indexed:
        call = _gpu_paged_call(
            target=target, b=b, h=h, group=group, m_k=m_k,
            page_size=page_size, d=d, window=window, scale=scale,
            out_shape=q.shape, dtype=q.dtype, num_warps=num_warps,
            num_stages=num_stages)
        return call(page_table, q, kv_pool, pos)

    plan = PagedPlan(domain, grid_mode, batch_dims=(b * h,),
                     backend=target, page_table=page_table)

    def q_place(bx, by, bh):
        return (bh // h, bh % h, 0, 0)

    def kv_index(grid_ids, refs):
        # refs[0] is the prefetched page table; the decoded bx is the
        # *logical* key block, translated here to its physical page.
        _, bx, _ = plan._decode(grid_ids, refs)
        bh = grid_ids[0]
        page = refs[0][bh // h, bx]
        return (page, (bh % h) // group, 0, 0)

    kernel = functools.partial(
        _paged_attn_kernel, window=window, scale=scale,
        page_size=page_size, h=h, has_window=bool(window))
    call = plan.pallas_call(
        kernel,
        in_specs=[
            plan.block_spec((1, 1, 1, d), q_place),
            plan._index_spec((1, 2, page_size, d), kv_index),
            target.scalar_spec(),
        ],
        out_specs=plan.block_spec((1, 1, 1, d), q_place),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            target.scratch((1, d), jnp.float32),
            target.scratch((1, 1), jnp.float32),
            target.scratch((1, 1), jnp.float32),
        ],
        num_warps=num_warps, num_stages=num_stages,
    )
    return call(q, kv_pool, pos)


def paged_flash_attention(q, kv_pool, page_table, seq_pos, *,
                          window: int = 0, scale: float | None = None,
                          grid_mode: str = "compact", backend=None,
                          num_warps: int | None = None,
                          num_stages: int | None = None,
                          interpret: bool | None = None,
                          verify: bool = False):
    """Paged single-token decode attention over a fused-KV page pool.

    q:          (B, H, 1, D) -- one query per serving slot.
    kv_pool:    (P, 2*Hkv, page_size, D) physical pages, K/V heads
                interleaved ``[K0, V0, K1, V1, ...]`` (see
                :mod:`repro.core.paged`); page 0 is the null page.
    page_table: (B, max_pages) i32 logical-block -> physical-page map
                per slot (null-page entries beyond each slot's length).
    seq_pos:    (B,) int32 per-slot decode positions (a scalar
                broadcasts).  Keys beyond a slot's position are masked;
                pages beyond ``pos // page_size`` are never touched on
                the gpu structure and compute-predicated off on the TPU
                structure.
    window:     optional run-time sliding window anchored at seq_pos.

    The page table travels exactly like the engine's decode LUT: a
    scalar-prefetch operand on block-indexed targets (resolved in the
    KV BlockSpec index map -- the lambda-map indirection of the paper,
    pointed at physical memory), a leading HBM operand read in-kernel
    on gpu structures.  Bit-identical to the contiguous
    ``flash_attention(..., kind="full", seq_pos=...)`` path with
    ``block_k == page_size`` when the mapped pages hold the same
    values."""
    target = backend_lib.resolve(backend, interpret)
    from repro.core.plan import normalize_lowering
    return _paged_impl(q, kv_pool, page_table, seq_pos, window=window,
                       scale=scale,
                       grid_mode=normalize_lowering(grid_mode),
                       backend=target, num_warps=num_warps,
                       num_stages=num_stages, verify=verify)
