"""Block-space flash attention: the paper's compact-grid technique applied
to the dominant kernel of the assigned LM architectures.

The (q_block, k_block) pairs of causal attention form a lower-triangular
block domain -- the 2-simplex case of the authors' block-space program
[Navarro et al. 2014/2016].  Instead of launching the bounding-box grid
``m_q x m_k`` and discarding invalid blocks at run time (the standard
masked-flash formulation), the compact grid launches exactly
``T(m) = m(m+1)/2`` (causal) or ``T(w) + (m-w)w`` (local window) steps
and decodes ``t -> (q_block, k_block)`` either in closed form (the
integer-sqrt inverse of the triangular enumeration -- the m=2 case of
the "order-m equation" map of related work [18]) or through the
scalar-prefetch lookup table, both emitted by the shared
:class:`~repro.core.plan.GridPlan` engine.  ``grid_mode`` selects the
lowering: ``closed_form`` (alias ``compact``) | ``prefetch_lut`` |
``bounding``.

Grid layout: ``(batch*heads, T)``; the compact enumerations are
row-major in q, so all k-steps of one q row are consecutive: the online
softmax state lives in VMEM scratch and the output block is written once
per row (standard flash revisiting pattern).  GQA folds the kv-head
index inside the BlockSpec index_map.

Compact KV (the ``storage=`` axis): ``kind="local"`` also accepts
``sq < sk`` with the decode convention (queries are the last sq
positions of the key sequence -- chunked prefill / decode against a long
cache).  The rectangular BandDomain then touches only the *last*
``sq + window`` key positions, and ``storage="compact"`` reads K/V
packed to exactly that support (the sliding-window KV-cache truncation:
O(window) cache instead of O(sk)); the kv BlockSpec index maps are
rewritten to packed slots.  For causal / full / square-local the column
support is all of sk, so compact and embedded KV coincide -- the packing
is the 1-D analogue of the fractal orthotope packing.

Forward only (training uses the custom-vjp jnp path in
``repro.models.attention``; this kernel is the serving/TPU fast path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compact import key_block_support
from repro.core.domain import make_attention_domain
from repro.core.plan import GridPlan, normalize_storage

NEG_INF = float(-1e30)  # avoid true -inf so exp() stays nan-free


def _row_bounds(kind, qb, m_k, wb, off_b):
    if kind == "causal":
        return 0 * qb, qb
    if kind == "local":
        return jnp.maximum(qb + off_b - (wb - 1), 0), qb + off_b
    return 0 * qb, qb * 0 + (m_k - 1)  # full


def _attn_kernel(coords, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                 *, kind, window, scale, block_q, block_k, m_k, wb, off):
    kb, qb = coords.bx, coords.by
    start, end = _row_bounds(kind, qb, m_k, wb, off // block_q)

    def body():
        @pl.when(kb == start)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        if kind in ("causal", "local"):
            # decode convention: query row qb covers embedded token
            # positions off + qb*block_q + [0, block_q)
            qpos = off + qb * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = kpos <= qpos
            if kind == "local":
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                 # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

        @pl.when(kb == end)
        def _():
            l = l_ref[...]
            l = jnp.where(l == 0, 1.0, l)
            o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)

    coords.when_valid(body)


@functools.partial(jax.jit, static_argnames=(
    "kind", "window", "scale", "block_q", "block_k", "grid_mode",
    "storage", "kv_seq_len", "interpret", "mesh", "shard_axis"))
def _flash_impl(q, k, v, *, kind, window, scale, block_q, block_k,
                grid_mode, storage, kv_seq_len, interpret, mesh=None,
                shard_axis="data"):
    b, h, sq, d = q.shape
    _, hkv, sk_arr, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    storage = normalize_storage(storage)
    sk = kv_seq_len if kv_seq_len is not None else sk_arr
    if kind == "local":
        # rectangular local (sq < sk) still needs square blocks: clamp
        # both to one value instead of letting min(.., sq) / min(.., sk)
        # diverge
        block_q = block_k = min(block_q, block_k, sq, sk)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError("sequence must be divisible by block size")
    m_q, m_k = sq // block_q, sk // block_k

    wb = 0
    if kind == "causal" and (sq != sk or block_q != block_k):
        raise ValueError("causal requires a square block grid")
    if kind == "local":
        if block_q != block_k or window % block_k:
            raise ValueError("local: need block_q == block_k | window")
        if (sk - sq) % block_k:
            raise ValueError("local: Sk - Sq must be block-aligned")
        wb = window // block_k + 1
    off = sk - sq if kind == "local" else 0

    domain = make_attention_domain(kind, m_q, m_k, wb)
    if mesh is not None:
        from repro.core.shard import ShardedPlan
        D = int(mesh.shape[shard_axis])
        if m_q % D:
            raise ValueError(
                f"sharded flash needs the query-block grid divisible by "
                f"the mesh axis: m_q={m_q} blocks over {D} devices")
        plan = ShardedPlan(domain, grid_mode, batch_dims=(b * h,),
                           mesh=mesh, axis=shard_axis, partition="rows")
        out_shape = (b, h, sq // D, d)
    else:
        plan = GridPlan(domain, grid_mode, batch_dims=(b * h,))
        out_shape = q.shape

    # compact KV: k/v hold only the key blocks in [s0, m_k)
    s0 = key_block_support(domain)[0] if storage == "compact" else 0
    if sk_arr != sk - s0 * block_k:
        raise ValueError(
            f"{storage} storage expects k/v of {sk - s0 * block_k} key "
            f"positions (support blocks [{s0}, {m_k}) of sk={sk}), got "
            f"{sk_arr}")

    def q_place(bx, by, bh):
        return (bh // h, bh % h, by, 0)

    def kv_place(bx, by, bh):
        kb = jnp.clip(bx - s0, 0, m_k - s0 - 1) if s0 else bx
        return (bh // h, (bh % h) // group, kb, 0)

    kernel = functools.partial(
        _attn_kernel, kind=kind, window=window, scale=scale,
        block_q=block_q, block_k=block_k, m_k=m_k, wb=wb, off=off)

    call = plan.pallas_call(
        kernel,
        in_specs=[
            plan.block_spec((1, 1, block_q, d), q_place),
            plan.block_spec((1, 1, block_k, d), kv_place),
            plan.block_spec((1, 1, block_k, d), kv_place),
        ],
        out_specs=plan.block_spec((1, 1, block_q, d), q_place),
        out_shape=jax.ShapeDtypeStruct(out_shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )
    if mesh is None:
        return call(q, k, v)

    # shard the query-block axis: q/o split along the sequence dim,
    # k/v replicated; each device runs its contiguous query-row band
    # (whole rows, so the online-softmax state never crosses devices).
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.shard import device_tables

    axis = shard_axis
    tbl, luts = device_tables(plan)
    qkv_specs = (P(None, None, axis, None), P(None, None, None, None),
                 P(None, None, None, None))

    def device_fn(tbl, luts, q, k, v):
        return call(tbl.reshape(-1), *luts, q, k, v)

    return shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis, None), tuple(P(axis, None) for _ in luts))
        + qkv_specs,
        out_specs=P(None, None, axis, None), check_rep=False)(
            tbl, luts, q, k, v)


def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    scale: float | None = None,
                    block_q: int | str = 128, block_k: int | str = 128,
                    grid_mode: str = "compact",
                    storage: str = "embedded",
                    kv_seq_len: int | None = None,
                    interpret: bool | None = None, mesh=None,
                    shard_axis: str = "data"):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) with Hkv | H.

    kind:      "causal" | "local" (window tokens) | "full"
    grid_mode: "closed_form" (alias "compact": the paper's block-space
               map) | "prefetch_lut" (scalar-prefetch table decode) |
               "bounding" (baseline full grid + run-time discard) |
               "auto" (resolve the tuned lowering -- and tuned block
               geometry, when block_q/block_k are left at "auto" --
               from the :mod:`~repro.core.tune` cache)
    storage:   "embedded" (k/v hold the full key sequence) | "compact"
               (k/v hold only the domain's key-block support, packed;
               see :func:`repro.core.compact.pack_kv`).  When the
               support is a strict suffix (rectangular local), pass the
               true key length as ``kv_seq_len``.
    causal requires Sq == Sk; local accepts Sq < Sk with the decode
    convention (queries are the last Sq positions) when
    Sk - Sq >= window (full window per query block).

    ``mesh=`` shards the query-block axis of the block domain over
    ``shard_axis``: q and the output split along the sequence dim into
    contiguous query-row bands (one owner per row, so the online
    softmax never crosses devices and results are bit-identical); k/v
    stay replicated.  Requires Sq/block_q divisible by the axis size.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.core import tune

    from .sierpinski_write import resolve_auto_schedule
    b, h, sq, d = q.shape
    _, hkv, _, _ = k.shape
    sk = kv_seq_len if kv_seq_len is not None else k.shape[2]
    grid_mode, block_q, block_k = resolve_auto_schedule(
        "flash",
        tune.shard_params(
            {"kind": kind, "batch": b, "heads": h, "kv_heads": hkv,
             "sq": sq, "sk": sk, "d": d, "window": window},
            mesh, shard_axis),
        grid_mode=(grid_mode, "lowering", "closed_form"),
        block_q=(block_q, "block_q", 128),
        block_k=(block_k, "block_k", 128))
    return _flash_impl(q, k, v, kind=kind, window=window, scale=scale,
                       block_q=block_q, block_k=block_k,
                       grid_mode=grid_mode, storage=storage,
                       kv_seq_len=kv_seq_len, interpret=interpret,
                       mesh=mesh, shard_axis=shard_axis)
