"""Block-space TPU mapping for embedded fractals (Navarro et al. 2017)."""
import jax

# Sharded and single-device runs must draw identical jax.random values
# from the same seed: with non-partitionable threefry (the default
# until jax 0.4.36), values depend on the output sharding, so a
# TP-sharded param init silently diverges from the single-device init.
# Set once at package import so every entry point (train, serve,
# benchmarks, tests) sees the same RNG stream.
jax.config.update("jax_threefry_partitionable", True)
