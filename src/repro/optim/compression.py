"""Gradient compression for the data-parallel all-reduce: int8 blockwise
quantization with error feedback, applied inside a shard_map over the DP
axes so the wire format (int8 + per-block f32 scales) is what crosses
the ICI/DCN links -- a ~4x reduction of the cross-pod gradient traffic.

The error-feedback residual keeps the quantization bias out of the
optimizer trajectory (Seide et al. 2014; Karimireddy et al. 2019).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization along the flattened array."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _size(shape):
    n = 1
    for s in shape:
        n *= int(s)
    return n


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:_size(shape)].reshape(shape)


def compress_roundtrip(x):
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.shape)


def compressed_psum_grads(grads, residual, axis_names):
    """Error-feedback compressed gradient mean over ``axis_names``.

    Must be called INSIDE shard_map where grads are per-device local
    values.  Returns (synced_grads, new_residual).
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s, gf.shape)
        new_r = gf - deq
        # the all-reduce moves int8-equivalent data; we psum the dequantized
        # value (XLA wire format); scales are tiny
        total = jax.lax.psum(deq, axis_names)
        n = 1
        for a in axis_names:
            # jax.lax.axis_size only exists in newer jax; psum(1, axis)
            # is the portable way to read a mapped axis size
            n *= jax.lax.psum(1, a)
        return (total / n).astype(g.dtype), new_r

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    synced = jax.tree.unflatten(tree, [o[0] for o in out])
    new_res = jax.tree.unflatten(tree, [o[1] for o in out])
    return synced, new_res


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
