from . import adamw, compression
from .adamw import AdamWConfig, apply_updates, init_state, schedule_lr
