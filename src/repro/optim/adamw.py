"""AdamW with decoupled weight decay, global-norm clipping, and optional
int8 gradient compression with error feedback (repro.optim.compression).

Pure-pytree implementation (no optax dependency): state = {m, v, count}
with m/v matching the parameter tree, so the parameter sharding specs
apply verbatim to the optimizer state (critical for fitting the big
archs: fully-sharded state is what makes 236B trainable on 256 chips).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"       # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"  # bfloat16 halves optimizer memory


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


_DECAY_EXEMPT = ("norm", "scale", "bias", "b_", "/bq", "/bk", "/bv",
                 "dt_bias", "A_log", "/D")


def _decay_mask(path_str: str) -> bool:
    return not any(t in path_str for t in _DECAY_EXEMPT)


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule_lr(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))

    bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(path, p, g, m, v):
        path_str = "/".join(str(getattr(k, "key", k)) for k in path)
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if _decay_mask(path_str):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
