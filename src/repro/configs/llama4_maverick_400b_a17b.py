"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8),
MoE every 2nd layer: 128 routed experts top-1 (d_ff_expert=8192) + 1
shared; dense layers d_ff=16384; vocab=202048; early-fusion multimodal
(text path here)  [hf:meta-llama/Llama-4-*].

Interleave step 2 matches the published 400B total / 17B active split
(128 experts every layer would be ~780B total).
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        moe=True, n_experts=128, top_k=1, n_shared_experts=1,
        d_ff_expert=8192, moe_period=2, moe_offset=1, d_ff=16384,
        capacity_factor=1.25, vocab_size=202048,
        attn_chunk=1024, flash_threshold=2048, logit_chunk=512,
        # 400B total: bf16 params + bf16 moments (f32 master caveat in
        # DESIGN.md SS6); FSDP over 'data' shards the expert weights.
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        n_experts=4, top_k=1, n_shared_experts=1, d_ff_expert=64,
        d_ff=128, vocab_size=512, capacity_factor=2.0,
        flash_threshold=4096, logit_chunk=0,
        dtype="float32", param_dtype="float32", remat=False)
