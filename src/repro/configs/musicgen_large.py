"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192,
vocab=2048 -- decoder-only over EnCodec tokens [arXiv:2306.05284].

Modality frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, S, d_model); the backbone is the
transformer only.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048, input_mode="embeddings",
        attn_chunk=1024, flash_threshold=2048,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, flash_threshold=4096,
        dtype="float32", param_dtype="float32", remat=False)
