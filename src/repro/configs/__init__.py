"""Architecture registry: the 10 assigned archs + quickstart.

Each ``<arch>.py`` exposes ``full()`` (the exact published config) and
``smoke()`` (reduced same-family config for CPU tests).  ``META`` holds
per-arch dry-run knobs: whether the arch is sub-quadratic (runs the
long_500k cell), whether expert/ffn weights need FSDP sharding to fit,
sequence-sharded activations, and train-time grad accumulation.
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

from repro.models import ModelConfig

ARCHS = [
    "falcon-mamba-7b",
    "gemma3-12b",
    "qwen1.5-32b",
    "qwen2.5-32b",
    "phi3-mini-3.8b",
    "deepseek-v2-236b",
    "llama4-maverick-400b-a17b",
    "musicgen-large",
    "zamba2-2.7b",
    "internvl2-26b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}
_MODULES["quickstart"] = "quickstart"

# input shapes assigned to the LM-family pool (seq_len x global_batch)
SHAPES = {
    "train_4k":    {"kind": "train",   "seq": 4096,   "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768,  "batch": 32},
    "decode_32k":  {"kind": "decode",  "seq": 32768,  "batch": 128},
    "long_500k":   {"kind": "decode",  "seq": 524288, "batch": 1},
}

# per-arch dry-run metadata
META: Dict[str, Dict] = {
    "falcon-mamba-7b":          {"subquadratic": True,  "fsdp": False,
                                 "seq_shard": True, "grad_accum": 4},
    "gemma3-12b":               {"subquadratic": True,  "fsdp": False,
                                 "seq_shard": True, "grad_accum": 4},
    "qwen1.5-32b":              {"subquadratic": False, "fsdp": False,
                                 "seq_shard": True, "grad_accum": 4},
    "qwen2.5-32b":              {"subquadratic": False, "fsdp": False,
                                 "seq_shard": True, "grad_accum": 4},
    "phi3-mini-3.8b":           {"subquadratic": False, "fsdp": False,
                                 "seq_shard": True, "grad_accum": 1},
    "deepseek-v2-236b":         {"subquadratic": False, "fsdp": True,
                                 "seq_shard": True, "grad_accum": 16,
                                 "moments": "bfloat16"},
    "llama4-maverick-400b-a17b": {"subquadratic": False, "fsdp": True,
                                  "seq_shard": True, "grad_accum": 8,
                                  "moments": "bfloat16"},
    "musicgen-large":           {"subquadratic": False, "fsdp": False,
                                 "seq_shard": True, "grad_accum": 4},
    "zamba2-2.7b":              {"subquadratic": True,  "fsdp": False,
                                 "seq_shard": True, "grad_accum": 4},
    "internvl2-26b":            {"subquadratic": False, "fsdp": False,
                                 "seq_shard": True, "grad_accum": 4},
    "quickstart":               {"subquadratic": False, "fsdp": False,
                                 "seq_shard": False, "grad_accum": 1},
}


def get_config(name: str, smoke: Optional[bool] = None) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke() if smoke else mod.full()


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honoring the long_500k skip rule
    for pure full-attention archs (see DESIGN.md SS5)."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            skipped = (s == "long_500k" and not META[a]["subquadratic"])
            if skipped and not include_skipped:
                continue
            out.append((a, s, skipped))
    return out
