"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32) d_ff=8192,
vocab=32064, RoPE + SwiGLU  [arXiv:2404.14219]."""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        attn_chunk=1024, flash_threshold=2048, logit_chunk=512,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, flash_threshold=4096, logit_chunk=0,
        dtype="float32", param_dtype="float32", remat=False)
