"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free Mamba-1,
vocab=65024, ssm_state=16  [arXiv:2410.05355]."""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, d_ff=0, vocab_size=65024,
        ssm_kind="mamba1", d_state=16, expand=2, conv_kernel=4,
        dt_rank=256, ssd_chunk=256,
        logit_chunk=512,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, vocab_size=512, dt_rank=8, ssd_chunk=16,
        dtype="float32", param_dtype="float32", remat=False, logit_chunk=0)
