"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512,
q_lora=1536, nope=128, rope=64, v=128), 2 shared + 160 routed experts
top-6 (d_ff_expert=1536), first layer dense (d_ff=12288), vocab=102400
[arXiv:2405.04434]."""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        moe=True, n_experts=160, top_k=6, n_shared_experts=2,
        d_ff_expert=1536, first_dense=1, d_ff=12288,
        capacity_factor=1.25, vocab_size=102400,
        attn_chunk=1024, flash_threshold=2048, logit_chunk=512,
        # 236B on 256 v5e chips: bf16 params + bf16 moments is what fits
        # (production would add a data-sharded f32 master copy; see
        # DESIGN.md SS6); FSDP over 'data' shards the expert weights.
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=3, d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, n_experts=8, top_k=2,
        n_shared_experts=1, d_ff_expert=32, d_ff=128, vocab_size=512,
        capacity_factor=2.0, flash_threshold=4096, logit_chunk=0,
        dtype="float32", param_dtype="float32", remat=False)
