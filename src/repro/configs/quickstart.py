"""quickstart: a ~100M dense LM for the end-to-end example driver."""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="quickstart", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=2048, vocab_size=32768,
        dtype="float32", param_dtype="float32",
        flash_threshold=4096, remat=False,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          d_ff=256, vocab_size=1024)
