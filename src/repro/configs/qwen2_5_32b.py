"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648,
vocab=152064, QKV bias  [hf:Qwen/Qwen2.5-*]."""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=27648, vocab_size=152064, qkv_bias=True,
        attn_chunk=1024, flash_threshold=2048, logit_chunk=512,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, flash_threshold=4096, logit_chunk=0,
        dtype="float32", param_dtype="float32", remat=False)
