"""zamba2-2.7b [hybrid]: 54L d_model=2560 Mamba-2 (ssm_state=64,
head_dim=64) + weight-shared attention blocks (32H, d_ff=10240) applied
every 6 layers, vocab=32000  [arXiv:2411.15242].

Simplifications noted in DESIGN.md: a single shared block (the released
model alternates two) and no LoRA adapters on the shared weights.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        ssm_kind="mamba2", d_state=64, expand=2, conv_kernel=4,
        ssd_head_dim=64, ssd_chunk=256, hybrid_attn_period=6,
        d_ff=10240, vocab_size=32000,
        attn_chunk=1024, flash_threshold=2048,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_state=16,
        ssd_head_dim=16, ssd_chunk=16, hybrid_attn_period=2, d_ff=128,
        vocab_size=512, flash_threshold=4096,
        dtype="float32", param_dtype="float32", remat=False)
