"""internvl2-26b [vlm]: InternLM2-style backbone, 48L d_model=6144 48H
(GQA kv=8) d_ff=16384, vocab=92553  [arXiv:2404.16821].

The InternViT frontend is a STUB per the assignment: ``input_specs``
supplies precomputed patch+text embeddings (B, S, d_model).
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92553, input_mode="embeddings",
        attn_chunk=1024, flash_threshold=2048, logit_chunk=512,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, flash_threshold=4096, logit_chunk=0,
        dtype="float32", param_dtype="float32", remat=False)
