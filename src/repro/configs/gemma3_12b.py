"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) head_dim=256,
d_ff=15360, vocab=262144, 5:1 local:global (window 1024)
[hf:google/gemma-3-*]."""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=15360, vocab_size=262144,
        attn_pattern=("local",) * 5 + ("global",), local_window=1024,
        rope_theta=1e6,
        attn_chunk=1024, flash_threshold=2048, logit_chunk=256,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, local_window=8, attn_chunk=8,
        flash_threshold=4096, logit_chunk=0,
        dtype="float32", param_dtype="float32", remat=False)
