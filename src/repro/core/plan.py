"""GridPlan: the unified block-space execution engine.

A ``GridPlan`` binds a :class:`~repro.core.domain.BlockDomain` (the
paper's compact parallel space and its lambda map) to one of three
*lowering strategies* and emits everything a Pallas kernel needs to run
over that domain:

* ``grid``        -- the launch grid (optionally with leading batch dims),
* ``index_map``   -- per-operand ``BlockSpec`` index maps built from one
                     shared decode of the grid step -> (bx, by),
* ``kernel coords`` -- the in-kernel ``(bx, by, valid)`` accessor,
* ``pallas_call`` -- a ``pl.pallas_call`` wrapper that hides the
                     lowering-specific grid-spec plumbing.

Lowerings
---------

``closed_form``
    The paper's per-block map: the grid has ``domain.num_blocks`` steps
    and each ``index_map`` evaluates ``domain.block_coords(t)`` inline
    (straight-line scalar math, unrolled at trace time).  The decode is
    defined once on the plan and shared by every operand's index map, so
    XLA/Mosaic CSE sees one digit-unrolling chain, not one per operand.

``prefetch_lut``
    The lookup-table realization (Navarro et al., "Efficient GPU Thread
    Mapping on Embedded 2D Fractals"): the host ``coords_host()`` table
    makes each decode an O(1) table read instead of the O(r) digit
    unrolling / integer-sqrt chain.  How the table travels is the
    backend's business (:mod:`repro.core.backend`): scalar prefetch on
    TPU, a regular HBM operand read at ``pl.program_id`` on GPU.
    Bit-identical to ``closed_form`` by construction: the table *is*
    the closed form, evaluated on host.

``bounding``
    The paper's baseline: launch the full bounding-box grid and discard
    non-member blocks at run time via ``domain.contains``.

``"compact"`` is accepted as a backward-compatible alias of
``closed_form`` (the name the kernels used before this engine existed).

Kernels written against a plan receive a :class:`BlockCoords` as their
first argument and are lowering-agnostic; any registered domain works in
any kernel under any lowering.

Superblock coarsening (``coarsen=s``)
-------------------------------------

``GridPlan(domain, ..., coarsen=s)`` makes each grid step own an s x s
embedded tile of fine blocks (s a power of the fractal's subdivision
factor): the grid enumerates the *coarse* domain (the same fractal at
level ``r - log_m s``), so the lambda decode runs once per superblock
and is amortized over its ``k**j`` member blocks.  ``storage_spec`` /
``neighbor_spec`` then emit supertile-sized BlockSpecs: a contiguous
(s*block)^2 region under embedded storage, or the contiguous
``k**ceil(j/2) x k**floor(j/2)`` fine-slot sub-rectangle of the packed
orthotope under compact storage (see
:class:`~repro.core.compact.SuperTiling`).  ``tile_map()`` /
``cell_offset_grids()`` give kernels the static packed<->embedded
fine-block permutation of one supertile.  See README "Scheduling".
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import backend as backend_lib
from . import fractal as F
from . import memo
from .domain import (BandDomain, BlockDomain, BoundingBoxDomain,
                     GeneralizedFractalDomain, SierpinskiDomain,
                     TriangularDomain)

LOWERINGS = ("closed_form", "prefetch_lut", "bounding", "mma")
_ALIASES = {"compact": "closed_form"}

STORAGES = ("embedded", "compact")

#: LUT column layout under ``storage="compact"``: the embedded (coarse)
#: block coords, the block's own packed slot / supertile index, then per
#: N/S/W/E/NW/NE/SW/SE neighbour (NEIGHBOR_OFFSETS8 order) the
#: (sx, sy, valid) triple -- 2 + 2 + 8*3 = 28 i32 columns.
_LUT_BX, _LUT_BY, _LUT_SX, _LUT_SY, _LUT_NBR = 0, 1, 2, 3, 4
_LUT_COLS = 28


def normalize_lowering(name: str) -> str:
    """Map user-facing lowering names (incl. legacy aliases) to canonical."""
    name = _ALIASES.get(name, name)
    if name not in LOWERINGS:
        raise ValueError(
            f"unknown lowering {name!r}; expected one of {LOWERINGS} "
            f"or aliases {tuple(_ALIASES)}")
    return name


def normalize_storage(name: str) -> str:
    if name not in STORAGES:
        raise ValueError(
            f"unknown storage {name!r}; expected one of {STORAGES}")
    return name


def xla_schedule(lowering: str) -> str:
    """The XLA-level flash-attention schedule equivalent to a lowering.

    ``closed_form``/``prefetch_lut``/``mma`` only launch member blocks
    -- the XLA mirror is the ``triangular`` (compact) schedule;
    ``bounding`` mirrors the ``dense`` masked schedule."""
    return "dense" if normalize_lowering(lowering) == "bounding" else \
        "triangular"


class BlockCoords:
    """In-kernel view of the current block: embedded coords + validity.

    ``bx``/``by``   -- embedded block coordinates (traced i32 scalars).
    ``batch``       -- tuple of leading batch-grid indices.
    ``valid``       -- membership predicate, or ``None`` when the plan
                       only enumerates member blocks (compact lowerings)
                       so no run-time discard is needed.
    ``first_step``  -- predicate for "is this the first grid step",
                       usable for one-time init of revisited outputs.
    ``grid_ids``    -- the raw grid indices of this step.
    ``refs``        -- the plan's decode-table refs, in operand order
                       (scalar-prefetch refs on TPU, leading HBM
                       operand refs on GPU).  gpu-structured kernels
                       pass these back into ``plan.storage_index`` /
                       ``plan.neighbor_index`` to address state tiles
                       themselves.
    """

    __slots__ = ("batch", "bx", "by", "valid", "first_step", "grid_ids",
                 "refs")

    def __init__(self, batch, bx, by, valid, first_step, grid_ids=(),
                 refs=()):
        self.batch = tuple(batch)
        self.bx = bx
        self.by = by
        self.valid = valid
        self.first_step = first_step
        self.grid_ids = tuple(grid_ids)
        self.refs = tuple(refs)

    def when_valid(self, body: Callable[[], None]) -> None:
        """Run ``body`` for member blocks only (no-op guard when the
        lowering already guarantees membership)."""
        if self.valid is None:
            body()
        else:
            pl.when(self.valid)(body)


class GridPlan:
    """Execution plan for one kernel launch over a block domain.

    Parameters
    ----------
    domain:      the block domain to enumerate.
    lowering:    "closed_form" | "prefetch_lut" | "bounding" | "mma"
                 (or the legacy alias "compact").  "mma" computes the
                 lambda decode as mixed-precision ``dot_general``
                 digit-basis chains (see :mod:`repro.core.mma`): on
                 block-indexed targets the chain output is bound as the
                 scalar-prefetch table, on gpu structures the chains
                 run in-kernel per program.
    batch_dims:  leading grid dimensions iterated outside the domain
                 (e.g. ``(batch * heads,)`` for attention).
    storage:     "embedded" (state arrays are the dense bounding-box
                 layout) or "compact" (state arrays live in the packed
                 O(n^H) orthotope layout of
                 :class:`~repro.core.compact.CompactLayout`; the
                 storage-array index maps emitted by ``storage_spec`` /
                 ``neighbor_spec`` address packed slots instead of
                 embedded block coords).
    coarsen:     s >= 1 embedded fine blocks per superblock side; s > 1
                 requires a fractal domain with s a power of its
                 subdivision factor.  The grid then enumerates the
                 coarse domain and every storage/neighbour spec covers
                 an s x s tile of fine blocks (the decode amortization
                 of Quezada et al.'s coarsening, on the block level).
    backend:     a :class:`~repro.core.backend.BackendTarget` (or its
                 name, or None = platform default): the emission
                 structure every ``pallas_call`` of this plan uses --
                 "tpu" (Mosaic scalar-prefetch), "gpu" (Triton,
                 in-kernel HBM addressing), or either "-interpret"
                 variant.
    """

    def __init__(self, domain: BlockDomain, lowering: str = "closed_form",
                 batch_dims: Sequence[int] = (), storage: str = "embedded",
                 coarsen: int = 1, backend=None):
        self.domain = domain
        self.lowering = normalize_lowering(lowering)
        self.batch_dims = tuple(int(d) for d in batch_dims)
        self.storage = normalize_storage(storage)
        self.target = backend_lib.resolve(backend)
        self.coarsen = int(coarsen)
        if self.coarsen < 1:
            raise ValueError(f"coarsen must be >= 1, got {coarsen}")
        if self.coarsen == 1:
            self._tiling = None
            #: the domain the *grid* enumerates (coarse under coarsening)
            self.sched_domain: BlockDomain = domain
        else:
            from .compact import super_tiling
            self._tiling = super_tiling(domain, self.coarsen)
            self.sched_domain = self._tiling.coarse
        self._layout = None

    @property
    def layout(self):
        """The domain's :class:`CompactLayout` (memoized per domain;
        available under either storage so callers can pack/unpack)."""
        if self._layout is None:
            from .compact import compact_layout
            self._layout = compact_layout(self.domain)
        return self._layout

    # -- grid ---------------------------------------------------------------

    @property
    def domain_dims(self) -> int:
        """How many trailing grid dimensions the domain occupies."""
        return 2 if self.lowering == "bounding" else 1

    @property
    def grid(self) -> Tuple[int, ...]:
        if self.lowering == "bounding":
            nbx, nby = self.sched_domain.bounding_box
            return self.batch_dims + (nby, nbx)
        return self.batch_dims + (self.sched_domain.num_blocks,)

    @property
    def num_steps(self) -> int:
        return int(np.prod(self.grid))

    # -- scalar-prefetch table ---------------------------------------------

    @property
    def _table_backed(self) -> bool:
        """Whether this plan's decode rides a bound table ref.

        ``prefetch_lut`` always does.  ``mma`` does only on
        block-indexed (TPU-structured) targets: Mosaic index maps
        cannot run ``dot_general``, so the chain output is bound as a
        scalar-prefetch table and read like a LUT; the gpu structure
        runs the chains in-kernel per program instead."""
        return self.lowering == "prefetch_lut" or (
            self.lowering == "mma" and self.target.block_indexed)

    @property
    def num_scalar_prefetch(self) -> int:
        return 1 if self._table_backed else 0

    def bound_prefetch(self):
        """The scalar-prefetch operands ``pallas_call`` binds itself, or
        ``None`` when the caller must supply them per call (the sharded
        planner: its tables are per-device shard_map operands, not trace
        constants)."""
        if not self.num_scalar_prefetch:
            return ()
        return (self.mma_table() if self.lowering == "mma"
                else self.lut(),)

    @staticmethod
    def _split_im_args(args, nsp: int):
        """Split an index_map's ``(*grid_ids, *prefetch_refs)`` arg list."""
        if nsp == 0:
            return tuple(args), ()
        return tuple(args[:-nsp]), tuple(args[-nsp:])

    def lut(self) -> jnp.ndarray:
        return jnp.asarray(self.lut_host())

    def lut_host(self) -> np.ndarray:
        """Host-built i32 decode table, one row per scheduled (member /
        coarse) block, memoized per (domain, storage, coarsen).

        embedded storage: (num_blocks, 2) of (bx, by).
        compact storage:  (num_blocks, 28): (bx, by, sx, sy) plus the
        eight (sx, sy, valid) neighbour-slot triples (NEIGHBOR_OFFSETS8
        order), so every compact address resolve -- including the 8-way
        CA halo gathers -- is an O(1) scalar-memory read.  Under
        ``coarsen`` the rows are coarse blocks and the slot columns are
        supertile indices (the rows widen per superblock, never per
        fine block: that is the amortization)."""
        return memo.cached("gridplan-lut", self.domain,
                           (self.storage, self.coarsen), self._lut_host)

    def _lut_host(self) -> np.ndarray:
        coords = self.sched_domain.coords_host()
        if self.storage == "embedded":
            return np.asarray(coords, np.int32)
        if self._tiling is not None:
            slots = self._tiling.tiles_host()
            nbrs = self._tiling.neighbor_tiles_host()
        else:
            slots = self.layout.slots_host()
            nbrs = self.layout.neighbor_slots_host()
        nbrs = nbrs.reshape(len(coords), 24)
        table = np.concatenate([coords, slots, nbrs],
                               axis=1).astype(np.int32)
        assert table.shape[1] == _LUT_COLS
        table.setflags(write=False)
        return table

    def mma_table(self) -> jnp.ndarray:
        """Decode table of the ``mma`` lowering -- the same row/column
        layout as :meth:`lut_host`, but every lambda / lambda^-1 entry
        is a :mod:`repro.core.mma` digit-basis ``dot_general`` chain
        instead of a host integer loop.  On block-indexed targets this
        is the bound scalar-prefetch operand (index maps read it like a
        LUT); the verifier re-derives it from ``linear_index`` ground
        truth, so a corrupted digit-basis matrix surfaces as table
        findings.  The memoized build runs the chains eagerly
        (``ensure_compile_time_eval``) so a first call inside a jit
        trace cannot cache tracers."""
        return jnp.asarray(self.mma_table_host())

    def mma_table_host(self) -> np.ndarray:
        """Host numpy copy of :meth:`mma_table` -- what the verifier
        re-derives against (it runs inside kernel jit traces, where the
        device array would be a tracer)."""
        return memo.cached("gridplan-mma-table", self.domain,
                           (self.storage, self.coarsen), self._mma_table)

    def _mma_table(self) -> np.ndarray:
        import jax

        with jax.ensure_compile_time_eval():
            table = np.asarray(self._mma_table_chains())
        table.setflags(write=False)
        return table

    def _mma_table_chains(self) -> jnp.ndarray:
        from . import mma
        from .compact import NEIGHBOR_OFFSETS8
        dom = self.sched_domain
        t = jnp.arange(dom.num_blocks, dtype=jnp.int32)
        frac = mma.fractal_of(dom)
        if frac is not None:
            spec, r = frac
            bx, by = mma.decode_linear(spec, r, t)
        else:
            bx, by = mma.decode_rows(dom, t)
        if self.storage == "embedded":
            return jnp.stack([bx, by], axis=-1).astype(jnp.int32)
        swap = self._tiling is not None and self._tiling.j % 2 == 1
        if frac is not None:
            sx, sy = mma.slots_of_linear(spec, r, t, swap=swap)
        else:
            # generic near-square layouts have no lambda to accelerate:
            # slots stay the integer row-major reshape of t.
            sx, sy = self.layout.slot(bx, by)
        cols = [bx, by, sx, sy]
        for dx, dy in NEIGHBOR_OFFSETS8:
            if frac is not None:
                nsx, nsy, ok = mma.neighbor_slots(
                    spec, r, dom, bx, by, dx, dy, swap=swap)
            else:
                nsx, nsy, ok = self.layout.neighbor_slot(bx, by, dx, dy)
            cols += [nsx, nsy, ok.astype(jnp.int32)]
        table = jnp.stack(cols, axis=-1).astype(jnp.int32)
        assert table.shape[1] == _LUT_COLS
        return table

    # -- the one shared decode ---------------------------------------------

    def _lut_row0(self) -> Optional[np.ndarray]:
        """Host copy of LUT row 0, when it is a trace constant (it is
        not for sharded plans: each device's table chunk starts at a
        different row, and the chunks are shard_map operands)."""
        return self.lut_host()[0]

    def _lut_read(self, lut_ref, t, col: int):
        """One LUT element read.  When ``t`` is a *static* step id --
        the DMA-pipeline prologues, which address steps 0..stages-2
        before the grid runs -- row 0 is host-known and folds to an
        immediate, so the first copies issue without waiting on the
        table load (the first-iteration LUT stall).  Traced steps read
        the table directly: a select would compute the same value but
        perturb XLA fusion, and the lowerings are contractually
        bit-identical."""
        if isinstance(t, (int, np.integer)) and int(t) == 0:
            row0 = self._lut_row0()
            if row0 is not None:
                return np.int32(row0[col])
        return lut_ref[t, col]

    def _decode(self, grid_ids, prefetch_refs=()):
        """grid step -> (batch_ids, bx, by) in the *scheduled* (coarse)
        block space.  Shared by every operand's index map and by the
        kernel prologue.  ``prefetch_refs`` holds the scalar-prefetch
        refs in operand order (the LUT is the last one here; the sharded
        planner prepends its per-device shard table)."""
        nb = len(self.batch_dims)
        batch = tuple(grid_ids[:nb])
        if self.lowering == "bounding":
            by, bx = grid_ids[nb], grid_ids[nb + 1]
        elif self._table_backed:  # prefetch_lut, or mma on TPU structures
            t = grid_ids[nb]
            lut_ref = prefetch_refs[-1]
            bx = self._lut_read(lut_ref, t, _LUT_BX)
            by = self._lut_read(lut_ref, t, _LUT_BY)
        elif self.lowering == "mma":  # gpu structure: chains in-kernel
            bx, by = self._mma_decode(grid_ids[nb])
        else:  # closed_form
            bx, by = self.sched_domain.block_coords(grid_ids[nb])
        return batch, bx, by

    def _mma_decode(self, t):
        """Linear step -> scheduled (bx, by) via the digit-basis matmul
        chains (fractal domains) or the row-comparison chain (generic
        row-major domains)."""
        from . import mma
        frac = mma.fractal_of(self.sched_domain)
        if frac is not None:
            return mma.decode_linear(frac[0], frac[1], t)
        return mma.decode_rows(self.sched_domain, t)

    def _place_coords(self, bx, by, prefetch_refs=()):
        """The (bx, by) an operand's ``place`` callback receives; the
        sharded planner localizes the row coordinate here."""
        return bx, by

    # -- per-operand index maps --------------------------------------------

    def index_map(self, place: Callable) -> Callable:
        """Build one operand's ``BlockSpec`` index map.

        ``place(bx, by, *batch_ids)`` returns the operand's block index
        tuple; the plan supplies the decoded coordinates with the arity
        and extra scalar-ref arguments each lowering requires."""
        nsp = self.num_scalar_prefetch

        def im(*args):
            grid_ids, refs = self._split_im_args(args, nsp)
            batch, bx, by = self._decode(grid_ids, refs)
            bx, by = self._place_coords(bx, by, refs)
            return place(bx, by, *batch)
        return im

    def block_spec(self, block_shape, place: Callable) -> pl.BlockSpec:
        return pl.BlockSpec(block_shape, self.index_map(place))

    # -- storage-array specs (embedded vs compact addressing) ---------------

    def supertile_shape(self, block_shape) -> Tuple[int, int]:
        """Cell shape of one storage supertile for fine ``block_shape``
        tiles: (s*b0, s*b1) embedded, (bh*b0, bw*b1) packed."""
        b0, b1 = block_shape
        if self.storage == "embedded" or self._tiling is None:
            return (self.coarsen * b0, self.coarsen * b1)
        bw, bh = self._tiling.sub_shape
        return (bh * b0, bw * b1)

    def tile_map(self):
        """Static packed->embedded fine-block permutation of one storage
        supertile as ``((oy, ox), (ey, ex))`` pairs, or ``None`` when
        the supertile is already embedded-arranged (embedded storage, or
        coarsen=1 where the tile is a single block)."""
        if self.storage == "embedded" or self._tiling is None:
            return None
        return self._tiling.tile_map()

    def cell_offset_grids(self, block: int):
        """(OY, OX) host i32 arrays shaped like the storage supertile:
        the embedded cell offset of every supertile cell relative to the
        superblock's embedded origin ``(by*s*block, bx*s*block)``.  For
        the trivial layouts this is a plain meshgrid; under compact
        coarsening it bakes the fine-block permutation in, so kernels
        evaluate membership masks directly on the packed arrangement."""
        tm = self.tile_map()
        if tm is None:
            h, w = self.supertile_shape((block, block))
            oy, ox = np.mgrid[0:h, 0:w]
            return oy.astype(np.int32), ox.astype(np.int32)
        h, w = self.supertile_shape((block, block))
        oy = np.zeros((h, w), np.int32)
        ox = np.zeros((h, w), np.int32)
        cy, cx = np.mgrid[0:block, 0:block]
        for (py, px), (ey, ex) in tm:
            oy[py * block:(py + 1) * block,
               px * block:(px + 1) * block] = ey * block + cy
            ox[py * block:(py + 1) * block,
               px * block:(px + 1) * block] = ex * block + cx
        return oy, ox

    def storage_index(self, grid_ids, refs=()):
        """(row, col) tile index of the state-array operand for one
        grid step: embedded -> the (super)block's (by, bx) in the
        bounding-box array; compact -> the packed slot (sy, sx) of the
        layout (the supertile sub-rectangle index under coarsening).
        Under ``prefetch_lut`` the slot is read from the extended LUT;
        the other lowerings evaluate ``layout.slot`` (lambda^-1)
        inline.  Shared by the ``BlockSpec`` index maps (TPU, where
        ``refs`` are scalar-prefetch refs) and the gpu-structured
        kernel bodies (where ``refs`` are the leading HBM operand refs
        and the returned index drives ``pl.load``/``pl.store``)."""
        if self.storage == "embedded":
            _, bx, by = self._decode(grid_ids, refs)
            bx, by = self._place_coords(bx, by, refs)
            return by, bx
        if self._table_backed:
            t = grid_ids[len(self.batch_dims)]
            lut_ref = refs[-1]
            return (self._lut_read(lut_ref, t, _LUT_SY),
                    self._lut_read(lut_ref, t, _LUT_SX))
        if self.lowering == "mma":
            from . import mma
            frac = mma.fractal_of(self.sched_domain)
            if frac is not None:
                # the compact enumeration is lambda-linear: the own slot
                # comes straight from the step id, one digit contraction
                swap = self._tiling is not None and self._tiling.j % 2
                sx, sy = mma.slots_of_linear(
                    frac[0], frac[1], grid_ids[len(self.batch_dims)],
                    swap=bool(swap))
                return sy, sx
        _, bx, by = self._decode(grid_ids, refs)
        if self._tiling is not None:
            tx, ty = self._tiling.tile_index(bx, by)
            return ty, tx
        sx, sy = self.layout.slot(bx, by)
        return sy, sx

    def neighbor_index(self, j: int, grid_ids, refs=()):
        """(row, col) tile index of the j-th halo operand
        (``compact.NEIGHBOR_OFFSETS8`` order, j in [0, 8): N/S/W/E then
        the corners): the embedded neighbour (super)block clamped into
        range, or -- under compact storage -- its lambda^-1-resolved
        packed slot (slot (0, 0) for out-of-range / non-member
        neighbours; the kernel masks those contributions)."""
        from .compact import NEIGHBOR_OFFSETS8
        dx, dy = NEIGHBOR_OFFSETS8[j]
        if self.storage == "embedded":
            nbx, nby = self.sched_domain.bounding_box
            _, bx, by = self._decode(grid_ids, refs)
            bx, by = self._place_coords(bx, by, refs)
            return (jnp.clip(by + dy, 0, nby - 1),
                    jnp.clip(bx + dx, 0, nbx - 1))
        if self._table_backed:
            t = grid_ids[len(self.batch_dims)]
            lut_ref = refs[-1]
            return (self._lut_read(lut_ref, t, _LUT_NBR + 3 * j + 1),
                    self._lut_read(lut_ref, t, _LUT_NBR + 3 * j))
        _, bx, by = self._decode(grid_ids, refs)
        if self.lowering == "mma":
            from . import mma
            frac = mma.fractal_of(self.sched_domain)
            if frac is not None:
                swap = self._tiling is not None and self._tiling.j % 2
                sx, sy, _ok = mma.neighbor_slots(
                    frac[0], frac[1], self.sched_domain, bx, by, dx, dy,
                    swap=bool(swap))
                return sy, sx
        if self._tiling is not None:
            tx, ty, _ok = self._tiling.neighbor_tile(bx, by, dx, dy)
            return ty, tx
        sx, sy, _ok = self.layout.neighbor_slot(bx, by, dx, dy)
        return sy, sx

    def _index_spec(self, tile, index_fn) -> pl.BlockSpec:
        """Wrap an ``(grid_ids, refs) -> block index`` function as a
        BlockSpec with this plan's index-map arity."""
        nsp = self.num_scalar_prefetch

        def im(*args):
            grid_ids, refs = self._split_im_args(args, nsp)
            return index_fn(grid_ids, refs)
        return pl.BlockSpec(tile, im)

    def storage_spec(self, block_shape) -> pl.BlockSpec:
        """BlockSpec for a 2-D state-array operand under this plan's
        storage (see :meth:`storage_index`).  ``block_shape`` is the
        *fine* block shape; the emitted spec's block is the
        supertile."""
        return self._index_spec(self.supertile_shape(block_shape),
                                self.storage_index)

    def neighbor_spec(self, block_shape, j: int) -> pl.BlockSpec:
        """BlockSpec for the j-th halo operand (see
        :meth:`neighbor_index`)."""
        return self._index_spec(
            self.supertile_shape(block_shape),
            lambda grid_ids, refs: self.neighbor_index(j, grid_ids, refs))

    # -- in-kernel accessor -------------------------------------------------

    def kernel_coords(self, *prefetch_refs) -> BlockCoords:
        grid_ids = tuple(pl.program_id(i) for i in range(len(self.grid)))
        batch, bx, by = self._decode(grid_ids, prefetch_refs)
        valid = self._step_valid(grid_ids, bx, by, prefetch_refs)
        first = grid_ids[0] == 0
        for g in grid_ids[1:]:
            first = first & (g == 0)
        return BlockCoords(batch, bx, by, valid, first, grid_ids,
                           prefetch_refs)

    def _step_valid(self, grid_ids, bx, by, prefetch_refs=()):
        """The membership/ownership predicate of one grid step (``None``
        when every step is live)."""
        if self.lowering == "bounding" and not getattr(
                self.sched_domain, "always_member", False):
            return self.sched_domain.contains(bx, by)
        return None

    # -- pallas_call wrapper ------------------------------------------------

    def pallas_call(self, kernel: Callable, *, in_specs, out_specs,
                    out_shape, scratch_shapes=(),
                    input_output_aliases: Optional[dict] = None,
                    interpret: Optional[bool] = None,
                    **kwargs) -> Callable:
        """Emit the ``pl.pallas_call`` for this plan on its
        :class:`~repro.core.backend.BackendTarget` (see
        :func:`repro.core.backend.emit`, which owns all grid-spec
        construction).  ``kernel(coords, *refs)`` is lowering- and
        backend-agnostic at the signature level; gpu-structured kernels
        additionally address state through ``coords.grid_ids`` /
        ``coords.refs`` and :meth:`storage_index` /
        :meth:`neighbor_index`.  ``interpret=None`` defers to the
        target's interpret flag (an explicit bool overrides, for
        tests)."""
        return backend_lib.emit(
            self, kernel, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape, scratch_shapes=scratch_shapes,
            input_output_aliases=input_output_aliases,
            interpret=interpret, **kwargs)

    # -- grid-step helpers for gpu-structured kernels ------------------------

    @property
    def steps_per_launch(self) -> int:
        """Grid steps per batch element (the domain grid volume): the
        partial-result axis gpu-structured reductions emit, one slot
        per step, before the deterministic host-side combine."""
        nb = len(self.batch_dims)
        out = 1
        for d in self.grid[nb:]:
            out *= int(d)
        return out

    def linear_step(self, grid_ids):
        """Flatten the (possibly 2-D, under ``bounding``) domain grid
        indices of one step to a linear step id in
        [0, steps_per_launch)."""
        nb = len(self.batch_dims)
        if self.lowering == "bounding":
            nbx = int(self.grid[nb + 1])
            return grid_ids[nb] * nbx + grid_ids[nb + 1]
        return grid_ids[nb]

    def grid_ids_at(self, lin, batch=()):
        """Inverse of :meth:`linear_step`: the full grid-index tuple of
        linear domain step ``lin`` under the given batch ids.  ``lin``
        may be traced (pipelined kernels addressing step t+s ahead of
        the grid) or a Python int (launch prologues, where static step
        ids let the decode constant-fold)."""
        batch = tuple(batch)
        if len(batch) != len(self.batch_dims):
            raise ValueError(
                f"expected {len(self.batch_dims)} batch ids, "
                f"got {len(batch)}")
        if self.lowering == "bounding":
            nbx = int(self.grid[len(batch) + 1])
            return batch + (lin // nbx, lin % nbx)
        return batch + (lin,)

    # -- host-side geometry helpers ----------------------------------------

    def row_extents(self) -> np.ndarray:
        """(nby, 2) i32 host array of [min_bx, max_bx] per block row.

        Rows with no member blocks get [0, -1].  This is the per-row
        k-extent the XLA-level flash schedules consume (the block-space
        work-saving of Theorem 2 applied row-wise).  One vectorized
        pass over the table, O(num_blocks)."""
        nbx, nby = self.domain.bounding_box
        lo = np.full((nby,), nbx, np.int64)
        hi = np.full((nby,), -1, np.int64)
        coords = self.domain.coords_host()
        np.minimum.at(lo, coords[:, 1], coords[:, 0])
        np.maximum.at(hi, coords[:, 1], coords[:, 0])
        lo[hi < 0] = 0
        return np.stack([lo, hi], -1).astype(np.int32)


# ---------------------------------------------------------------------------
# Domain registry: every compact domain the engine knows how to lower.
# Used by the equivalence tests and the lowering A/B benchmarks.
# ---------------------------------------------------------------------------

def registered_domains(size: str = "small") -> dict:
    """Representative instances of every registered domain family.

    size: "small" (fast interpret-mode tests) or "medium"."""
    if size == "small":
        return {
            "sierpinski": SierpinskiDomain(8),
            "carpet": GeneralizedFractalDomain(F.CARPET, 9),
            "vicsek": GeneralizedFractalDomain(F.VICSEK, 9),
            "triangular": TriangularDomain(6),
            "band": BandDomain(8, 3),
            "bounding-box": BoundingBoxDomain(4, 3),
        }
    return {
        "sierpinski": SierpinskiDomain(32),
        "carpet": GeneralizedFractalDomain(F.CARPET, 27),
        "vicsek": GeneralizedFractalDomain(F.VICSEK, 27),
        "triangular": TriangularDomain(17),
        "band": BandDomain(24, 5),
        "bounding-box": BoundingBoxDomain(7, 5),
    }
