"""MMA-accelerated lambda decode: the block-space map as matrix products.

The paper's lambda(w) map (and its inverse, the Squeeze-style compact
slot resolution) is a per-scale-level weighted sum over base-k digits.
Following *Accelerating Compact Fractals with Tensor Core GPUs* (arXiv
2110.12952) and *Squeeze* (arXiv 2201.00613), every such sum is a small
matrix contraction: encode the digit stream of an index as a one-hot
matrix ``O`` of shape (levels, k) and contract it with a precomputed
*digit-basis* matrix ``B`` of shape (levels, k, width) --
``lambda = O . B`` rides the MXU / tensor cores instead of the scalar
ALUs the ``closed_form`` lowering burns.

Mixed-precision contract
------------------------
One-hot digit vectors are bf16 (0/1 are exact in any float format);
basis matrices are f32 with integer entries; every ``dot_general``
accumulates in f32 (``preferred_element_type``).  A dot of 0/1 values
against integer weights is a sum of exact addends, and f32 addition of
integers is exact while every partial sum stays below 2**24 --
:data:`DIGIT_BOUND`.  The basis builders therefore *reject* any level
count whose coordinates, volume, or slot indices could reach 2**24, and
within that bound the chains are bit-identical to the integer
``closed_form`` decode (asserted by ``tests/test_mma.py`` and the plan
verifier's table re-derivation).

Everything here is pure jnp so the same chains run (a) on host for
table construction (``GridPlan.mma_table``), (b) inside jit, and (c)
inside gpu-structured Pallas kernel bodies, which compute their block
coordinates in-kernel per program.  The TPU structure instead binds the
chain *output* as a scalar-prefetch table (Mosaic index maps cannot run
``dot_general``), so the decoded coordinates ride the existing
BlockCoords plumbing.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from . import fractal as F
from . import memo

#: Largest integer magnitude whose f32 sums stay exact.  Every basis
#: builder raises ``ValueError`` when a coordinate, slot, or linear
#: index could reach this bound.
DIGIT_BOUND = 1 << 24


def fractal_of(domain) -> Optional[Tuple[F.FractalSpec, int]]:
    """``(spec, r_b)`` of a fractal block domain, else ``None``.

    Mirrors ``CompactLayout._fractal_spec``: the classic gasket domain
    predates :class:`~repro.core.fractal.FractalSpec` and carries no
    ``.spec`` attribute."""
    from .domain import GeneralizedFractalDomain, SierpinskiDomain
    if isinstance(domain, SierpinskiDomain):
        return F.SIERPINSKI, domain.r_b
    if isinstance(domain, GeneralizedFractalDomain):
        return domain.spec, domain.r_b
    return None


def _check_bound(spec: F.FractalSpec, r: int) -> None:
    if spec.k ** r >= DIGIT_BOUND or spec.m ** r >= DIGIT_BOUND:
        raise ValueError(
            f"mma digit-basis for {spec.name} at r={r}: volume k^r="
            f"{spec.k ** r} / extent m^r={spec.m ** r} reaches 2^24; "
            f"f32 accumulation would stop being exact "
            f"(DIGIT_BOUND={DIGIT_BOUND})")


# ---------------------------------------------------------------------------
# Host-built digit-basis matrices (memoized on the spec via core.memo)
# ---------------------------------------------------------------------------

def coords_basis(spec: F.FractalSpec, r: int) -> np.ndarray:
    """(r, k, 2) f32 basis: digit c at level mu contributes the copy
    offset ``offsets[c] * m**(mu-1)`` to the embedded (bx, by) -- the
    weights of :meth:`FractalSpec.lambda_map_linear` as a matrix."""
    def build():
        _check_bound(spec, r)
        b = np.zeros((r, spec.k, 2), np.float32)
        for mu in range(1, r + 1):
            p = spec.m ** (mu - 1)
            for c, (ox, oy) in enumerate(spec.offsets):
                b[mu - 1, c, 0] = ox * p
                b[mu - 1, c, 1] = oy * p
        b.setflags(write=False)
        return b
    return memo.cached("mma-coords-basis", spec, (r,), build)


def slots_basis(spec: F.FractalSpec, r: int) -> np.ndarray:
    """(r, k, 2) f32 basis: digit c at level mu contributes to the
    orthotope (w_x, w_y) -- odd levels are base-k digits of w_y, even of
    w_x (the Lemma 2 alternating unrolling).  Contracting the digit
    one-hots of a *linear* index with this basis is
    ``deinterleave_linear``; contracting per-level *copy rows* (see
    :func:`copy_rows`) is ``lambda_inverse``."""
    def build():
        _check_bound(spec, r)
        b = np.zeros((r, spec.k, 2), np.float32)
        for mu in range(1, r + 1):
            for c in range(spec.k):
                if mu % 2 == 1:
                    b[mu - 1, c, 1] = c * spec.k ** ((mu - 1) // 2)
                else:
                    b[mu - 1, c, 0] = c * spec.k ** (mu // 2 - 1)
        b.setflags(write=False)
        return b
    return memo.cached("mma-slots-basis", spec, (r,), build)


def linear_basis(spec: F.FractalSpec, r: int) -> np.ndarray:
    """(r, k, 1) f32 basis: copy c at level mu contributes
    ``c * k**(mu-1)`` to the linear lambda-order index."""
    def build():
        _check_bound(spec, r)
        b = np.zeros((r, spec.k, 1), np.float32)
        for mu in range(1, r + 1):
            for c in range(spec.k):
                b[mu - 1, c, 0] = c * spec.k ** (mu - 1)
        b.setflags(write=False)
        return b
    return memo.cached("mma-linear-basis", spec, (r,), build)


def pair_basis(spec: F.FractalSpec) -> np.ndarray:
    """(m*m, k) f32 match matrix: base-m digit pair (dx, dy) -> one-hot
    copy row.  Pairs matching no copy offset give an all-zero row, which
    under every weighted contraction contributes nothing -- exactly the
    copy-0 fall-through of the integer ``lambda_inverse`` (copy 0 has
    contribution ``0 * weight``)."""
    def build():
        b = np.zeros((spec.m * spec.m, spec.k), np.float32)
        for c, (ox, oy) in enumerate(spec.offsets):
            b[oy * spec.m + ox, c] = 1.0
        b.setflags(write=False)
        return b
    return memo.cached("mma-pair-basis", spec, (), build)


# ---------------------------------------------------------------------------
# Chain evaluators (jnp; host numpy inputs, jitted arrays, and
# gpu-structured Pallas kernel scalars all take the same path)
# ---------------------------------------------------------------------------

def _lift(a: np.ndarray) -> jnp.ndarray:
    """Lift a host basis array into the trace as *ops* (a stack of
    scalar constants): Pallas kernel bodies reject captured array
    constants, and the gpu structure evaluates these chains in-kernel.
    Scalar constants fold into the program; the stack/reshape
    re-materializes the (tiny) basis per trace, a fixed prologue cost
    next to the dot itself.  Outside a kernel (host table builds under
    ``ensure_compile_time_eval``, plain jit) this is just an eager
    constant."""
    if a.size == 0:
        return jnp.zeros(a.shape, a.dtype)
    flat = [jnp.asarray(v, a.dtype) for v in a.ravel().tolist()]
    return jnp.stack(flat).reshape(a.shape)


def _basis(b) -> jnp.ndarray:
    return _lift(b) if isinstance(b, np.ndarray) else jnp.asarray(b)


def _powers(base: int, levels: int) -> jnp.ndarray:
    return _lift(
        np.power(base, np.arange(levels, dtype=np.int64)).astype(np.int32))


def digit_onehot(v, base: int, levels: int) -> jnp.ndarray:
    """(..., levels, base) bf16 one-hot of the base-``base`` digits of
    an integer array (0/1 are exact in bf16)."""
    v = jnp.asarray(v)
    d = (v[..., None] // _powers(base, levels)) % base
    oh = d[..., None] == jnp.arange(base, dtype=jnp.int32)
    return oh.astype(jnp.bfloat16)


def _contract(onehot: jnp.ndarray, basis) -> jnp.ndarray:
    """Contract (..., L, B) digit one-hots with an (L, B, W) basis into
    (..., W) f32 -- the MMA: one (1, L*B) x (L*B, W) matmul per decode,
    batched over the leading dims."""
    nb = onehot.ndim - 2
    return lax.dot_general(
        onehot, _basis(basis),
        dimension_numbers=(((nb, nb + 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32)


def decode_linear(spec: F.FractalSpec, r: int, i):
    """lambda over a linear grid index: MMA replica of
    :meth:`FractalSpec.lambda_map_linear` -> (bx, by) i32."""
    out = _contract(digit_onehot(i, spec.k, r), coords_basis(spec, r))
    return out[..., 0].astype(jnp.int32), out[..., 1].astype(jnp.int32)


def slots_of_linear(spec: F.FractalSpec, r: int, i, swap: bool = False):
    """Packed slot (sx, sy) of linear step i -- MMA replica of
    ``deinterleave_linear`` (the compact enumeration is lambda-linear,
    so the own slot never needs the inverse chain).  ``swap`` mirrors
    the odd-level ``SuperTiling.tile_index`` transpose."""
    out = _contract(digit_onehot(i, spec.k, r), slots_basis(spec, r))
    sx = out[..., 0].astype(jnp.int32)
    sy = out[..., 1].astype(jnp.int32)
    return (sy, sx) if swap else (sx, sy)


def decode_orthotope(spec: F.FractalSpec, r: int, wx, wy):
    """lambda over orthotope coords: MMA replica of
    :meth:`FractalSpec.lambda_map`.  The per-level one-hots interleave
    digits of w_y (odd levels) and w_x (even levels) -- a static
    restack, then one contraction with the coords basis."""
    ohy = digit_onehot(wy, spec.k, (r + 1) // 2)
    ohx = digit_onehot(wx, spec.k, r // 2)
    parts = []
    for mu in range(1, r + 1):
        if mu % 2 == 1:
            parts.append(ohy[..., (mu - 1) // 2, :])
        else:
            parts.append(ohx[..., mu // 2 - 1, :])
    if not parts:
        z = jnp.zeros(jnp.shape(jnp.asarray(wx)) + (0, spec.k),
                      jnp.bfloat16)
        out = _contract(z, coords_basis(spec, r))
    else:
        out = _contract(jnp.stack(parts, axis=-2), coords_basis(spec, r))
    return out[..., 0].astype(jnp.int32), out[..., 1].astype(jnp.int32)


def copy_rows(spec: F.FractalSpec, r: int, x, y) -> jnp.ndarray:
    """(..., r, k) f32 per-level copy-index rows of embedded coords:
    base-m digit-pair one-hots contracted with the pair-match basis.
    Each row is one-hot (a matched pair) or all-zero (non-member level,
    the copy-0 fall-through)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    pows = _powers(spec.m, r)
    dx = (x[..., None] // pows) % spec.m
    dy = (y[..., None] // pows) % spec.m
    pr = dy * spec.m + dx
    oh = (pr[..., None] == jnp.arange(spec.m * spec.m, dtype=jnp.int32))
    oh = oh.astype(jnp.bfloat16)
    return lax.dot_general(
        oh, _basis(pair_basis(spec)),
        dimension_numbers=(((oh.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def member_of_rows(r: int, rows: jnp.ndarray):
    """Membership from copy rows: every level matched <=> the f32 sum of
    the (at most r) ones equals r -- value-equal to the domain's
    digit-pair / bit membership test."""
    return jnp.sum(rows, axis=(-2, -1)) == np.float32(r)


def inverse_slots(spec: F.FractalSpec, r: int, x, y, swap: bool = False):
    """MMA replica of :meth:`FractalSpec.lambda_inverse`: embedded
    coords -> packed orthotope slot (sx, sy).  Non-member inputs decode
    to some in-range slot (zero rows contribute nothing), exactly like
    the integer fall-through."""
    rows = copy_rows(spec, r, x, y)
    out = _contract(rows.astype(jnp.bfloat16), slots_basis(spec, r))
    sx = out[..., 0].astype(jnp.int32)
    sy = out[..., 1].astype(jnp.int32)
    return (sy, sx) if swap else (sx, sy)


def linear_of(spec: F.FractalSpec, r: int, x, y):
    """MMA replica of :meth:`FractalSpec.linear_index`."""
    rows = copy_rows(spec, r, x, y)
    out = _contract(rows.astype(jnp.bfloat16), linear_basis(spec, r))
    return out[..., 0].astype(jnp.int32)


def neighbor_slots(spec: F.FractalSpec, r: int, domain, bx, by,
                   dx: int, dy: int, swap: bool = False):
    """MMA replica of ``CompactLayout.neighbor_slot`` /
    ``SuperTiling.neighbor_tile``: the (dx, dy) neighbour of embedded
    (bx, by), clamped into the bounding box, membership-tested via the
    copy-row sum, resolved to its packed slot, and zeroed when invalid
    -- bit-for-bit the integer table entry."""
    nbx, nby = domain.bounding_box
    x = jnp.asarray(bx) + dx
    y = jnp.asarray(by) + dy
    xc = jnp.clip(x, 0, nbx - 1)
    yc = jnp.clip(y, 0, nby - 1)
    rows = copy_rows(spec, r, xc, yc)
    out = _contract(rows.astype(jnp.bfloat16), slots_basis(spec, r))
    sx = out[..., 0].astype(jnp.int32)
    sy = out[..., 1].astype(jnp.int32)
    if swap:
        sx, sy = sy, sx
    ok = (x >= 0) & (x < nbx) & (y >= 0) & (y < nby) \
        & member_of_rows(r, rows)
    zero = jnp.int32(0)
    return jnp.where(ok, sx, zero), jnp.where(ok, sy, zero), ok


# ---------------------------------------------------------------------------
# Non-fractal (attention / generic) domains: row-comparison chains
# ---------------------------------------------------------------------------

def row_basis(domain):
    """Host row tables of a row-major contiguous block domain:
    ``(starts, diff, ones)`` where ``starts`` is the (R+1,) i32 first
    linear index of each block row (``starts[R] = num_blocks``),
    ``diff[rho] = min_bx[rho] - starts[rho]`` (f32), and ``ones`` is the
    (R,) f32 summing vector.  Raises ``ValueError`` when the domain's
    canonical enumeration is not row-major with ascending-contiguous
    rows (every registered attention domain is)."""
    def build():
        coords = np.asarray(domain.coords_host(), np.int64)
        n = len(coords)
        nbx, nby = domain.bounding_box
        if n >= DIGIT_BOUND or nbx >= DIGIT_BOUND:
            raise ValueError(
                f"mma row basis: {n} blocks / width {nbx} reaches "
                f"2^24; f32 accumulation would stop being exact")
        bx, by = coords[:, 0], coords[:, 1]
        if np.any(np.diff(by) < 0):
            raise ValueError(
                "mma row basis: domain enumeration is not row-major")
        starts = np.searchsorted(by, np.arange(nby + 1)).astype(np.int64)
        lo = np.zeros(nby, np.int64)
        for rho in range(nby):
            s, e = int(starts[rho]), int(starts[rho + 1])
            if e == s:
                continue
            lo[rho] = bx[s]
            if not np.array_equal(bx[s:e],
                                  np.arange(lo[rho], lo[rho] + e - s)):
                raise ValueError(
                    f"mma row basis: block row {rho} is not a "
                    f"contiguous ascending span")
        out = (starts.astype(np.int32),
               (lo - starts[:-1]).astype(np.float32),
               np.ones(nby, np.float32))
        for a in out:
            a.setflags(write=False)
        return out
    return memo.cached("mma-row-basis", domain, (), build)


def decode_rows(domain, t):
    """Linear step -> (bx, by) for a row-major contiguous domain, as
    two dot products: the row index is the count of row starts at or
    below t (a comparison matrix contracted with ones, minus one), and
    the column is t plus the one-hot row's ``min_bx - start`` offset.
    Value-equal to ``domain.block_coords`` for t in [0, num_blocks)."""
    starts, diff, ones = row_basis(domain)
    si = _lift(starts)
    t = jnp.asarray(t)
    ge_lo = (t[..., None] >= si[:-1]).astype(jnp.bfloat16)
    ge_hi = (t[..., None] >= si[1:]).astype(jnp.bfloat16)

    def dot(a, b):
        return lax.dot_general(
            a, _basis(b),
            dimension_numbers=(((a.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    by = dot(ge_lo, ones) - np.float32(1.0)
    bx = t.astype(jnp.float32) + dot(ge_lo - ge_hi, diff)
    return bx.astype(jnp.int32), by.astype(jnp.int32)


def row_extents_chain(domain) -> jnp.ndarray:
    """Device (nby, 2) i32 of [min_bx, max_bx] per block row -- the
    flash q/k window hulls -- via membership matmuls: prefix/suffix
    member counts are the 0/1 membership matrix contracted with
    triangular ones matrices; the min (max) column is the number of
    leading (trailing) zero prefix (suffix) counts.  Empty rows give
    [0, -1], bit-identical to ``GridPlan.row_extents``."""
    nbx, nby = domain.bounding_box
    if nbx >= DIGIT_BOUND:
        raise ValueError(
            f"mma row extents: width {nbx} reaches 2^24; f32 "
            f"accumulation would stop being exact")
    x, y = np.mgrid[0:nbx, 0:nby]
    mem = np.broadcast_to(
        np.asarray(domain.contains(x.T, y.T)), (nby, nbx))
    m = jnp.asarray(mem).astype(jnp.bfloat16)
    tri = np.triu(np.ones((nbx, nbx), np.float32))

    def dot(a, b):
        return lax.dot_general(
            a, jnp.asarray(b),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    prefix = dot(m, tri)          # (nby, nbx): members at cols <= x
    suffix = dot(m, tri.T)        # members at cols >= x
    lead = jnp.sum((prefix == 0).astype(jnp.float32), axis=1)
    trail = jnp.sum((suffix == 0).astype(jnp.float32), axis=1)
    count = prefix[:, -1]
    lo = jnp.where(count == 0, np.float32(0.0), lead)
    hi = np.float32(nbx - 1) - trail
    return jnp.stack(
        [lo.astype(jnp.int32), hi.astype(jnp.int32)], axis=-1)
