"""Persisted autotuner over the block-space scheduling axes.

Navarro et al. ("Efficient GPU Thread Mapping on Embedded 2D Fractals",
2020) show the best realization of the fractal map is configuration
dependent: which of the lowerings wins flips with problem size, block
geometry and hardware.  This module searches the axes the execution
engine exposes -- ``lowering x storage x block x fuse x coarsen`` --
measures each viable candidate with the same wall-clock harness the
benchmarks use, and persists the winner to a JSON cache keyed by
``(kernel, domain, n, backend)`` so a serving process pays the search
once per configuration, ever.

Two consumption paths:

* explicit: ``autotune_ca / autotune_write / autotune_flash`` run the
  search and return the winning config dict (``--autotune`` on the
  examples and benchmarks);
* implicit: the kernel entry points accept ``grid_mode="auto"`` (and
  ``fuse="auto"`` / ``coarsen="auto"`` where they exist), which is a
  cache *lookup only* -- never a measurement -- falling back to the
  defaults when no tuned entry exists.  Lookup happens in the un-jitted
  entry wrappers so a fresh tuning run is picked up by the next call,
  not pinned by jit's static-argument cache.

The cache file defaults to ``~/.cache/repro-tune.json`` and is
overridden by the ``REPRO_TUNE_CACHE`` environment variable (CI points
it at a workspace path).  Writes are atomic (tmp + rename).
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Callable, Iterable, Optional, Sequence

import jax
import numpy as np

CACHE_ENV = "REPRO_TUNE_CACHE"

#: measurement defaults: enough to get a stable median without making
#: a full search take minutes in interpret mode.
MEASURE_WARMUP = 1
MEASURE_ITERS = 3


def default_cache_path() -> str:
    return os.environ.get(CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-tune.json")


def _pos_int(v, hi: int = 1 << 20) -> bool:
    return isinstance(v, int) and not isinstance(v, bool) and 0 < v <= hi


def _sane_config(config: dict) -> bool:
    """A cached winner is only trusted if every knob the kernels act on
    carries a value the tuner could actually have produced -- an
    unknown lowering / storage or a non-positive-integer schedule
    factor marks the entry corrupt (tampered file, version skew, torn
    write) and the lookup treats it as a miss so the kernel runs on
    defaults.  Keys outside the known-knob set are left alone: callers
    may cache richer configs (and tests cache synthetic ones)."""
    if not config:
        return False
    from repro.core.plan import LOWERINGS
    checks = {
        "lowering": lambda v: v in LOWERINGS,
        "storage": lambda v: v in ("embedded", "compact"),
        "fuse": _pos_int,
        "coarsen": _pos_int,
        "stages": _pos_int,
        "num_stages": _pos_int,
        "block_q": _pos_int,
        "block_k": _pos_int,
        "page_size": _pos_int,
        "num_warps": lambda v: v is None or _pos_int(v, 64),
    }
    for k, v in config.items():
        check = checks.get(k)
        if check is not None and not check(v):
            return False
    return True


class TuneCache:
    """JSON-persisted map from tuning key to winning config.

    Entries are ``{"config": {...}, "us": float, "tuned_at": epoch}``
    keyed by the sorted-JSON of ``{"kernel": ..., **params}``.  The
    backend is always part of ``params`` (a CPU winner must never leak
    onto TPU), enforced by :func:`autotune` / :func:`best` rather than
    trusted to callers.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._data = None

    @staticmethod
    def key(kernel: str, params: dict) -> str:
        return json.dumps({"kernel": kernel, **params}, sort_keys=True)

    def _load(self) -> dict:
        if self._data is None:
            self._data = {}
            try:
                with open(self.path) as f:
                    data = json.load(f)
                if isinstance(data, dict):
                    self._data = data
            except (OSError, ValueError):
                pass  # missing or corrupt cache == empty cache
        return self._data

    def get(self, kernel: str, params: dict) -> Optional[dict]:
        entry = self._load().get(self.key(kernel, params))
        if not isinstance(entry, dict) or not isinstance(
                entry.get("config"), dict):
            return None
        config = dict(entry["config"])
        if not _sane_config(config):
            return None  # corrupt / tampered entry reads as a miss
        return config

    def put(self, kernel: str, params: dict, config: dict, us: float,
            save: bool = True) -> None:
        self._load()[self.key(kernel, params)] = {
            "config": dict(config), "us": round(float(us), 2),
            "tuned_at": time.time()}
        if save:
            self.save()

    def save(self) -> None:
        """Merge-on-save: under an exclusive lock, re-read the file and
        union it with the in-memory entries (ours win on conflict)
        before the atomic write, so concurrent tuning/benchmark
        processes append to the cache instead of clobbering each
        other's entries.  A corrupt or partially-written file on disk
        merges as empty.  The flock closes the read-merge-write window;
        on platforms without fcntl the merge still narrows it to the
        dump itself."""
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        try:
            import fcntl
            lock = open(self.path + ".lock", "w")
            fcntl.flock(lock, fcntl.LOCK_EX)
        except (ImportError, OSError):
            lock = None
        try:
            ours = self._load()
            merged = {}
            try:
                with open(self.path) as f:
                    disk = json.load(f)
                if isinstance(disk, dict):
                    merged.update(disk)
            except (OSError, ValueError):
                pass
            merged.update(ours)
            self._data = merged
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tune.tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(merged, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
        finally:
            if lock is not None:
                lock.close()

    def __len__(self) -> int:
        return len(self._load())


_DEFAULT: Optional[TuneCache] = None


def default_cache() -> TuneCache:
    """Process-wide cache bound to the current default path (re-made
    when REPRO_TUNE_CACHE changes, so tests can redirect it)."""
    global _DEFAULT
    path = default_cache_path()
    if _DEFAULT is None or _DEFAULT.path != path:
        _DEFAULT = TuneCache(path)
    return _DEFAULT


def _with_backend(params: dict) -> dict:
    p = dict(params)
    p.setdefault("backend", jax.default_backend())
    return p


def target_params(params: dict, target) -> dict:
    """Qualify a tuning key with the emission target
    (:mod:`repro.core.backend`), so e.g. a gpu-interpret winner never
    answers for the tpu-interpret structure and vice versa.  ``target``
    may be a BackendTarget, a name, or None (= the process default,
    resolved here).  The reference point is the bare *platform* default
    -- not the process default -- because the cache file is shared
    across processes: a run steered onto another target via
    ``REPRO_BACKEND``/``set_default`` must stamp its entries even
    though that target is its own default.  True platform-default
    entries keep the unqualified key, so existing caches stay valid."""
    from . import backend as backend_lib
    target = backend_lib.resolve(target)
    if target == backend_lib.platform_default():
        return params
    return {**params, "target": target.name}


def shard_params(params: dict, mesh, shard_axis: str) -> dict:
    """Qualify a tuning key with the shard count a kernel will actually
    run at (``mesh.shape[shard_axis]``), so a single-device winner never
    answers for a sharded run and different shard counts never collide.
    Unsharded lookups (``mesh=None``) keep the unqualified key, so
    existing caches remain valid.  The kernel entry points route every
    ``"auto"`` resolve through this."""
    if mesh is None:
        return params
    return {**params, "devices": int(mesh.shape[shard_axis])}


def measure(fn: Callable, *args, warmup: int = MEASURE_WARMUP,
            iters: int = MEASURE_ITERS) -> float:
    """Median wall-clock microseconds per call (the benchmarks'
    ``time_fn``, re-stated here so the tuner has no benchmark-package
    dependency and hillclimb can reuse one measurement path)."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    if out is not None:
        jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(samples))


def _axis_distance(a: dict, b: dict) -> int:
    """How many knobs two configs disagree on (missing = default)."""
    return sum(1 for k in set(a) | set(b) if a.get(k) != b.get(k))


def autotune(kernel: str, params: dict, candidates: Iterable[dict],
             build: Callable[[dict], Callable], *,
             cache: Optional[TuneCache] = None, force: bool = False,
             warmup: int = MEASURE_WARMUP, iters: int = MEASURE_ITERS,
             verbose: bool = False, seed_config: Optional[dict] = None,
             verify: Optional[Callable[[dict], None]] = None):
    """Generic search: measure every viable candidate, persist the winner.

    ``build(config)`` returns a zero-arg measurable callable, or raises
    ValueError / NotImplementedError to declare the candidate inviable
    for this problem (e.g. fuse > supertile, coarsen on a non-fractal
    domain) -- inviable candidates are skipped, not errors.

    ``verify(config)``, when given, runs after ``build`` and before any
    measurement; raising ValueError (the plan verifier's
    ``PlanVerificationError`` is one) rejects the candidate so a plan
    that fails static analysis is never timed, let alone persisted as a
    winner.  The kernel-specific searchers wire this to
    :mod:`repro.analysis` via their ``verify=True`` flag.

    ``seed_config`` warm-starts the search from a related problem's
    winner (e.g. the D=1 cache entry seeding a D>1 search): only the
    seed and its one-knob neighbours are measured, seed first, instead
    of the full cross product.

    Returns ``(config, us, trials)`` where trials is the full
    [(config, us)] measurement log (the hillclimb table rides on it).
    """
    cache = cache if cache is not None else default_cache()
    params = _with_backend(params)
    if not force:
        hit = cache.get(kernel, params)
        if hit is not None:
            return hit, None, []
    candidates = list(candidates)
    if seed_config is not None:
        near = [c for c in candidates
                if _axis_distance(c, seed_config) <= 1]
        if near:
            near.sort(key=lambda c: _axis_distance(c, seed_config))
            if verbose:
                print(f"  warm-start from {seed_config}: measuring "
                      f"{len(near)} of {len(candidates)} candidates")
            candidates = near
    trials = []
    best_cfg, best_us = None, float("inf")
    for cfg in candidates:
        try:
            fn = build(cfg)
        except (ValueError, NotImplementedError) as e:
            if verbose:
                print(f"  skip {cfg}: {e}")
            continue
        if verify is not None:
            try:
                verify(cfg)
            except (ValueError, NotImplementedError) as e:
                if verbose:
                    print(f"  reject {cfg}: plan verification failed: {e}")
                continue
        us = measure(fn, warmup=warmup, iters=iters)
        trials.append((dict(cfg), us))
        if verbose:
            print(f"  {cfg} -> {us:.1f} us")
        if us < best_us:
            best_cfg, best_us = dict(cfg), us
    if best_cfg is None:
        raise ValueError(f"autotune({kernel}): no viable candidate "
                         f"for {params}")
    cache.put(kernel, params, best_cfg, best_us)
    return best_cfg, best_us, trials


def best(kernel: str, params: dict, default: Optional[dict] = None,
         cache: Optional[TuneCache] = None) -> Optional[dict]:
    """Cache lookup only (the ``grid_mode='auto'`` path): the tuned
    config for this (kernel, params, backend), or ``default``."""
    cache = cache if cache is not None else default_cache()
    hit = cache.get(kernel, _with_backend(params))
    return hit if hit is not None else default


# ---------------------------------------------------------------------------
# Kernel-specific search spaces + searchers.  Each synthesizes its own
# operands (random state masked to the fractal / random qkv), so callers
# only describe the problem; the returned config is then passed to the
# real entry points.
# ---------------------------------------------------------------------------

#: the full (unrestricted) storage axis.  A search restricted to a
#: subset gets its own cache key (see :func:`_axis_param`): its winner
#: prescribes a storage, so it must never answer -- or overwrite -- the
#: unrestricted key the kernels' ``grid_mode="auto"`` lookups use.
ALL_STORAGES = ("embedded", "compact")
ALL_FLASH_BLOCKS = (64, 128, 256)


def _axis_param(params: dict, name: str, value, full) -> dict:
    """Stamp a candidate-axis restriction into the cache key params
    when (and only when) it deviates from the full default axis."""
    if tuple(sorted(map(str, value))) != tuple(sorted(map(str, full))):
        params[name] = "+".join(sorted(map(str, value)))
    return params

def _fuse_axis(block: int, coarsen: int, max_fuse: int) -> Sequence[int]:
    """Fuse depths to try: powers of two up to min(max_fuse, supertile
    side) -- the fused halo ring must fit inside one neighbour tile."""
    out, f = [], 1
    while f <= min(max_fuse, block * coarsen):
        out.append(f)
        f *= 2
    return out


def _coarsen_axis(fractal: str, n: int, block: int,
                  max_coarsen: int) -> Sequence[int]:
    from . import fractal as F
    m = 2 if fractal in ("sierpinski", "sierpinski-gasket") \
        else F.FRACTALS[fractal].m
    out, s = [], 1
    while s <= max_coarsen and (n // block) % s == 0 and s < n // block:
        out.append(s)
        s *= m
    return out or [1]


def _lowering_axis(target=None) -> tuple:
    """:data:`~repro.core.plan.LOWERINGS`, with ``mma`` hoisted to the
    front on targets whose matrix units make the digit-basis decode
    profitable (``prefers_mma``): candidate order is measurement order,
    so the likely winner warms the jit caches first and the sharded
    warm-start explores its one-knob neighbourhood."""
    from . import backend as backend_lib
    from .plan import LOWERINGS
    target = backend_lib.resolve(target)
    if target.prefers_mma:
        return ("mma",) + tuple(lo for lo in LOWERINGS if lo != "mma")
    return tuple(LOWERINGS)


def ca_candidates(fractal: str, n: int, block: int, *,
                  storages=("embedded", "compact"), max_fuse: int = 8,
                  max_coarsen: int = 4, target=None):
    from . import backend as backend_lib
    target = backend_lib.resolve(target)
    # pipelining depth is a real axis where the emission can use it:
    # the TPU structure's DMA double buffers, or a compiled gpu's
    # Triton scheduler.  The emulated gpu target ignores it for CA.
    stages_axis = (1, 2) if target.block_indexed \
        or (target.kind == "gpu" and not target.interpret) else (1,)
    for storage in storages:
        for lowering in _lowering_axis(target):
            for coarsen in _coarsen_axis(fractal, n, block, max_coarsen):
                for fuse in _fuse_axis(block, coarsen, max_fuse):
                    for stages in stages_axis:
                        yield {"lowering": lowering, "storage": storage,
                               "fuse": fuse, "coarsen": coarsen,
                               "stages": stages}


def autotune_ca(*, fractal: str = "sierpinski-gasket", n: int = 256,
                block: int = 16, rule: str = "parity", steps: int = 8,
                storages=ALL_STORAGES, max_fuse: int = 8,
                max_coarsen: int = 4, cache: Optional[TuneCache] = None,
                force: bool = False, interpret: Optional[bool] = None,
                verbose: bool = False, backend=None, mesh=None,
                shard_axis: str = "data", verify: bool = False):
    """Search the CA scheduling axes for (fractal, n, block, rule).

    ``mesh=`` tunes the *sharded* run (shard-count-qualified cache
    key), warm-started from the D=1 winner when one is cached: only the
    D=1 config and its one-knob neighbours are re-measured instead of
    the full cross product (the fuse/coarsen landscape moves little
    with D; the lowering sometimes flips).  ``backend=`` tunes a
    non-default emission target under its own qualified key.
    ``verify=True`` statically verifies each candidate's GridPlan
    (:mod:`repro.analysis`) before it is measured; failing candidates
    are rejected from the search."""
    from .compact import compact_layout
    from .domain import make_fractal_domain
    from repro.kernels.sierpinski_ca import ca_run

    dom = make_fractal_domain(fractal, n // block)
    mask = np.zeros((n, n), bool)
    y, x = np.mgrid[0:n, 0:n]
    mask[:] = np.asarray(dom.cell_member(x, y, n))
    rng = np.random.default_rng(0)
    state = (rng.integers(0, 2, (n, n)) * mask).astype(np.float32)
    import jax.numpy as jnp
    operands = {"embedded": (jnp.asarray(state), jnp.zeros((n, n),
                                                           jnp.float32))}
    if "compact" in storages:
        lay = compact_layout(dom)
        operands["compact"] = (lay.pack(operands["embedded"][0], block),
                               lay.pack(operands["embedded"][1], block))

    def build(cfg):
        a, b = operands[cfg["storage"]]

        def fn():
            return ca_run(a, b, steps, rule=rule, block=block,
                          grid_mode=cfg["lowering"],
                          storage=cfg["storage"], n=n, fuse=cfg["fuse"],
                          coarsen=cfg["coarsen"],
                          num_stages=cfg.get("stages", 1),
                          backend=backend, interpret=interpret,
                          donate=False, mesh=mesh,
                          shard_axis=shard_axis)
        return fn

    vfy = None
    if verify:
        def vfy(cfg):
            a, b = operands[cfg["storage"]]
            # steps == fuse: a single fused launch traces (and thereby
            # verifies) the exact plan the measured config runs.
            ca_run(a, b, cfg["fuse"], rule=rule, block=block,
                   grid_mode=cfg["lowering"], storage=cfg["storage"],
                   n=n, fuse=cfg["fuse"], coarsen=cfg["coarsen"],
                   num_stages=cfg.get("stages", 1), backend=backend,
                   interpret=interpret, donate=False, mesh=mesh,
                   shard_axis=shard_axis, verify=True)

    base = _axis_param(
        {"fractal": fractal, "n": n, "block": block, "rule": rule},
        "storages", storages, ALL_STORAGES)
    base = target_params(base, backend)
    params = shard_params(base, mesh, shard_axis)
    seed = None
    if mesh is not None:
        # warm-start the D>1 search from the single-device winner
        seed = best("ca", base, cache=cache)
    cands = ca_candidates(fractal, n, block, storages=storages,
                          max_fuse=max_fuse, max_coarsen=max_coarsen,
                          target=backend)
    return autotune("ca", params, cands, build, cache=cache, force=force,
                    verbose=verbose, seed_config=seed, verify=vfy)


def write_candidates(fractal: str, n: int, block: int, *,
                     storages=("embedded", "compact"),
                     max_coarsen: int = 4, target=None):
    for storage in storages:
        for lowering in _lowering_axis(target):
            for coarsen in _coarsen_axis(fractal, n, block, max_coarsen):
                yield {"lowering": lowering, "storage": storage,
                       "coarsen": coarsen}


def autotune_write(*, fractal: str = "sierpinski-gasket", n: int = 256,
                   block: int = 16, storages=ALL_STORAGES,
                   max_coarsen: int = 4,
                   cache: Optional[TuneCache] = None, force: bool = False,
                   interpret: Optional[bool] = None,
                   verbose: bool = False, backend=None, mesh=None,
                   shard_axis: str = "data", verify: bool = False):
    """Search lowering x storage x coarsen for the write microbenchmark
    (``mesh``/``backend``/``verify`` as in :func:`autotune_ca`, incl.
    the D=1 warm start)."""
    from .compact import compact_layout
    from .domain import make_fractal_domain
    from repro.kernels.sierpinski_write import sierpinski_write
    import jax.numpy as jnp

    dom = make_fractal_domain(fractal, n // block)
    operands = {"embedded": jnp.zeros((n, n), jnp.float32)}
    if "compact" in storages:
        operands["compact"] = compact_layout(dom).pack(
            operands["embedded"], block)

    def build(cfg):
        m = operands[cfg["storage"]]

        def fn():
            return sierpinski_write(m, 1.0, block=block,
                                    grid_mode=cfg["lowering"],
                                    storage=cfg["storage"], n=n,
                                    coarsen=cfg["coarsen"],
                                    backend=backend, interpret=interpret,
                                    mesh=mesh, shard_axis=shard_axis)
        return fn

    vfy = None
    if verify:
        def vfy(cfg):
            sierpinski_write(operands[cfg["storage"]], 1.0, block=block,
                             grid_mode=cfg["lowering"],
                             storage=cfg["storage"], n=n,
                             coarsen=cfg["coarsen"], backend=backend,
                             interpret=interpret, mesh=mesh,
                             shard_axis=shard_axis, verify=True)

    base = _axis_param({"fractal": fractal, "n": n, "block": block},
                       "storages", storages, ALL_STORAGES)
    base = target_params(base, backend)
    params = shard_params(base, mesh, shard_axis)
    seed = best("write", base, cache=cache) if mesh is not None else None
    cands = write_candidates(fractal, n, block, storages=storages,
                             max_coarsen=max_coarsen, target=backend)
    return autotune("write", params, cands, build, cache=cache,
                    force=force, verbose=verbose, seed_config=seed,
                    verify=vfy)


#: Triton compiler axes the gpu targets additionally search (the
#: TPU-side analogue is the block geometry itself).
GPU_NUM_WARPS = (2, 4, 8)
GPU_NUM_STAGES = (1, 2, 3)


def flash_candidates(sq: int, sk: int, *, blocks=ALL_FLASH_BLOCKS,
                     target=None):
    """lowering x block geometry, crossed with the gpu-structure
    pipelining axes when the target has them: on a *compiled* gpu
    target num_warps x num_stages (Triton occupancy + scheduling); on
    the emulated gpu target num_stages alone, which is still a real
    knob there -- it sizes the KV-FIFO software pipeline the flash
    kernel itself unrolls.  ``target`` accepts a BackendTarget, a
    name, or None (= the process default -- on a CUDA machine the gpu
    axes appear without asking)."""
    from . import backend as backend_lib
    target = backend_lib.resolve(target)
    gpu = target.kind == "gpu"
    compiled = gpu and not target.interpret
    for lowering in _lowering_axis(target):
        for b in blocks:
            if b <= min(sq, sk) and sq % b == 0 and sk % b == 0:
                base = {"lowering": lowering, "block_q": b, "block_k": b}
                if not gpu:
                    yield base
                elif compiled:
                    for nw in GPU_NUM_WARPS:
                        for ns in GPU_NUM_STAGES:
                            yield {**base, "num_warps": nw,
                                   "num_stages": ns}
                else:
                    for ns in (1, 2):
                        yield {**base, "num_stages": ns}


def autotune_flash(*, kind: str = "causal", batch: int = 1, heads: int = 4,
                   kv_heads: Optional[int] = None, sq: int = 1024,
                   sk: Optional[int] = None, d: int = 64, window: int = 0,
                   blocks=(64, 128, 256), cache: Optional[TuneCache] = None,
                   force: bool = False, interpret: Optional[bool] = None,
                   verbose: bool = False, backend=None,
                   verify: bool = False):
    """Search lowering x block geometry (x num_warps/num_stages on a
    compiled gpu target) for the flash-attention kernel.
    ``verify=True`` statically verifies each candidate's plan before
    measuring it (:mod:`repro.analysis`)."""
    from repro.kernels.flash_attention import flash_attention
    import jax.numpy as jnp

    sk = sq if sk is None else sk
    kv_heads = heads if kv_heads is None else kv_heads
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(batch, heads, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(batch, kv_heads, sk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(batch, kv_heads, sk, d)), jnp.float32)

    def build(cfg):
        def fn():
            return flash_attention(q, k, v, kind=kind, window=window,
                                   block_q=cfg["block_q"],
                                   block_k=cfg["block_k"],
                                   grid_mode=cfg["lowering"],
                                   num_warps=cfg.get("num_warps"),
                                   num_stages=cfg.get("num_stages"),
                                   backend=backend, interpret=interpret)
        return fn

    vfy = None
    if verify:
        def vfy(cfg):
            flash_attention(q, k, v, kind=kind, window=window,
                            block_q=cfg["block_q"],
                            block_k=cfg["block_k"],
                            grid_mode=cfg["lowering"],
                            num_warps=cfg.get("num_warps"),
                            num_stages=cfg.get("num_stages"),
                            backend=backend, interpret=interpret,
                            verify=True)

    params = target_params(_axis_param(
        {"kind": kind, "batch": batch, "heads": heads,
         "kv_heads": kv_heads, "sq": sq, "sk": sk, "d": d,
         "window": window},
        "blocks", blocks, ALL_FLASH_BLOCKS), backend)
    return autotune("flash", params,
                    flash_candidates(sq, sk, blocks=blocks,
                                     target=backend),
                    build, cache=cache, force=force, verbose=verbose,
                    verify=vfy)


#: the full page-size axis the paged-decode search sweeps.  Page size
#: trades pool fragmentation (small pages waste less tail) against
#: gather granularity (large pages mean fewer LUT rows per step); like
#: the flash block geometry, the winner is configuration dependent.
ALL_PAGE_SIZES = (8, 16, 32, 64)


def paged_candidates(seq: int, *, page_sizes=ALL_PAGE_SIZES,
                     target=None):
    """lowering x page_size for the paged decode kernel.  Page sizes
    larger than the sequence are inviable (a one-page pool degenerates
    to the contiguous layout and is covered by the flash search)."""
    for lowering in _lowering_axis(target):
        for ps in page_sizes:
            if ps <= seq:
                yield {"lowering": lowering, "page_size": ps}


def autotune_paged(*, batch: int = 4, heads: int = 4,
                   kv_heads: Optional[int] = None, seq: int = 256,
                   d: int = 64, window: int = 0,
                   page_sizes=ALL_PAGE_SIZES,
                   cache: Optional[TuneCache] = None, force: bool = False,
                   interpret: Optional[bool] = None, verbose: bool = False,
                   backend=None, mesh=None, shard_axis: str = "data",
                   verify: bool = False):
    """Search lowering x page_size for the paged flash-decode kernel.

    Every candidate decodes the *same* logical caches: contiguous K/V
    are scattered into a fresh pool at each candidate's page size, so
    the measurement isolates the layout axis.  The page pool is sized
    to the candidate (``batch * ceil(seq/ps) + 1`` pages incl. the
    null page), matching what a serving process at that page size
    would hold live.  ``mesh=`` tunes the slot-sharded decode under a
    shard-count-qualified key (warm-started from the D=1 winner);
    ``verify=True`` statically verifies each candidate's paged plan
    before it is measured."""
    from repro.core import paged as paged_lib
    from repro.models.attention import decode_attention_paged
    import jax.numpy as jnp

    if interpret is not None:
        # the paged entry point has no interpret= knob of its own: the
        # emulation choice rides the resolved target
        from . import backend as backend_lib
        backend = backend_lib.resolve(backend, interpret)
    kv_heads = heads if kv_heads is None else kv_heads
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(batch, heads, 1, d)), jnp.float32)
    k = rng.normal(size=(batch, kv_heads, seq, d)).astype(np.float32)
    v = rng.normal(size=(batch, kv_heads, seq, d)).astype(np.float32)
    pos = jnp.full((batch,), seq, jnp.int32)

    def operands(ps: int):
        npages = paged_lib.pages_for(seq, ps)
        pool = paged_lib.init_pool(batch * npages + 1, kv_heads, ps, d)
        table = np.full((batch, npages), paged_lib.NULL_PAGE, np.int32)
        for b_ in range(batch):
            pages = 1 + b_ * npages + np.arange(npages)
            table[b_] = pages
            pool = paged_lib.write_prefill_pages(
                pool, jnp.asarray(pages, jnp.int32), k[b_], v[b_])
        return pool, jnp.asarray(table)

    pools = {ps: operands(ps) for ps in page_sizes if ps <= seq}

    def build(cfg):
        pool, table = pools[cfg["page_size"]]

        def fn():
            return decode_attention_paged(
                q, pool, table, pos, window=window,
                grid_mode=cfg["lowering"], backend=backend,
                mesh=mesh, shard_axis=shard_axis)
        return fn

    vfy = None
    if verify:
        def vfy(cfg):
            pool, table = pools[cfg["page_size"]]
            decode_attention_paged(
                q, pool, table, pos, window=window,
                grid_mode=cfg["lowering"], backend=backend,
                mesh=mesh, shard_axis=shard_axis, verify=True)

    base = _axis_param(
        {"batch": batch, "heads": heads, "kv_heads": kv_heads,
         "seq": seq, "d": d, "window": window},
        "page_sizes", page_sizes, ALL_PAGE_SIZES)
    base = target_params(base, backend)
    params = shard_params(base, mesh, shard_axis)
    seed = best("paged", base, cache=cache) if mesh is not None else None
    return autotune("paged", params,
                    paged_candidates(seq, page_sizes=page_sizes,
                                     target=backend),
                    build, cache=cache, force=force, verbose=verbose,
                    seed_config=seed, verify=vfy)


# ---------------------------------------------------------------------------
# CLI smoke: a deliberately tiny search so CI can exercise the full
# measure -> persist -> reload path in seconds (interpret mode).
# ---------------------------------------------------------------------------

def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny search space (CI)")
    ap.add_argument("--cache", default=None, help="cache file path")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even on a cache hit")
    args = ap.parse_args(argv)
    cache = TuneCache(args.cache) if args.cache else default_cache()
    if args.smoke:
        n, block, max_fuse, max_coarsen, blocks = 32, 8, 2, 2, (32,)
        sq, pseq, psizes = 64, 32, (8, 16)
    else:
        n, block, max_fuse, max_coarsen, blocks = 256, 16, 8, 4, (64, 128)
        sq, pseq, psizes = 512, 256, (16, 32, 64)
    for name, fn in (
        ("ca", lambda: autotune_ca(n=n, block=block, max_fuse=max_fuse,
                                   max_coarsen=max_coarsen, cache=cache,
                                   force=args.force, verbose=True)),
        ("write", lambda: autotune_write(n=n, block=block,
                                         max_coarsen=max_coarsen,
                                         cache=cache, force=args.force,
                                         verbose=True)),
        ("flash", lambda: autotune_flash(sq=sq, d=32, blocks=blocks,
                                         cache=cache, force=args.force,
                                         verbose=True)),
        ("paged", lambda: autotune_paged(batch=2, heads=2, seq=pseq,
                                         d=32, page_sizes=psizes,
                                         cache=cache, force=args.force,
                                         verbose=True)),
    ):
        cfg, us, trials = fn()
        tag = f"{us:.1f} us, {len(trials)} trials" if us is not None \
            else "cache hit"
        print(f"{name}: best={cfg} ({tag})")
    # reload through a fresh cache object to prove the persistence path
    fresh = TuneCache(cache.path)
    print(f"cache {cache.path}: {len(fresh)} entries")


if __name__ == "__main__":
    main()
