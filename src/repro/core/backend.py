"""Backend-neutral kernel emission: the ``BackendTarget`` capability
descriptor plus the one place in the repo that constructs Pallas grid
specs.

The paper reports its lambda(omega) speedups on GPUs, but the execution
engine grew up against TPU Pallas: scalar-prefetch decode tables
(``pltpu.PrefetchScalarGridSpec``), SMEM scalar operands, and the
sequential-grid revisiting idiom are Mosaic-specific, and everywhere
else the kernels silently fell back to interpret mode.  This module
gives the engine a real backend axis:

``tpu`` (Mosaic)
    The existing path, unchanged semantics: operand placement happens in
    ``BlockSpec`` index maps, which may read host-built decode tables
    through scalar prefetch; run-time scalars ride SMEM refs; the grid
    is sequential, so revisited output blocks accumulate across steps
    and online-softmax state lives in VMEM scratch.

``gpu`` (Triton / ``pallas.gpu``)
    No scalar prefetch and no sequential-grid guarantee, so the same
    plans lower the way the paper's CUDA kernels (and the follow-up GPU
    thread-mapping work, arXiv:2004.13475) do: the per-block
    lambda / slot / neighbour LUT travels as a **regular HBM operand**
    read in-kernel at ``pl.program_id``; state arrays arrive whole and
    kernels address tiles with computed offsets (``pl.load`` /
    ``pl.store``); run-time step counts are ordinary scalar operands;
    reduction state lives in loop carries, not scratch.  On a CUDA
    device the call lowers through Triton with ``num_warps`` /
    ``num_stages`` from the autotuner.

``tpu-interpret`` / ``gpu-interpret``
    Either structure executed by the Pallas interpreter -- selectable
    in CI so both lowerings are exercised (and cross-checked
    bit-for-bit) without the hardware.

Selection order for the default target: an explicit ``backend=``
argument > :func:`set_default` > the ``REPRO_BACKEND`` environment
variable > the jax platform (tpu -> ``tpu``, gpu -> ``gpu``, anything
else -> ``tpu-interpret``, preserving the historical CPU behaviour).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: environment override consulted by :func:`resolve` (CI's gpu-backend
#: job sets ``REPRO_BACKEND=gpu-interpret``).
BACKEND_ENV = "REPRO_BACKEND"

_OVERRIDE: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class BackendTarget:
    """Capability descriptor for one kernel-emission target.

    Fields are the capabilities the kernels and plans actually branch
    on -- nothing here is advisory:

    kind:                "tpu" (Mosaic) or "gpu" (Triton) emission
                         structure.
    interpret:           run the structure under the Pallas interpreter.
    has_scalar_prefetch: BlockSpec index maps may read host decode
                         tables (``PrefetchScalarGridSpec``).  Without
                         it, tables become leading HBM operands read
                         in-kernel.
    smem_scalar_params:  run-time scalars (fused step counts, decode
                         positions) ride SMEM refs; otherwise they are
                         regular (1,) i32 operands.
    block_indexed:       operand tiles are placed by BlockSpec index
                         maps (the grid-sequenced Mosaic pipeline);
                         otherwise state arrays arrive whole and the
                         kernel computes tile offsets itself.
    sequential_grid:     grid steps execute in order, so revisited
                         output blocks may accumulate across steps and
                         per-row state may live in scratch.  GPU grids
                         are parallel: reductions must use loop carries
                         or per-step partials.
    supports_scratch:    ``scratch_shapes`` (VMEM accumulators) exist.
    memory_space:        where operand tiles land ("vmem" pipeline
                         copies vs "hbm" pointers) -- documentation of
                         the model each structure assumes.
    async_copy:          kernels may issue explicit in-kernel DMA
                         (``pltpu.make_async_copy`` + DMA semaphores,
                         operands parked in ``pltpu.ANY``) and overlap
                         the copy with compute.  Mosaic has DMA
                         engines; the interpreter emulates the copies
                         synchronously, preserving semantics.
    pipeline_stages:     maximum useful staged-copy depth for
                         software-pipelined streaming loops: the DMA
                         double buffers of the TPU structure (2) and
                         the FIFO/Triton stages of the GPU structure
                         (4, quad buffering).  1 means the target has
                         no software pipeline: ``resolve_stages``
                         clamps every request back to the synchronous
                         path.
    prefers_mma:         the target has matrix units (MXU / tensor
                         cores) that make the ``mma`` digit-basis
                         decode chains profitable; the autotuner ranks
                         ``mma`` candidates first on such targets.
                         Both structures carry the flag (TPUs have the
                         MXU, GPUs tensor cores); a scalar-only target
                         would clear it.
    """

    name: str
    kind: str
    interpret: bool
    has_scalar_prefetch: bool
    smem_scalar_params: bool
    block_indexed: bool
    sequential_grid: bool
    supports_scratch: bool
    memory_space: str
    async_copy: bool
    pipeline_stages: int
    prefers_mma: bool

    # -- variants -----------------------------------------------------------

    def emulated(self) -> "BackendTarget":
        """This structure under the interpreter (idempotent; returns
        the canonical singleton)."""
        if self.interpret:
            return self
        return TARGETS[self.name + "-interpret"]

    def native(self) -> "BackendTarget":
        if not self.interpret:
            return self
        return TARGETS[self.kind]

    # -- emission helpers ---------------------------------------------------

    def scalar_spec(self) -> pl.BlockSpec:
        """BlockSpec for a run-time scalar operand (shape (1,) i32):
        an SMEM ref on TPU, a regular operand elsewhere."""
        if self.smem_scalar_params:
            return pl.BlockSpec(memory_space=pltpu.SMEM)
        return full_spec((1,))

    def scratch(self, shape, dtype):
        """A VMEM scratch allocation, where the target has scratch."""
        if not self.supports_scratch:
            raise ValueError(
                f"target {self.name!r} has no scratch memory: keep "
                f"reduction state in loop carries")
        return pltpu.VMEM(shape, dtype)

    # -- software pipelining ------------------------------------------------

    def resolve_stages(self, num_stages: Optional[int]) -> int:
        """Clamp a requested pipeline depth to what this target can
        stage.  ``None`` / ``"auto"`` and anything <= 1 mean the
        synchronous path; depths beyond :attr:`pipeline_stages` clamp
        down rather than error so a tune-cache entry from a deeper
        target stays usable."""
        if num_stages is None or num_stages == "auto":
            return 1
        return max(1, min(int(num_stages), self.pipeline_stages))

    def any_spec(self) -> pl.BlockSpec:
        """BlockSpec parking an operand un-copied (``pltpu.ANY``) so
        the kernel streams tiles out of it with explicit DMA.  Only
        meaningful on :attr:`async_copy` targets."""
        if not self.async_copy:
            raise ValueError(
                f"target {self.name!r} has no async-copy support; "
                f"operands must arrive via BlockSpec pipeline copies")
        return pl.BlockSpec(memory_space=pltpu.ANY)

    def dma_sems(self, shape) -> object:
        """A scratch array of DMA-completion semaphores (one per
        in-flight copy slot)."""
        if not self.async_copy:
            raise ValueError(
                f"target {self.name!r} has no DMA semaphores")
        return pltpu.SemaphoreType.DMA(tuple(shape))

    @staticmethod
    def start_copy(src, dst, sem):
        """Begin ``src -> dst`` on a DMA engine; returns the copy
        descriptor (``.wait()`` blocks on ``sem``).  The interpreter
        performs the copy synchronously at ``start``/``wait``."""
        return pltpu.make_async_copy(src, dst, sem)

    def call_kwargs(self, num_warps: Optional[int] = None,
                    num_stages: Optional[int] = None) -> dict:
        """Extra ``pl.pallas_call`` kwargs for this target (the Triton
        compiler parameters, when actually compiling for a GPU)."""
        if self.kind == "gpu" and not self.interpret:
            from jax.experimental.pallas import triton as pltriton
            return {"compiler_params": pltriton.TritonCompilerParams(
                num_warps=int(num_warps or 4),
                num_stages=int(num_stages or 2))}
        return {}


def _mk(name, kind, interpret):
    tpu = kind == "tpu"
    return BackendTarget(
        name=name, kind=kind, interpret=interpret,
        has_scalar_prefetch=tpu, smem_scalar_params=tpu,
        block_indexed=tpu, sequential_grid=tpu, supports_scratch=tpu,
        memory_space="vmem" if tpu else "hbm",
        # capability flags are per *structure*, not per execution mode:
        # the -interpret variants keep them so the pipelined paths are
        # exercised (and parity-tested) without the hardware.
        async_copy=tpu, pipeline_stages=2 if tpu else 4,
        prefers_mma=True)


TPU = _mk("tpu", "tpu", False)
GPU = _mk("gpu", "gpu", False)
TPU_INTERPRET = _mk("tpu-interpret", "tpu", True)
GPU_INTERPRET = _mk("gpu-interpret", "gpu", True)

TARGETS = {t.name: t for t in (TPU, GPU, TPU_INTERPRET, GPU_INTERPRET)}
_ALIASES = {"mosaic": "tpu", "triton": "gpu"}


def platform_default() -> BackendTarget:
    """The target the bare jax platform implies, ignoring
    :func:`set_default` and ``REPRO_BACKEND``.  This is the reference
    point for *persisted* qualification (tune-cache keys): a process
    whose default was steered away from the platform must stamp its
    entries, or another process with a different default would read
    them as its own."""
    plat = jax.default_backend()
    return TPU if plat == "tpu" else (
        GPU if plat == "gpu" else TPU_INTERPRET)


def set_default(name: Optional[str]) -> None:
    """Process-wide default target override (the ``--backend`` flag of
    serve/train); ``None`` restores platform/env selection."""
    global _OVERRIDE
    if name is not None:
        resolve(name)  # validate eagerly
    _OVERRIDE = name


def resolve(spec=None, interpret: Optional[bool] = None) -> BackendTarget:
    """Normalize a backend spec to a :class:`BackendTarget`.

    spec: a target, a name ("tpu" | "gpu" | "*-interpret" | "interpret"
    = platform default emulated), or None (defaulting rules in the
    module docstring).  ``interpret=True`` forces emulation;
    ``interpret=False`` pins the native structure (the caller takes
    responsibility for the platform).  With ``interpret`` unset, a
    native target off its own platform auto-emulates -- the historical
    "interpret off-TPU" fallback, now per-target.
    """
    if isinstance(spec, BackendTarget):
        target = spec
    else:
        if spec is None:
            spec = _OVERRIDE or os.environ.get(BACKEND_ENV) or None
        if spec is None:
            plat = jax.default_backend()
            target = TPU if plat == "tpu" else (
                GPU if plat == "gpu" else TPU_INTERPRET)
        else:
            name = _ALIASES.get(spec, spec)
            if name == "interpret":
                plat = jax.default_backend()
                target = (GPU if plat == "gpu" else TPU).emulated()
            elif name in TARGETS:
                target = TARGETS[name]
            else:
                raise ValueError(
                    f"unknown backend {spec!r}; expected one of "
                    f"{tuple(TARGETS)} or {tuple(_ALIASES)} or "
                    f"'interpret'")
    if interpret is True:
        return target.emulated()
    if interpret is False:
        return target.native()
    if not target.interpret and jax.default_backend() != target.kind:
        return target.emulated()
    return target


def stream_tiles(src_ref, bufs_ref, sems, *, srcs_for, lin, total,
                 stages):
    """One sequential-grid step of software-pipelined tile streaming
    (the TPU structure's async-copy double/multi buffer).

    ``src_ref`` is the state parked whole in ``pltpu.ANY``;
    ``bufs_ref`` is VMEM scratch ``(stages, n_tiles, th, tw)`` and
    ``sems`` a matching ``(stages, n_tiles)`` DMA semaphore array.
    ``srcs_for(step)`` returns the (tile_row, tile_col) indices of the
    ``n_tiles`` tiles step ``step`` consumes (``step`` may be a traced
    scalar or a static int -- prologue decodes constant-fold).

    Grid step ``lin`` (of ``total``) waits on its own copies -- started
    ``stages - 1`` steps earlier, or in the step-0 prologue -- then
    starts the copies for step ``lin + stages - 1`` so they fly during
    this step's compute, and returns the current tiles.  Tile indices
    are clamped into the source's range, so prefetches past the grid
    (and fetches of masked-off neighbour slots) read in-bounds garbage
    that the caller's validity masking discards.  Consumption order is
    exactly the synchronous order: results are bit-identical."""
    n_tiles, th, tw = (int(bufs_ref.shape[1]), int(bufs_ref.shape[2]),
                       int(bufs_ref.shape[3]))
    nr = int(src_ref.shape[0]) // th
    nc = int(src_ref.shape[1]) // tw

    def copy(slot, j, ty, tx):
        ty = jnp.clip(ty, 0, nr - 1)
        tx = jnp.clip(tx, 0, nc - 1)
        return pltpu.make_async_copy(
            src_ref.at[pl.ds(ty * th, th), pl.ds(tx * tw, tw)],
            bufs_ref.at[slot, j], sems.at[slot, j])

    def start_all(step, slot):
        for j, (ty, tx) in enumerate(srcs_for(step)):
            copy(slot, j, ty, tx).start()

    @pl.when(lin == 0)
    def _():
        # prologue: fill the first stages-1 buffer slots (static step
        # ids, so the step-0 decode folds to constants)
        for i in range(min(stages - 1, total)):
            start_all(i, i)

    nxt = lin + (stages - 1)

    @pl.when(nxt < total)
    def _():
        start_all(jnp.minimum(nxt, total - 1), jax.lax.rem(nxt, stages))

    slot = jax.lax.rem(lin, stages)
    tiles = []
    for j, (ty, tx) in enumerate(srcs_for(lin)):
        copy(slot, j, ty, tx).wait()
        tiles.append(bufs_ref[slot, j])
    return tiles


def full_spec(shape) -> pl.BlockSpec:
    """BlockSpec handing the kernel the whole operand (the GPU targets'
    HBM-resident view: one block covering the array, pinned at the
    origin for every grid step)."""
    nd = len(shape)
    return pl.BlockSpec(tuple(shape), lambda *_: (0,) * nd)


# ---------------------------------------------------------------------------
# emission observer (the access sanitizer's hook)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EmitRecord:
    """What one :func:`emit` call is about to lower -- handed to the
    installed emit hook so it can instrument the launch (the analysis
    sanitizer wraps index maps and the kernel body) and observe calls.
    ``aliases`` is the array-operand-keyed mapping, before the table
    shift."""

    plan: object
    in_specs: tuple
    out_specs: object
    out_shape: object
    aliases: dict
    nsp: int
    interpret: bool


_EMIT_HOOK = None


def set_emit_hook(hook):
    """Install an emission observer; returns the previous hook.  The
    hook sees every *interpreted* launch: ``instrument(record, kernel,
    in_specs, out_specs)`` may return replacements, and ``wrap_call``
    wraps the emitted callable.  ``None`` uninstalls."""
    global _EMIT_HOOK
    prev = _EMIT_HOOK
    _EMIT_HOOK = hook
    return prev


# ---------------------------------------------------------------------------
# the emitter: every plan-driven pallas_call in the repo goes through
# here, and this is the only module that constructs a grid spec.
# ---------------------------------------------------------------------------

def emit(plan, kernel: Callable, *, in_specs, out_specs, out_shape,
         scratch_shapes=(), input_output_aliases: Optional[dict] = None,
         interpret: Optional[bool] = None,
         num_warps: Optional[int] = None,
         num_stages: Optional[int] = None, **kwargs) -> Callable:
    """Build the ``pl.pallas_call`` for ``plan`` on its target.

    ``kernel(coords, *refs)`` is lowering- and target-agnostic at the
    signature level; the wrapper injects the decoded
    :class:`~repro.core.plan.BlockCoords` and routes the plan's decode
    tables (``plan.num_scalar_prefetch`` of them) the way the target
    supports:

    * scalar prefetch (TPU): ``PrefetchScalarGridSpec``, tables
      readable from index maps and the kernel prologue;
    * regular operands (GPU): tables become leading full-array HBM
      operands -- index maps cannot see them, so gpu-structured kernels
      do their own tile addressing via ``plan.storage_index`` /
      ``plan.neighbor_index`` with ``coords.grid_ids`` /
      ``coords.refs``.

    ``input_output_aliases`` is keyed on the *array* operands (tables
    excluded); the emitter shifts it.  When :meth:`plan.bound_prefetch`
    returns tables the returned callable takes just the array operands;
    when it returns ``None`` the caller passes the tables first
    (sharded plans, whose tables are per-device ``shard_map``
    operands).
    """
    target = plan.target
    interp = target.interpret if interpret is None else interpret
    if scratch_shapes and not target.supports_scratch:
        raise ValueError(
            f"target {target.name!r} has no scratch memory; "
            f"gpu-structured kernels keep state in loop carries")
    aliases = {int(i): int(o)
               for i, o in (input_output_aliases or {}).items()}
    nsp = plan.num_scalar_prefetch
    extra = dict(kwargs)
    extra.update(target.call_kwargs(num_warps, num_stages))

    record = None
    if _EMIT_HOOK is not None and interp:
        record = EmitRecord(plan=plan, in_specs=tuple(in_specs),
                            out_specs=out_specs, out_shape=out_shape,
                            aliases=dict(aliases), nsp=nsp,
                            interpret=interp)
        kernel, in_specs, out_specs = _EMIT_HOOK.instrument(
            record, kernel, in_specs, out_specs)
        hook = _EMIT_HOOK

        def _wrap(fn):
            return hook.wrap_call(record, fn)
    else:
        def _wrap(fn):
            return fn

    if nsp == 0:
        def wrapped(*refs):
            kernel(plan.kernel_coords(), *refs)

        call = pl.pallas_call(
            wrapped, grid=plan.grid, in_specs=list(in_specs),
            out_specs=out_specs, out_shape=out_shape,
            scratch_shapes=list(scratch_shapes),
            input_output_aliases=aliases, interpret=interp, **extra)
        return _wrap(lambda *operands: call(*operands))

    def wrapped(*args):
        kernel(plan.kernel_coords(*args[:nsp]), *args[nsp:])

    # operand indices count the tables as inputs 0..nsp either way
    aliases = {i + nsp: o for i, o in aliases.items()}

    if target.has_scalar_prefetch:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=nsp,
            grid=plan.grid,
            in_specs=list(in_specs),
            out_specs=out_specs,
            scratch_shapes=list(scratch_shapes),
        )
        call = pl.pallas_call(
            wrapped, grid_spec=grid_spec, out_shape=out_shape,
            input_output_aliases=aliases, interpret=interp, **extra)
    else:
        def call(*args):
            # table shapes are only known at call time (sharded chunks
            # arrive pre-split by shard_map); build the call lazily --
            # these closures only ever run under jit, so construction
            # cost is per-trace, not per-step.
            tspecs = [full_spec(t.shape) for t in args[:nsp]]
            c = pl.pallas_call(
                wrapped, grid=plan.grid,
                in_specs=tspecs + list(in_specs),
                out_specs=out_specs, out_shape=out_shape,
                input_output_aliases=aliases, interpret=interp, **extra)
            return c(*args)

    bound = plan.bound_prefetch()
    if bound is None:
        return _wrap(lambda *operands: call(*operands))
    return _wrap(lambda *operands: call(*bound, *operands))
