"""Block-space domains: compact grid enumerations of structured-sparse
block sets, generalizing the paper's lambda(w) beyond fractals.

A BlockDomain answers two questions for a Pallas (or XLA-level) kernel:

  * ``num_blocks`` -- how many grid steps to launch (the paper's
    parallel-space volume), and
  * ``block_coords(i)`` -- traceable scalar int math mapping the linear
    grid index to the 2-D block coordinate in the *embedded* space (the
    paper's lambda).

The bounding-box baseline is itself a domain, so every kernel can A/B
exactly as the paper does.  ``coords_host()`` gives the same enumeration
as a host numpy array, used for (a) oracle tests and (b) the
scalar-prefetch lookup-table variant (the TPU analogue of the paper's
"shared lookup table" intra-block option).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from . import fractal as F


class BlockDomain:
    """Interface; block coords are (bx, by) with y the row (downwards)."""

    name: str = "abstract"

    @property
    def num_blocks(self) -> int:
        raise NotImplementedError

    def block_coords(self, i):
        """Linear grid index -> (bx, by); must be jax-traceable int math."""
        raise NotImplementedError

    def contains(self, bx, by):
        """Membership test in the embedded block space (traceable)."""
        raise NotImplementedError

    def coords_host(self) -> np.ndarray:
        """(num_blocks, 2) int32 enumeration on host (oracle + lookup table)."""
        i = np.arange(self.num_blocks, dtype=np.int64)
        bx, by = self.block_coords(i)
        return np.stack([np.asarray(bx), np.asarray(by)], -1).astype(np.int32)

    def space_efficiency(self) -> float:
        """Fraction of bounding-box blocks that are real work (Theorem 2)."""
        bb = self.bounding_box
        return self.num_blocks / float(bb[0] * bb[1])

    @property
    def bounding_box(self) -> Tuple[int, int]:
        raise NotImplementedError


class BoundingBoxDomain(BlockDomain):
    """The paper's baseline: launch every block of the n_b x n_b box and
    let the kernel discard non-members at run time."""

    name = "bounding-box"

    def __init__(self, nbx: int, nby: int, member=None):
        self.nbx, self.nby = nbx, nby
        self._member = member

    @property
    def num_blocks(self) -> int:
        return self.nbx * self.nby

    @property
    def bounding_box(self):
        return (self.nbx, self.nby)

    def block_coords(self, i):
        return i % self.nbx, i // self.nbx

    def contains(self, bx, by):
        if self._member is None:
            return (bx == bx)  # all true, shape-following
        return self._member(bx, by)


class SierpinskiDomain(BlockDomain):
    """The paper, faithfully: 3**r_b blocks mapped by lambda (Eq. 4-10)."""

    name = "sierpinski"

    def __init__(self, n_b: int):
        self.n_b = n_b
        self.r_b = F.scale_level(n_b)

    @property
    def num_blocks(self) -> int:
        return 3 ** self.r_b

    @property
    def bounding_box(self):
        return (self.n_b, self.n_b)

    def block_coords(self, i):
        return F.lambda_map_linear(i, self.r_b)

    def contains(self, bx, by):
        return F.is_member(bx, by, self.n_b)


class GeneralizedFractalDomain(BlockDomain):
    """Paper SS V future-work question 1: any F^{k,s} digit-unrolled fractal."""

    name = "generalized-fractal"

    def __init__(self, spec: F.FractalSpec, n_b: int):
        self.spec = spec
        self.n_b = n_b
        self.r_b = spec.scale_level(n_b)
        self.name = f"fractal:{spec.name}"

    @property
    def num_blocks(self) -> int:
        return self.spec.k ** self.r_b

    @property
    def bounding_box(self):
        return (self.n_b, self.n_b)

    def block_coords(self, i):
        return self.spec.lambda_map_linear(i, self.r_b)

    def contains(self, bx, by):
        g = self.spec.membership_grid(self.n_b)
        return jnp.asarray(g)[by, bx]


def _isqrt(x):
    """Traceable integer sqrt for the triangular decode (related work [18]
    solves an order-m equation; here m=2 so it is a square root).  float32
    sqrt + correction steps is exact for x < 2**24, i.e. block grids up to
    m ~ 5790 (seq 2.9M at 512-token blocks) -- asserted by the domains."""
    x = jnp.asarray(x, jnp.int32)
    s = jnp.asarray(jnp.floor(jnp.sqrt(jnp.asarray(x, jnp.float32))), jnp.int32)
    for _ in range(2):
        s = jnp.where((s + 1) * (s + 1) <= x, s + 1, s)
        s = jnp.where(s * s > x, s - 1, s)
    return s


class TriangularDomain(BlockDomain):
    """Causal (lower-triangular) block domain over m x m blocks: the
    2-simplex case of the authors' block-space program, and the domain of
    causal attention.  T(m) = m(m+1)/2 blocks instead of m**2."""

    name = "triangular"

    def __init__(self, m: int):
        if m * (m + 1) // 2 >= 2 ** 24:
            raise ValueError("triangular decode exact only below 2**24 blocks")
        self.m = m

    @property
    def num_blocks(self) -> int:
        return self.m * (self.m + 1) // 2

    @property
    def bounding_box(self):
        return (self.m, self.m)

    def block_coords(self, i):
        # row q = floor((sqrt(8i+1)-1)/2); col k = i - q(q+1)/2  (k <= q)
        q = (_isqrt(8 * jnp.asarray(i, jnp.int32) + 1) - 1) // 2
        k = jnp.asarray(i, jnp.int32) - q * (q + 1) // 2
        if isinstance(i, (int, np.integer)):
            return int(k), int(q)
        return k, q  # (bx=key block, by=query block)

    def contains(self, bx, by):
        return bx <= by


class BandDomain(BlockDomain):
    """Sliding-window (local) attention block domain: key block kj in
    [max(0, qi-w+1), qi] for each query block qi.  Blocks:
    T(w) + (m-w)*w   vs   bounding box m**2."""

    name = "band"

    def __init__(self, m: int, w: int):
        if w > m:
            w = m
        self.m, self.w = m, w
        self._tw = w * (w + 1) // 2

    @property
    def num_blocks(self) -> int:
        return self._tw + (self.m - self.w) * self.w

    @property
    def bounding_box(self):
        return (self.m, self.m)

    def block_coords(self, i):
        i = jnp.asarray(i, jnp.int32)
        tw = self._tw
        # triangular head (rows 0..w-1), then dense band rows of width w
        q_tri = (_isqrt(8 * i + 1) - 1) // 2
        k_tri = i - q_tri * (q_tri + 1) // 2
        j = i - tw
        q_band = self.w + j // self.w
        k_band = q_band - self.w + 1 + j % self.w
        in_tri = i < tw
        q = jnp.where(in_tri, q_tri, q_band)
        k = jnp.where(in_tri, k_tri, k_band)
        return k, q

    def contains(self, bx, by):
        return (bx <= by) & (bx > by - self.w)


def make_attention_domain(kind: str, m_q: int, m_k: int, window_blocks: int = 0):
    """Factory used by the attention kernels.

    kind: "causal" -> TriangularDomain (requires m_q == m_k),
          "local"  -> BandDomain,
          "full"   -> BoundingBoxDomain (bidirectional / baseline).
    """
    if kind == "causal":
        if m_q != m_k:
            raise ValueError("causal triangular domain needs square block grid")
        return TriangularDomain(m_q)
    if kind == "local":
        return BandDomain(m_q, window_blocks)
    if kind == "full":
        return BoundingBoxDomain(m_k, m_q)
    raise ValueError(kind)
