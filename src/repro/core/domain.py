"""Block-space domains: compact grid enumerations of structured-sparse
block sets, generalizing the paper's lambda(w) beyond fractals.

A BlockDomain answers two questions for a Pallas (or XLA-level) kernel:

  * ``num_blocks`` -- how many grid steps to launch (the paper's
    parallel-space volume), and
  * ``block_coords(i)`` -- traceable scalar int math mapping the linear
    grid index to the 2-D block coordinate in the *embedded* space (the
    paper's lambda).

The bounding-box baseline is itself a domain, so every kernel can A/B
exactly as the paper does.  ``coords_host()`` gives the same enumeration
as a host numpy array, used for (a) oracle tests and (b) the
scalar-prefetch lookup-table variant (the TPU analogue of the paper's
"shared lookup table" intra-block option).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from . import fractal as F


class BlockDomain:
    """Interface; block coords are (bx, by) with y the row (downwards)."""

    name: str = "abstract"
    #: True when every bounding-box block is a member (no run-time
    #: discard needed even under the "bounding" lowering).
    always_member: bool = False

    @property
    def cache_key(self):
        """Hashable identity for host-table memoization
        (:mod:`repro.core.memo`), or None when the instance cannot
        guarantee one (e.g. closures over arbitrary membership
        callables)."""
        return None

    @property
    def num_blocks(self) -> int:
        raise NotImplementedError

    def block_coords(self, i):
        """Linear grid index -> (bx, by); must be jax-traceable int math."""
        raise NotImplementedError

    def linear_index(self, bx, by):
        """Member block coords -> linear grid index (the inverse of
        ``block_coords``; traceable int math).  Undefined garbage -- but
        still in-range after clamping -- for non-member coords; compact
        storage index maps rely only on the member case."""
        raise NotImplementedError

    def contains(self, bx, by):
        """Membership test in the embedded block space (traceable)."""
        raise NotImplementedError

    def cell_member(self, gx, gy, n: int):
        """Cell-level membership of the embedded n x n grid (traceable);
        only meaningful for domains with intra-block structure (fractals).
        Default: every cell of a member block is live."""
        return (gx == gx)  # all true, shape-following

    def coords_host(self) -> np.ndarray:
        """(num_blocks, 2) int32 enumeration on host (oracle + the
        scalar-prefetch lookup table).  Memoized per instance -- the
        table is re-read per GridPlan launch."""
        cached = getattr(self, "_coords_host", None)
        if cached is None:
            i = np.arange(self.num_blocks, dtype=np.int64)
            bx, by = self.block_coords(i)
            cached = np.stack(
                [np.asarray(bx), np.asarray(by)], -1).astype(np.int32)
            cached.setflags(write=False)
            self._coords_host = cached
        return cached

    def space_efficiency(self) -> float:
        """Fraction of bounding-box blocks that are real work (Theorem 2)."""
        bb = self.bounding_box
        return self.num_blocks / float(bb[0] * bb[1])

    @property
    def bounding_box(self) -> Tuple[int, int]:
        raise NotImplementedError


class BoundingBoxDomain(BlockDomain):
    """The paper's baseline: launch every block of the n_b x n_b box and
    let the kernel discard non-members at run time."""

    name = "bounding-box"

    def __init__(self, nbx: int, nby: int, member=None):
        self.nbx, self.nby = nbx, nby
        self._member = member
        self.always_member = member is None

    @property
    def cache_key(self):
        if self._member is not None:
            return None  # membership closure: identity not capturable
        return ("bounding-box", self.nbx, self.nby)

    @property
    def num_blocks(self) -> int:
        return self.nbx * self.nby

    @property
    def bounding_box(self):
        return (self.nbx, self.nby)

    def block_coords(self, i):
        return i % self.nbx, i // self.nbx

    def linear_index(self, bx, by):
        return by * self.nbx + bx

    def contains(self, bx, by):
        if self._member is None:
            return (bx == bx)  # all true, shape-following
        return self._member(bx, by)


class SierpinskiDomain(BlockDomain):
    """The paper, faithfully: 3**r_b blocks mapped by lambda (Eq. 4-10)."""

    name = "sierpinski"

    def __init__(self, n_b: int):
        self.n_b = n_b
        self.r_b = F.scale_level(n_b)

    @property
    def cache_key(self):
        return ("sierpinski", self.n_b)

    @property
    def num_blocks(self) -> int:
        return 3 ** self.r_b

    @property
    def bounding_box(self):
        return (self.n_b, self.n_b)

    def block_coords(self, i):
        return F.lambda_map_linear(i, self.r_b)

    def linear_index(self, bx, by):
        # per scale level the base-3 digit is the bit-pair sum
        # (0,0)->0 (0,1)->1 (1,1)->2; see F.lambda_inverse
        i = bx * 0
        for mu in range(1, self.r_b + 1):
            b = ((bx >> (mu - 1)) & 1) + ((by >> (mu - 1)) & 1)
            i = i + b * 3 ** (mu - 1)
        return i

    def contains(self, bx, by):
        return F.is_member(bx, by, self.n_b)

    def cell_member(self, gx, gy, n: int):
        return F.is_member(gx, gy, n)


class GeneralizedFractalDomain(BlockDomain):
    """Paper SS V future-work question 1: any F^{k,s} digit-unrolled fractal."""

    name = "generalized-fractal"

    def __init__(self, spec: F.FractalSpec, n_b: int):
        self.spec = spec
        self.n_b = n_b
        self.r_b = spec.scale_level(n_b)
        self.name = f"fractal:{spec.name}"

    @property
    def cache_key(self):
        return ("fractal", self.spec.name, self.n_b)

    @property
    def num_blocks(self) -> int:
        return self.spec.k ** self.r_b

    @property
    def bounding_box(self):
        return (self.n_b, self.n_b)

    def block_coords(self, i):
        return self.spec.lambda_map_linear(i, self.r_b)

    def linear_index(self, bx, by):
        return self.spec.linear_index(bx, by, self.r_b)

    def contains(self, bx, by):
        # the coarse block grid is the same fractal at level r_b, so the
        # digit test replaces the dense membership_grid(n_b) this used to
        # rebuild on every (traced) call
        return self.spec.is_member(bx, by, self.n_b)

    def cell_member(self, gx, gy, n: int):
        return self.spec.is_member(gx, gy, n)


def _is_host(x) -> bool:
    return isinstance(x, (int, np.integer, np.ndarray))


def _isqrt(x):
    """Integer sqrt for the triangular decode (related work [18] solves
    an order-m equation; here m=2 so it is a square root).  float32
    sqrt + correction steps is exact for x < 2**24, i.e. block grids up
    to m ~ 5790 (seq 2.9M at 512-token blocks) -- asserted by the
    domains.  Dispatches on input type so the same decode runs traced
    (jit / Pallas index_map) and on host (coords_host table builds)."""
    if _is_host(x):
        x = np.asarray(x, np.int64)
        s = np.floor(np.sqrt(x.astype(np.float64))).astype(np.int64)
        s = np.where((s + 1) * (s + 1) <= x, s + 1, s)
        return np.where(s * s > x, s - 1, s)
    x = jnp.asarray(x, jnp.int32)
    s = jnp.asarray(jnp.floor(jnp.sqrt(jnp.asarray(x, jnp.float32))), jnp.int32)
    for _ in range(2):
        s = jnp.where((s + 1) * (s + 1) <= x, s + 1, s)
        s = jnp.where(s * s > x, s - 1, s)
    return s


class TriangularDomain(BlockDomain):
    """Causal (lower-triangular) block domain over m x m blocks: the
    2-simplex case of the authors' block-space program, and the domain of
    causal attention.  T(m) = m(m+1)/2 blocks instead of m**2."""

    name = "triangular"

    def __init__(self, m: int):
        if m * (m + 1) // 2 >= 2 ** 24:
            raise ValueError("triangular decode exact only below 2**24 blocks")
        self.m = m

    @property
    def cache_key(self):
        return ("triangular", self.m)

    @property
    def num_blocks(self) -> int:
        return self.m * (self.m + 1) // 2

    @property
    def bounding_box(self):
        return (self.m, self.m)

    def block_coords(self, i):
        # row q = floor((sqrt(8i+1)-1)/2); col k = i - q(q+1)/2  (k <= q)
        if not _is_host(i):
            i = jnp.asarray(i, jnp.int32)
        q = (_isqrt(8 * i + 1) - 1) // 2
        k = i - q * (q + 1) // 2
        if isinstance(i, (int, np.integer)):
            return int(k), int(q)
        return k, q  # (bx=key block, by=query block)

    def linear_index(self, bx, by):
        return by * (by + 1) // 2 + bx

    def contains(self, bx, by):
        return bx <= by


class BandDomain(BlockDomain):
    """Sliding-window (local) attention block domain: key block kj in
    [max(0, qi + off - w + 1), qi + off] for each query block qi, with
    ``off = m_k - m_q`` (queries are the *last* m_q rows of the key
    grid -- the decode convention; off = 0 is square self-attention).

    Square blocks: T(w) + (m-w)*w vs bounding box m**2.  Rectangular
    (off > 0) requires off >= w - 1 so every row sees a full window:
    m*w blocks, and the key-block support shrinks to the *last*
    m + w - 1 key blocks -- the compact sliding-window KV cache."""

    name = "band"

    def __init__(self, m: int, w: int, m_k: int = None):
        if w < 1:
            raise ValueError(
                f"band window must be at least 1 block, got w={w}: a "
                f"0-wide band has no blocks and its decode divides by "
                f"zero")
        m_k = m if m_k is None else m_k
        if m_k < m:
            raise ValueError(f"band domain needs m_k >= m_q, got "
                             f"m_k={m_k} < m_q={m}")
        self.off = m_k - m
        if self.off == 0 and w > m:
            w = m
        if self.off and self.off < w - 1:
            raise ValueError(
                f"rectangular band needs m_k - m_q >= w - 1 (every query "
                f"row sees a full window), got off={self.off}, w={w}")
        self.m, self.w, self.m_k = m, w, m_k
        self._tw = w * (w + 1) // 2
        if self.off == 0 and m * (m + 1) // 2 >= 2 ** 24:
            raise ValueError("band decode exact only below 2**24 blocks")

    @property
    def cache_key(self):
        return ("band", self.m, self.w, self.m_k)

    @property
    def num_blocks(self) -> int:
        if self.off:
            return self.m * self.w
        return self._tw + (self.m - self.w) * self.w

    @property
    def bounding_box(self):
        return (self.m_k, self.m)

    def block_coords(self, i):
        if _is_host(i):
            where, i = np.where, np.asarray(i, np.int64)
        else:
            where, i = jnp.where, jnp.asarray(i, jnp.int32)
        if self.off:
            q = i // self.w
            k = self.off + q - self.w + 1 + i % self.w
            return k, q
        tw = self._tw
        # triangular head (rows 0..w-1), then dense band rows of width w
        q_tri = (_isqrt(8 * i + 1) - 1) // 2
        k_tri = i - q_tri * (q_tri + 1) // 2
        j = i - tw
        # clamp to >= 0 so host int overflow / traced negatives in the
        # head region stay inert before the select
        jw = where(j < 0, 0, j)
        q_band = self.w + jw // self.w
        k_band = q_band - self.w + 1 + jw % self.w
        in_tri = i < tw
        q = where(in_tri, q_tri, q_band)
        k = where(in_tri, k_tri, k_band)
        return k, q

    def linear_index(self, bx, by):
        if self.off:
            return by * self.w + (bx - (self.off + by - self.w + 1))
        where = np.where if _is_host(bx) else jnp.where
        return where(by < self.w, by * (by + 1) // 2 + bx,
                     self._tw + (by - self.w) * self.w
                     + (bx - (by - self.w + 1)))

    def contains(self, bx, by):
        return (bx <= by + self.off) & (bx > by + self.off - self.w)


def make_fractal_domain(fractal: str, n_b: int) -> BlockDomain:
    """Factory used by the embedded-fractal kernels (write / CA).

    fractal: "sierpinski-gasket" (the paper's gasket, O(1) bit-test
    membership) or any registered FractalSpec name ("sierpinski-carpet",
    "vicsek-cross", ... -- O(r*k) digit-test membership)."""
    if fractal in ("sierpinski", "sierpinski-gasket"):
        return SierpinskiDomain(n_b)
    if fractal not in F.FRACTALS:
        raise ValueError(
            f"unknown fractal {fractal!r}; registered: "
            f"{tuple(F.FRACTALS)}")
    return GeneralizedFractalDomain(F.FRACTALS[fractal], n_b)


def make_attention_domain(kind: str, m_q: int, m_k: int,
                          window_blocks: int = None):
    """Factory used by the attention kernels.

    kind: "causal" -> TriangularDomain (requires m_q == m_k),
          "local"  -> BandDomain (``window_blocks`` is REQUIRED and must
                      be >= 1: a defaulted 0-block window used to build a
                      degenerate domain whose decode divided by zero),
          "full"   -> BoundingBoxDomain (bidirectional / baseline).
    """
    if kind == "causal":
        if m_q != m_k:
            raise ValueError("causal triangular domain needs square block grid")
        return TriangularDomain(m_q)
    if kind == "local":
        if window_blocks is None or window_blocks < 1:
            raise ValueError(
                f"kind='local' requires window_blocks >= 1, got "
                f"{window_blocks!r}")
        return BandDomain(m_q, window_blocks, m_k)
    if kind == "full":
        return BoundingBoxDomain(m_k, m_q)
    raise ValueError(kind)
