"""Mesh-aware block-space execution: ShardedPlan partitions a
BlockDomain across one axis of a ``jax.sharding.Mesh`` and lowers each
device's sub-domain through the existing GridPlan paths (closed_form /
prefetch_lut / bounding) inside ``shard_map``.

Partitions
----------

``"storage-rows"`` (compact storage)
    The packed orthotope of :class:`~repro.core.compact.CompactLayout`
    is split into D contiguous *slot-row* slabs (supertile rows under
    ``coarsen``, via the existing :class:`SuperTiling` geometry), padded
    to a common height.  Each device holds only its slab -- per-device
    memory is O(n^H / D) + halo -- and enumerates its slots row-major:
    the closed-form decode is ``lambda(w_x, w_y)`` evaluated directly on
    the orthotope coordinate (``FractalSpec.lambda_map``), i.e. the
    paper's map re-rooted at the device's first packed row.  Because the
    fractal orthotope is dense (Lemma 2: num_slots == num_blocks), equal
    row slabs are an exactly balanced work partition.

``"linear"`` (embedded storage)
    The canonical lambda-order enumeration [0, num_blocks) is split into
    D contiguous ranges -- sharding the paper's *parallel space* itself.
    State arrays stay replicated (they are already the dense O(n^2)
    layout); each device computes its range and the driver combines with
    a disjoint-ownership-mask ``psum`` (exact: every cell has exactly
    one nonzero contributor).

``"rows"`` (attention: the query-block axis)
    Query-block rows are split into D contiguous bands; the domains'
    canonical enumerations are row-major in the query block, so each
    band is a contiguous linear range and the closed-form decode is the
    parent decode at a per-device offset.  Q and O shard along the
    sequence axis; K/V stay replicated.

``"zigzag"`` (attention: balanced causal bands)
    Contiguous bands are pathological for *causal* attention: row ``j``
    of a triangular domain holds ``j + 1`` key blocks, so the last
    device does ~``(2D - 1)/1`` times the work of the first.  The
    zig-zag (snake) assignment gives device ``d`` rows ``{j : min(r,
    2D-1-r) == d}`` with ``r = j mod 2D`` -- pairing light row ``k*2D +
    d`` with heavy row ``k*2D + (2D-1-d)`` so every pair contributes
    ``(2k)*2D + 2D + 1`` blocks *independent of d*: with ``nby % 2D ==
    0`` (enforced) the split is exactly balanced.  The owned rows are
    scattered, so the per-device enumeration is table-backed
    (prefetch_lut / mma chunks carry global coords; ``bounding``
    reconstructs the global row from the device id in the shard table);
    the local row of global ``j`` is the closed form ``2*(j // 2D) + (r
    >= D)``, used by ``_place_coords`` to address the device's Q/O
    band.  Drivers permute Q block rows into the device-concatenated
    snake order before shard_map and inverse-permute O after
    (:func:`zigzag_row_order`).

Per-device parameters inside SPMD
---------------------------------

``shard_map`` traces one program for all devices, so anything
device-dependent must arrive through *sharded operands*.  Every sharded
lowering therefore carries one extra scalar-prefetch operand, the
**shard table** -- ``[lo_or_row_lo, count, ...]`` plus, under compact
storage, the ghost-row map -- and ``prefetch_lut`` additionally ships
its (per-device, padded) decode LUT.  Validity of a grid step
(padding, uneven splits, ownership under ``bounding``) is folded into
``BlockCoords.valid``, which every kernel already honours.

Halo exchange (compact CA)
--------------------------

A slab's blocks have embedded neighbours whose lambda^-1-resolved slots
may live in other devices' slabs -- and orthotope row distance is not
embedded distance, so the ghost rows of a slab are a *scattered* set of
remote rows.  :class:`HaloPlan` resolves them host-side from the
layout's neighbour tables, and exchanges exactly those rows between
launches with one ``jax.lax.ppermute`` per active device offset; the
kernel then reads ``[local slab ++ ghost rows ++ dump row]`` through the
shard table's ghost map.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import memo
from .compact import NEIGHBOR_OFFSETS8
from .domain import BlockDomain
from .plan import _LUT_NBR, GridPlan

PARTITIONS = ("linear", "rows", "storage-rows", "zigzag")

#: shard-table column layout (i32): [0] the device's linear offset
#: (linear/rows) or first owned storage row (storage-rows); [1] the
#: number of valid grid steps / owned blocks; [2] the first owned
#: query-block row ("rows") or the device index ("zigzag") -- then, for
#: "storage-rows", the ghost map (global storage row -> row of the
#: device's extended local array).
SHARD_LO = 0
SHARD_COUNT = 1
SHARD_ROWLO = 2
SHARD_DEV = 2
SHARD_GMAP = 2


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _widen(spans: dict, key, lo: int, hi: int) -> None:
    """Grow ``spans[key]`` to cover the half-open column span
    [lo, hi)."""
    if key in spans:
        plo, phi = spans[key]
        spans[key] = (min(plo, lo), max(phi, hi))
    else:
        spans[key] = (lo, hi)


class HaloPlan:
    """Host-resolved ghost-row exchange for a storage-row partition.

    For each device: which global storage rows (supertile rows under
    coarsening) its halo needs (``ghost_rows``), and the padded
    send/recv index tables the ``ppermute`` rounds use.  ``h_max``
    ghost rows (+1 dump row for padding traffic and never-needed rows)
    bound the halo memory.

    Rounds are keyed (device offset ``delta``, strip class): the
    trapezoid update reads a ``dy = +1`` neighbour's *top* ``h`` cell
    rows and a ``dy = -1`` neighbour's *bottom* ``h`` rows (``h`` =
    the fuse depth), so a ghost row whose readers all sit on one side
    ships only that strip instead of its full ``row_unit`` height.
    ``dy = 0`` readers (and packed supertiles, whose cell rows are not
    embedded-ordered -- ``plan.tile_map() is not None``) force the
    full row.  Orthogonally, each entry ships only the *occupied
    column window*: the span of slot columns its receiver's readers
    actually resolve (``col_span``), widened to the round's max width
    ``wcols`` and clamped into ``[0, ncols)`` so every payload in a
    round has one static shape.  Unshipped strip/column cells stay
    zero and are never read by a valid step.  The partition of each
    device's steps into *interior*
    (all 8 neighbour rows local) and *boundary* (any ghost neighbour)
    -- ``int_steps`` / ``bnd_steps`` -- is what lets a driver overlap
    the exchange with interior compute (:meth:`ShardedPlan.phase_view`).
    """

    def __init__(self, plan: "ShardedPlan", with_halo: bool):
        D, rpd, nrows = plan.num_shards, plan.rpd, plan.nrows
        self.ghost_rows = [[] for _ in range(D)]
        self.row_class = [dict() for _ in range(D)]
        self.col_span = [dict() for _ in range(D)]  # (g, cls) -> (lo, hi)
        self.int_steps = None
        self.bnd_steps = None
        if with_halo:
            if plan._tiling is not None:
                own = plan._tiling.tiles_host()
                nbrs = plan._tiling.neighbor_tiles_host()
            else:
                own = plan.layout.slots_host()
                nbrs = plan.layout.neighbor_slots_host()
            rows = own[:, 1]
            strips = plan.tile_map() is None
            self.int_steps = [[] for _ in range(D)]
            self.bnd_steps = [[] for _ in range(D)]
            for d in range(D):
                lo, hi = d * rpd, min((d + 1) * rpd, nrows)
                sel = (rows >= lo) & (rows < hi)
                nb, mine = nbrs[sel], own[sel]
                cls = self.row_class[d]
                span = self.col_span[d]
                for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS8):
                    rem = (nb[:, j, 2] == 1) \
                        & ((nb[:, j, 1] < lo) | (nb[:, j, 1] >= hi))
                    gr, gc = nb[:, j, 1][rem], nb[:, j, 0][rem]
                    c = "top" if strips and dy == 1 else \
                        "bot" if strips and dy == -1 else "full"
                    for g in np.unique(gr):
                        cols = gc[gr == g]
                        cls.setdefault(int(g), set()).add(c)
                        _widen(span, (int(g), c),
                               int(cols.min()), int(cols.max()) + 1)
                for g, s in cls.items():
                    if "full" in s:
                        merged = [span.pop((g, c)) for c in s
                                  if (g, c) in span]
                        cls[g] = {"full"}
                        span[(g, "full")] = (
                            min(x for x, _ in merged),
                            max(y for _, y in merged))
                self.ghost_rows[d] = sorted(cls)
                remote = (nb[..., 2] == 1) \
                    & ((nb[..., 1] < lo) | (nb[..., 1] >= hi))
                t_ids = (mine[:, 1] - lo) * plan.ncols + mine[:, 0]
                bnd = remote.any(axis=1)
                self.int_steps[d] = sorted(int(t) for t in t_ids[~bnd])
                self.bnd_steps[d] = sorted(int(t) for t in t_ids[bnd])
        self.h_max = max((len(g) for g in self.ghost_rows), default=0)
        # ghost map: global row -> row of [slab ++ ghosts ++ dump]
        dump = rpd + self.h_max
        gmap = np.full((D, plan.nrows_pad), dump, np.int32)
        for d in range(D):
            lo = d * rpd
            for i in range(rpd):
                if lo + i < plan.nrows_pad:
                    gmap[d, lo + i] = i
            for p, g in enumerate(self.ghost_rows[d]):
                gmap[d, g] = rpd + p
        self.ghost_map = gmap
        # ppermute rounds: one per (device offset, strip class) with
        # any traffic
        self.rounds = []   # [(delta, cls, send (D, m), recv (D, m),
        #                     scol (D, m), rcol (D, m), wcols)]
        for delta in range(1, D):
            for cls in ("full", "top", "bot"):
                needs = [[g for g in self.ghost_rows[d]
                          if g // rpd == (d - delta) % D
                          and cls in self.row_class[d][g]]
                         for d in range(D)]
                m = max(len(x) for x in needs)
                if m == 0:
                    continue
                wc = max(hi_ - lo_ for d in range(D) for g in needs[d]
                         for lo_, hi_ in (self.col_span[d][(g, cls)],))
                send = np.zeros((D, m), np.int32)
                recv = np.full((D, m), self.h_max, np.int32)  # pad -> dump
                scol = np.zeros((D, m), np.int32)
                rcol = np.zeros((D, m), np.int32)
                for d in range(D):
                    for i, g in enumerate(needs[(d + delta) % D]):
                        send[d, i] = g - d * rpd  # local row at source
                        sp = self.col_span[(d + delta) % D][(g, cls)]
                        scol[d, i] = min(sp[0], plan.ncols - wc)
                    for i, g in enumerate(needs[d]):
                        recv[d, i] = self.ghost_rows[d].index(g)
                        sp = self.col_span[d][(g, cls)]
                        rcol[d, i] = min(sp[0], plan.ncols - wc)
                self.rounds.append(
                    (delta, cls, send, recv, scol, rcol, wc))

    def send_recv_host(self):
        """((send, recv, scol, rcol), ...) host tables, one 4-tuple
        per round; drivers pass them into shard_map sharded along the
        mesh axis.  ``scol``/``rcol`` are the clamped first slot
        column of each entry's shipped window (source / receiver side;
        equal by construction -- both resolve the receiver's span)."""
        return tuple((s, r, sc, rc)
                     for _, _, s, r, sc, rc, _ in self.rounds)

    def _strip(self, cls: str, RU: int, h: int):
        """(row offset, height) of one class's strip within a row."""
        if cls == "top":
            return 0, h
        if cls == "bot":
            return RU - h, h
        return 0, RU

    def exchange(self, plan: "ShardedPlan", local: jnp.ndarray,
                 send_recv, h: Optional[int] = None) -> jnp.ndarray:
        """Inside shard_map: run every ppermute round and return the
        ghost block ((h_max + 1), RU, W) = exchanged ghost rows ++ a
        zero-init dump row.  ``h`` is the strip height in cells (the
        launch fuse depth); ``None`` ships full rows.  Each entry
        ships only its ``wcols``-slot-column window (gathered at the
        sender's ``scol``, scattered at the receiver's ``rcol``); the
        rest of the ghost row stays zero.  Independent of the local
        compute, so a driver can launch interior work while the
        collective is in flight and :meth:`cat` afterwards."""
        rpd, RU = plan.rpd, plan.row_unit
        h = RU if h is None else min(int(h), RU)
        W = local.shape[-1]
        tw = W // plan.ncols  # cell columns per slot column
        rows = local.reshape(rpd, RU, W)
        ghost = jnp.zeros((self.h_max + 1, RU, W), local.dtype)
        D = plan.num_shards
        for (delta, cls, *_, wc), (send, recv, scol, rcol) in zip(
                self.rounds, send_recv):
            off, nr = self._strip(cls, RU, h)
            base = rows[send.reshape(-1), off:off + nr]  # (m, nr, W)
            cidx = (scol.reshape(-1)[:, None] * tw
                    + jnp.arange(wc * tw))               # (m, wc*tw)
            payload = jnp.take_along_axis(base, cidx[:, None, :],
                                          axis=2)
            got = jax.lax.ppermute(
                payload, plan.axis,
                [(s, (s + delta) % D) for s in range(D)])
            ri = recv.reshape(-1)
            rr = off + jnp.arange(nr)
            cc = rcol.reshape(-1)[:, None] * tw + jnp.arange(wc * tw)
            ghost = ghost.at[ri[:, None, None], rr[None, :, None],
                             cc[:, None, :]].set(got)
        return ghost

    def cat(self, plan: "ShardedPlan", local: jnp.ndarray,
            ghost: jnp.ndarray) -> jnp.ndarray:
        """local slab (rpd*RU, W) ++ ghost block -> extended array
        ((rpd + h_max + 1)*RU, W) the kernels address via the shard
        table's ghost map."""
        rpd, RU = plan.rpd, plan.row_unit
        W = local.shape[-1]
        rows = local.reshape(rpd, RU, W)
        return jnp.concatenate([rows, ghost], axis=0).reshape(
            (rpd + self.h_max + 1) * RU, W)

    def extend(self, plan: "ShardedPlan", local: jnp.ndarray,
               send_recv, h: Optional[int] = None) -> jnp.ndarray:
        """exchange + cat: the synchronous (non-overlapped) path."""
        return self.cat(plan, local, self.exchange(plan, local,
                                                   send_recv, h))

    def bytes_exchanged(self, plan: "ShardedPlan", block: int,
                        h: Optional[int] = None,
                        itemsize: int = 4) -> dict:
        """Payload bytes one exchange moves across the whole mesh:
        ``trimmed`` (what :meth:`exchange` ships -- strip height ``h``
        x the per-round occupied column window, padding included) vs
        ``strips`` (strip-trimmed but full-width rows) vs
        ``full_rows`` (the pre-trim scheme: every ghost row at full
        row_unit height and width)."""
        plan.bind_block(block)
        RU = plan.row_unit
        tw = plan.supertile_shape((block, block))[1]
        W = plan.ncols * tw
        h = RU if h is None else min(int(h), RU)
        D, rpd = plan.num_shards, plan.rpd
        trimmed = sum(D * s.shape[1] * self._strip(cls, RU, h)[1]
                      * wc * tw * itemsize
                      for _, cls, s, _, _, _, wc in self.rounds)
        strips = sum(D * s.shape[1] * self._strip(cls, RU, h)[1] * W
                     * itemsize for _, cls, s, *_ in self.rounds)
        full = 0
        for delta in range(1, D):
            m = max(len([g for g in self.ghost_rows[d]
                         if g // rpd == (d - delta) % D])
                    for d in range(D))
            full += D * m * RU * W * itemsize
        return {"trimmed": trimmed, "strips": strips,
                "full_rows": full}


class ShardedPlan(GridPlan):
    """A GridPlan whose grid is one device's share of the domain.

    Parameters beyond :class:`GridPlan`:

    mesh, axis:  the jax Mesh and the name of the axis to shard over.
    partition:   "storage-rows" | "linear" | "rows" (default: by
                 storage -- compact shards its packed rows, embedded
                 shards the canonical enumeration).
    halo:        build the ghost-row exchange plan (CA stencils under
                 compact storage; write/sum leave it off).

    The plan's specs address *local* arrays: under "storage-rows" the
    device's padded slab (inputs may be the halo-extended array), under
    "rows" the device's query-row band, under "linear" the replicated
    global array.  All host tables a driver must feed through shard_map
    come from :meth:`shard_table_host`, :meth:`lut_sharded_host` and
    ``halo.send_recv_host()``.
    """

    def __init__(self, domain: BlockDomain, lowering: str = "closed_form",
                 batch_dims: Sequence[int] = (), storage: str = "embedded",
                 coarsen: int = 1, backend=None, *, mesh: Mesh, axis: str,
                 partition: Optional[str] = None, halo: bool = False):
        super().__init__(domain, lowering, batch_dims, storage, coarsen,
                         backend)
        self.mesh, self.axis = mesh, axis
        self.num_shards = int(mesh.shape[axis])
        if partition is None:
            partition = "storage-rows" if self.storage == "compact" \
                else "linear"
        if partition not in PARTITIONS:
            raise ValueError(f"unknown partition {partition!r}; expected "
                             f"one of {PARTITIONS}")
        #: None, or "interior" / "boundary" on a :meth:`phase_view`
        self.phase = None
        if partition == "storage-rows" and self.storage != "compact":
            raise ValueError("storage-rows partition requires compact "
                             "storage")
        if partition != "storage-rows" and self.storage == "compact":
            raise ValueError("compact storage shards its packed rows; "
                             f"partition {partition!r} is embedded-only")
        self.partition = partition
        D = self.num_shards
        if partition == "storage-rows":
            self.ncols, self.nrows = self._storage_grid()
            self.rpd = _ceil_div(self.nrows, D)
            self.nrows_pad = self.rpd * D
            N = self.sched_domain.num_blocks
            lo = np.minimum(np.arange(D) * self.rpd * self.ncols, N)
            self._lo = lo.astype(np.int64)
            self._count = np.minimum(
                N - lo, self.rpd * self.ncols).clip(min=0)
            self.steps_per_shard = self.rpd * self.ncols
            self.halo = memo.cached(
                "halo-plan", domain,
                (self.storage, self.coarsen, D, bool(halo)),
                lambda: HaloPlan(self, with_halo=halo))
        elif partition == "rows":
            nbx, nby = self.sched_domain.bounding_box
            by = self.sched_domain.coords_host()[:, 1]
            if np.any(np.diff(by) < 0):
                raise ValueError(
                    f"'rows' partition needs a query-row-major "
                    f"enumeration; {self.sched_domain.name} is not")
            self.rbd = _ceil_div(nby, D)
            row_lo = np.minimum(np.arange(D + 1) * self.rbd, nby)
            lo = np.searchsorted(by, row_lo, side="left")
            self._row_lo = row_lo[:-1].astype(np.int64)
            self._lo = lo[:-1].astype(np.int64)
            self._count = np.diff(lo).astype(np.int64)
            self.steps_per_shard = int(self._count.max())
            self.halo = None
        elif partition == "zigzag":
            nbx, nby = self.sched_domain.bounding_box
            coords = self.sched_domain.coords_host()
            by = coords[:, 1]
            if np.any(np.diff(by) < 0):
                raise ValueError(
                    f"'zigzag' partition needs a query-row-major "
                    f"enumeration; {self.sched_domain.name} is not")
            if nby % (2 * D):
                raise ValueError(
                    f"'zigzag' partition needs the query-block row count "
                    f"({nby}) divisible by 2 * num_shards ({2 * D}) for "
                    f"an exactly balanced snake")
            r = by % (2 * D)
            dev = np.minimum(r, 2 * D - 1 - r)
            local = 2 * (by // (2 * D)) + (r >= D)
            key = local.astype(np.int64) * nbx + coords[:, 0]
            self.rbd = nby // D
            self._zz_idx = []
            for d in range(D):
                sel = np.nonzero(dev == d)[0]
                self._zz_idx.append(
                    sel[np.argsort(key[sel], kind="stable")].astype(
                        np.int64))
            self._count = np.asarray(
                [len(s) for s in self._zz_idx], np.int64)
            self._lo = np.zeros(D, np.int64)
            self.steps_per_shard = int(self._count.max())
            self.halo = None
        else:  # linear
            N = self.sched_domain.num_blocks
            per = _ceil_div(N, D)
            lo = np.minimum(np.arange(D) * per, N)
            self._lo = lo.astype(np.int64)
            self._count = np.minimum(N - lo, per).clip(min=0)
            self.steps_per_shard = per
            self.halo = None

    # -- storage geometry ----------------------------------------------------

    def _storage_grid(self) -> Tuple[int, int]:
        """(ncols, nrows) of the scheduled storage grid: supertiles
        under coarsening, packed slots otherwise."""
        if self._tiling is not None:
            scols, srows = self.layout.grid_shape
            bw, bh = self._tiling.sub_shape
            return scols // bw, srows // bh
        return self.layout.grid_shape

    @property
    def row_unit(self) -> int:
        """Cells per storage row of one fine block row -- set by the
        driver via :meth:`bind_block`."""
        return self._row_unit

    def bind_block(self, block: int) -> "ShardedPlan":
        """Record the fine block size (cells); needed to convert storage
        rows to array rows for padding / halo exchange."""
        th, _ = self.supertile_shape((block, block))
        self._row_unit = th if self.storage == "compact" else block
        self._block = block
        return self

    def local_storage_shape(self, block: int) -> Tuple[int, int]:
        """Cell shape of one device's storage-array shard."""
        if self.storage == "embedded":
            return self.layout.embedded_shape(block)
        self.bind_block(block)
        _, tw = self.supertile_shape((block, block))
        return (self.rpd * self.row_unit, self.ncols * tw)

    def global_padded_rows(self, block: int) -> int:
        self.bind_block(block)
        return self.nrows_pad * self.row_unit

    def pad_rows(self, arr: jnp.ndarray, block: int) -> jnp.ndarray:
        """Zero-pad a global packed array to D-divisible storage rows."""
        rows = self.global_padded_rows(block)
        pad = rows - arr.shape[0]
        if pad == 0:
            return arr
        return jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1))

    def unpad_rows(self, arr: jnp.ndarray, block: int) -> jnp.ndarray:
        scols, srows = self.layout.grid_shape
        return arr[:srows * block]

    # -- per-device tables ---------------------------------------------------

    def shard_table_host(self) -> np.ndarray:
        """(D, L) i32: one shard-table row per device (see SHARD_*);
        memoized per (domain, plan axes, D, partition, halo)."""
        return memo.cached(
            "shard-table", self.domain,
            (self.storage, self.coarsen, self.num_shards, self.partition,
             self.halo.h_max if self.halo is not None else -1),
            self._shard_table_host)

    def _shard_table_host(self) -> np.ndarray:
        cols = [self._row_lo_col(), self._count]
        if self.partition == "rows":
            cols.append(self._row_lo)
        elif self.partition == "zigzag":
            cols.append(np.arange(self.num_shards))
        tbl = np.stack([np.asarray(c, np.int64) for c in cols], -1)
        if self.partition == "storage-rows":
            tbl = np.concatenate([tbl, self.halo.ghost_map], axis=1)
        tbl = tbl.astype(np.int32)
        tbl.setflags(write=False)
        return tbl

    def _row_lo_col(self):
        if self.partition == "storage-rows":
            return np.arange(self.num_shards) * self.rpd
        return self._lo

    def lut_sharded_host(self) -> Optional[np.ndarray]:
        """(D * steps_per_shard, C) i32 decode table under prefetch_lut:
        the parent LUT re-ordered into each device's enumeration order,
        chunked per device and padded (pad rows repeat the chunk head so
        every read stays in-range; validity comes from the shard table's
        count).  Memoized per (domain, plan axes, D, partition)."""
        if self.lowering != "prefetch_lut":
            return None
        return memo.cached(
            "shard-lut", self.domain,
            (self.storage, self.coarsen, self.num_shards, self.partition),
            self._lut_sharded_host)

    def _lut_sharded_host(self) -> np.ndarray:
        base = GridPlan.lut_host(self)
        if self.partition == "storage-rows":
            if self._tiling is not None:
                slots = self._tiling.tiles_host()
            else:
                slots = self.layout.slots_host()
            order = np.argsort(
                slots[:, 1].astype(np.int64) * self.ncols + slots[:, 0],
                kind="stable")
            base = base[order]
        per = self.steps_per_shard
        out = np.zeros((self.num_shards, per, base.shape[1]), base.dtype)
        for d in range(self.num_shards):
            if self.partition == "zigzag":
                idx = self._zz_idx[d]
                c = len(idx)
                out[d] = base[idx[0]] if c else base[0]
                out[d, :c] = base[idx]
            else:
                lo, c = int(self._lo[d]), int(self._count[d])
                out[d] = base[lo] if c else base[0]
                out[d, :c] = base[lo:lo + c]
        out = out.reshape(self.num_shards * per, base.shape[1])
        out.setflags(write=False)
        return out

    def mma_table_sharded(self) -> Optional[jnp.ndarray]:
        """(D * steps_per_shard, C) i32 decode table of the table-backed
        ``mma`` lowering: the device-computed canonical chain table
        (:meth:`GridPlan.mma_table`), permuted/chunked/padded into the
        per-device enumeration order by a host-built gather index that
        replicates :meth:`_lut_sharded_host` exactly -- so the chunks
        carry chain-derived entries in LUT layout.  ``None`` when this
        plan binds no mma table (other lowerings, or gpu structures
        which run the chains in-kernel)."""
        tbl = self.mma_table_sharded_host()
        return None if tbl is None else jnp.asarray(tbl)

    def mma_table_sharded_host(self) -> Optional[np.ndarray]:
        """Host numpy copy of :meth:`mma_table_sharded` (the verifier
        runs inside kernel jit traces, where the device gather would be
        a tracer)."""
        if not (self.lowering == "mma" and self._table_backed):
            return None
        idx = memo.cached(
            "shard-mma-index", self.domain,
            (self.storage, self.coarsen, self.num_shards, self.partition),
            self._mma_shard_index)
        return GridPlan.mma_table_host(self)[idx]

    def _mma_shard_index(self) -> np.ndarray:
        n = self.sched_domain.num_blocks
        order = np.arange(n, dtype=np.int64)
        if self.partition == "storage-rows":
            if self._tiling is not None:
                slots = self._tiling.tiles_host()
            else:
                slots = self.layout.slots_host()
            order = np.argsort(
                slots[:, 1].astype(np.int64) * self.ncols + slots[:, 0],
                kind="stable")
        per = self.steps_per_shard
        out = np.zeros((self.num_shards, per), np.int64)
        for d in range(self.num_shards):
            if self.partition == "zigzag":
                idx = self._zz_idx[d]
                c = len(idx)
                out[d] = idx[0] if c else 0
                out[d, :c] = idx
            else:
                lo, c = int(self._lo[d]), int(self._count[d])
                out[d] = order[lo] if c else order[0]
                out[d, :c] = order[lo:lo + c]
        out = out.reshape(self.num_shards * per)
        out.setflags(write=False)
        return out

    # -- interior/boundary phase views ---------------------------------------

    def phase_widths(self) -> Tuple[int, int]:
        """(max interior, max boundary) step counts over the devices --
        the static grid sizes of the two phase launches."""
        h = self.halo
        if h is None or h.int_steps is None:
            return 0, 0
        return (max((len(s) for s in h.int_steps), default=0),
                max((len(s) for s in h.bnd_steps), default=0))

    def phase_tables_host(self):
        """(interior, boundary) ``(D, 1 + max)`` i32 phase tables --
        ``[count, step ids...]`` per device, zero-padded (pad steps
        decode to step 0 and are masked by the count) -- or ``None``
        when either phase is empty everywhere, i.e. there is nothing
        to overlap."""
        mi, mb = self.phase_widths()
        if mi == 0 or mb == 0:
            return None

        def tbl(lists, m):
            out = np.zeros((self.num_shards, 1 + m), np.int32)
            for d, s in enumerate(lists):
                out[d, 0] = len(s)
                out[d, 1:1 + len(s)] = s
            out.setflags(write=False)
            return out
        return (tbl(self.halo.int_steps, mi),
                tbl(self.halo.bnd_steps, mb))

    def phase_view(self, which: str) -> "ShardedPlan":
        """A view of this plan whose grid covers only the interior or
        boundary steps: grid steps are indirected through one extra
        scalar-prefetch operand (the device's phase-table row, passed
        last), so the boundary launch -- the only one that reads ghost
        rows -- can start after the halo exchange while interior steps
        ran concurrently with it.  Both launches visit each owned step
        exactly once between them with unchanged operands, so the pair
        is bit-identical to the single synchronous launch."""
        if which not in ("interior", "boundary"):
            raise ValueError(f"unknown phase {which!r}")
        if self.partition != "storage-rows" or self.halo is None \
                or self.halo.int_steps is None:
            raise ValueError("phase views need a storage-rows plan "
                             "built with halo=True")
        if self.lowering == "bounding":
            raise ValueError("phase views reorder the step grid; the "
                             "bounding lowering is not step-indexed")
        import copy
        pv = copy.copy(self)
        pv.phase = which
        mi, mb = self.phase_widths()
        pv.steps_per_shard = mi if which == "interior" else mb
        return pv

    def _phase_step(self, t, refs):
        """Raw grid step -> scheduled step id: the phase table (last
        scalar-prefetch ref) indirects it on a phase view; identity
        otherwise."""
        if self.phase is None:
            return t
        return refs[-1][1 + t]

    def _phase_count(self, sref, refs):
        if self.phase is None:
            return sref[SHARD_COUNT]
        return refs[-1][0]

    # -- GridPlan overrides --------------------------------------------------

    @property
    def num_scalar_prefetch(self) -> int:
        base = 2 if self._table_backed else 1
        return base + (1 if self.phase is not None else 0)

    def bound_prefetch(self):
        return None  # per-device tables: the driver passes them

    def _lut_row0(self):
        return None  # per-device LUT chunks arrive as shard_map operands

    @property
    def grid(self):
        if self.lowering == "bounding":
            nbx, nby = self.sched_domain.bounding_box
            if self.partition in ("rows", "zigzag"):
                return self.batch_dims + (self.rbd, nbx)
            return self.batch_dims + (nby, nbx)
        return self.batch_dims + (self.steps_per_shard,)

    def _storage_coords(self, col, row):
        """Storage grid position (col, row) -> scheduled embedded block
        coords, the sharded closed-form decode (lambda on the orthotope
        coordinate; linear-order block_coords for block-linear
        layouts)."""
        mma_lib = None
        if self.lowering == "mma":
            from . import mma as mma_lib
        if self._tiling is not None:
            t = self._tiling
            wx, wy = (col, row) if t.j % 2 == 0 else (row, col)
            if mma_lib is not None:
                return mma_lib.decode_orthotope(t.spec, t.coarse.r_b,
                                                wx, wy)
            return t.spec.lambda_map(wx, wy, t.coarse.r_b)
        spec = self.layout._fractal_spec()
        if spec is not None:
            if mma_lib is not None:
                return mma_lib.decode_orthotope(spec, self.domain.r_b,
                                                col, row)
            return spec.lambda_map(col, row, self.domain.r_b)
        i = jnp.clip(row * self.ncols + col, 0,
                     self.sched_domain.num_blocks - 1)
        if mma_lib is not None:
            return mma_lib.decode_rows(self.sched_domain, i)
        return self.sched_domain.block_coords(i)

    def _storage_row(self, bx, by):
        """Scheduled block coords -> its global storage row (traceable)."""
        if self._tiling is not None:
            return self._tiling.tile_index(bx, by)[1]
        return self.layout.slot(bx, by)[1]

    def _decode(self, grid_ids, prefetch_refs=()):
        nb = len(self.batch_dims)
        batch = tuple(grid_ids[:nb])
        sref = prefetch_refs[0]
        if self.lowering == "bounding":
            by, bx = grid_ids[nb], grid_ids[nb + 1]
            if self.partition == "rows":
                by = by + sref[SHARD_ROWLO]
            elif self.partition == "zigzag":
                by = self._zz_global_row(by, sref[SHARD_DEV])
            return batch, bx, by
        t = self._phase_step(grid_ids[nb], prefetch_refs)
        if self._table_backed:  # prefetch_lut, or mma on TPU structures
            lut_ref = prefetch_refs[1]
            return batch, lut_ref[t, 0], lut_ref[t, 1]
        if self.partition == "zigzag":
            raise ValueError(
                "the zigzag partition's owned rows are scattered; its "
                "linear enumeration decodes through tables "
                "(prefetch_lut / mma) or the bounding grid")
        if self.partition == "storage-rows":
            col = t % self.ncols
            row = jnp.minimum(sref[SHARD_LO] + t // self.ncols,
                              self.nrows - 1)
            bx, by = self._storage_coords(col, row)
            return batch, bx, by
        # linear / rows: the parent enumeration at the device offset,
        # clamped into the device's own range so padded steps decode to
        # an owned (and discarded) block
        i = jnp.clip(sref[SHARD_LO]
                     + jnp.minimum(t, sref[SHARD_COUNT] - 1),
                     0, self.sched_domain.num_blocks - 1)
        if self.lowering == "mma":  # gpu structure: chains in-kernel
            return batch, *self._mma_decode(i)
        return batch, *self.sched_domain.block_coords(i)

    def _zz_global_row(self, local, dev):
        """Local band row -> global query-block row of the snake."""
        two_d = 2 * self.num_shards
        return (local // 2) * two_d + jnp.where(
            local % 2 == 0, dev, two_d - 1 - dev)

    def _place_coords(self, bx, by, prefetch_refs=()):
        if self.partition == "rows":
            return bx, by - prefetch_refs[0][SHARD_ROWLO]
        if self.partition == "zigzag":
            two_d = 2 * self.num_shards
            return bx, 2 * (by // two_d) + (by % two_d >= self.num_shards)
        return bx, by

    def _step_valid(self, grid_ids, bx, by, prefetch_refs=()):
        sref = prefetch_refs[0]
        nb = len(self.batch_dims)
        if self.lowering != "bounding":
            return grid_ids[nb] < self._phase_count(sref, prefetch_refs)
        member = super()._step_valid(grid_ids, bx, by, prefetch_refs)
        owned = self._owned(sref, bx, by)
        return owned if member is None else member & owned

    def _owned(self, sref, bx, by):
        """Does this device own scheduled block (bx, by)?  Traceable;
        garbage for non-member coords (mask with membership first)."""
        if self.partition == "storage-rows":
            row = self._storage_row(bx, by)
            return (row >= sref[SHARD_LO]) \
                & (row < sref[SHARD_LO] + self.rpd)
        if self.partition == "rows":
            nby = self.sched_domain.bounding_box[1]
            return (by >= sref[SHARD_ROWLO]) \
                & (by < sref[SHARD_ROWLO] + self.rbd) & (by < nby)
        if self.partition == "zigzag":
            two_d = 2 * self.num_shards
            r = by % two_d
            nby = self.sched_domain.bounding_box[1]
            return (jnp.minimum(r, two_d - 1 - r) == sref[SHARD_DEV]) \
                & (by < nby)
        li = self.sched_domain.linear_index(bx, by)
        return (li >= sref[SHARD_LO]) \
            & (li < sref[SHARD_LO] + sref[SHARD_COUNT])

    # -- storage-array tile indices (local slab addressing) ------------------

    def storage_index(self, grid_ids, refs=()):
        """Local-slab tile index of the state operand (shared by the
        BlockSpec index maps and the gpu-structured kernel bodies, as
        in :meth:`GridPlan.storage_index`)."""
        if self.storage == "embedded":
            return super().storage_index(grid_ids, refs)
        if self.lowering == "bounding":
            _, bx, by = self._decode(grid_ids, refs)
            row = jnp.clip(self._storage_row(bx, by), 0,
                           self.nrows_pad - 1)
            loc = jnp.clip(refs[0][SHARD_GMAP + row], 0, self.rpd - 1)
            return loc, self._storage_col(bx, by)
        # the sharded enumerations are slab-row-major: the step index
        # addresses the local slab directly
        t = self._phase_step(grid_ids[len(self.batch_dims)], refs)
        return t // self.ncols, t % self.ncols

    def _storage_col(self, bx, by):
        if self._tiling is not None:
            return self._tiling.tile_index(bx, by)[0]
        return self.layout.slot(bx, by)[0]

    def neighbor_index(self, j: int, grid_ids, refs=()):
        if self.storage == "embedded":
            return super().neighbor_index(j, grid_ids, refs)
        dx, dy = NEIGHBOR_OFFSETS8[j]
        sref = refs[0]
        if self._table_backed:
            t = self._phase_step(grid_ids[len(self.batch_dims)], refs)
            lut_ref = refs[1]
            nsx = lut_ref[t, _LUT_NBR + 3 * j]
            nsy = lut_ref[t, _LUT_NBR + 3 * j + 1]
        else:
            _, bx, by = self._decode(grid_ids, refs)
            frac = None
            if self.lowering == "mma":
                from . import mma
                frac = mma.fractal_of(self.sched_domain)
            if frac is not None:
                swap = self._tiling is not None and self._tiling.j % 2
                nsx, nsy, _ok = mma.neighbor_slots(
                    frac[0], frac[1], self.sched_domain, bx, by, dx, dy,
                    swap=bool(swap))
            elif self._tiling is not None:
                nsx, nsy, _ok = self._tiling.neighbor_tile(bx, by, dx, dy)
            else:
                nsx, nsy, _ok = self.layout.neighbor_slot(bx, by, dx, dy)
        row = jnp.clip(nsy, 0, self.nrows_pad - 1)
        return sref[SHARD_GMAP + row], nsx

    # -- ownership masks for the embedded psum combine -----------------------

    def owned_cell_mask(self, tbl, n: int, block: int) -> jnp.ndarray:
        """(n, n) bool inside shard_map: cells of member fine blocks
        whose *scheduled* block this device owns.  Ownership is disjoint
        and complete over member blocks, so masked psum combines are
        exact."""
        iy = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
        ix = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        fbx, fby = ix // block, iy // block
        member = self.domain.contains(fbx, fby)
        sbx, sby = fbx // self.coarsen, fby // self.coarsen
        return member & self._owned(tbl, sbx, sby)

    def member_cell_block_mask(self, n: int, block: int) -> jnp.ndarray:
        """(n, n) bool: cells belonging to member fine blocks."""
        iy = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
        ix = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        return self.domain.contains(ix // block, iy // block)


def zigzag_row_order(nby: int, num_shards: int) -> np.ndarray:
    """(nby,) permutation: position ``d * (nby // D) + l`` holds the
    global query-block row that device ``d``'s band row ``l`` owns
    under the snake assignment.  shard_map splits an operand into
    contiguous chunks, so a driver gathers Q block rows by this
    permutation before the sharded launch and scatters O back through
    its inverse (``np.argsort``) after."""
    D = num_shards
    if nby % (2 * D):
        raise ValueError(f"zigzag needs nby ({nby}) divisible by 2*D "
                         f"({2 * D})")
    perm = np.empty(nby, np.int64)
    rbd = nby // D
    for d in range(D):
        l = np.arange(rbd)
        perm[d * rbd:(d + 1) * rbd] = \
            (l // 2) * (2 * D) + np.where(l % 2 == 0, d, 2 * D - 1 - d)
    return perm


def device_tables(plan: ShardedPlan):
    """(shard_table, lut_tuple) device arrays for a driver's shard_map:
    the (D, L) shard table plus, under the table-backed lowerings
    (prefetch_lut, or mma on TPU structures), the per-device decode
    table -- both sharded ``P(axis, None)`` on their leading axis so
    each device receives its own row/chunk.  One builder shared by
    every sharded kernel driver so the prefetch-operand plumbing cannot
    drift between kernels."""
    tbl = jnp.asarray(plan.shard_table_host())
    lut = plan.lut_sharded_host()
    if lut is not None:
        return tbl, (jnp.asarray(lut),)
    mma_tbl = plan.mma_table_sharded()
    return tbl, ((mma_tbl,) if mma_tbl is not None else ())
