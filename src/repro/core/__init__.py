# The paper's primary contribution: the block-space fractal map lambda(w)
# and its generalization to block-structured sparse compute domains.
from . import domain, fractal
from .domain import (BandDomain, BlockDomain, BoundingBoxDomain,
                     GeneralizedFractalDomain, SierpinskiDomain,
                     TriangularDomain, make_attention_domain)
from .fractal import (CARPET, FRACTALS, HAUSDORFF, SIERPINSKI, VICSEK,
                      FractalSpec, all_block_coords, gasket_volume,
                      is_member, lambda_inverse, lambda_map,
                      lambda_map_linear, membership_grid, orthotope_shape,
                      pack_to_orthotope, scale_level, unpack_from_orthotope)
