# The paper's primary contribution: the block-space fractal map lambda(w)
# and its generalization to block-structured sparse compute domains,
# plus the GridPlan execution engine that lowers any domain to a Pallas
# grid via closed-form, scalar-prefetch-LUT, or bounding-box strategies,
# with state either embedded (O(n^2)) or orthotope-resident (O(n^H),
# CompactLayout).
from . import compact, domain, fractal, plan
from .compact import (NEIGHBOR_OFFSETS, CompactLayout, cell_neighbor_tables,
                      key_block_support, pack_kv)
from .domain import (BandDomain, BlockDomain, BoundingBoxDomain,
                     GeneralizedFractalDomain, SierpinskiDomain,
                     TriangularDomain, make_attention_domain,
                     make_fractal_domain)
from .fractal import (CARPET, FRACTALS, HAUSDORFF, SIERPINSKI, VICSEK,
                      FractalSpec, all_block_coords, deinterleave_linear,
                      gasket_volume, is_member, lambda_inverse, lambda_map,
                      lambda_map_linear, membership_grid, orthotope_shape,
                      pack_to_orthotope, scale_level, unpack_from_orthotope)
from .plan import (LOWERINGS, STORAGES, BlockCoords, GridPlan,
                   normalize_lowering, normalize_storage,
                   registered_domains, xla_schedule)
