"""Compact n^H storage: fractal (and general block-domain) state resident
in the packed orthotope layout of Lemma 2.

Every kernel in this repo used to *store* the fractal in the dense
embedded n x n array, so memory stayed O(n^2) even though the paper's
lambda(w) map launches only O(n^H) parallel work.  A
:class:`CompactLayout` moves the data itself into the compact layout:

* fractal domains pack block-for-block into the Lemma 2 orthotope
  (``k**ceil(r/2) x k**floor(r/2)`` blocks, k = 3 for the gasket) using
  the alternating base-k digit addressing of ``lambda``/``lambda^-1``;
* every other block domain packs block-linearly (slot ``i`` of the
  domain's canonical enumeration at row-major position ``i`` of a
  near-square grid), so triangular / band / bounding-box state can be
  orthotope-resident too.

The layout answers three questions:

* ``slot(bx, by)``         -- which packed block holds embedded block
                              (bx, by)  (traceable scalar int math, so it
                              runs inside ``BlockSpec.index_map``);
* ``pack`` / ``unpack``    -- host/jit bridges between the embedded and
                              packed arrays (reusing ``lambda_map`` /
                              ``lambda_inverse`` for fractals);
* ``neighbor_slots_host`` -- per compact block, the compact slots of its
                              N/S/W/E *embedded* neighbours (the
                              lambda^-1-resolved halo addressing a CA
                              stencil needs), built host-side and shipped
                              through GridPlan's ``prefetch_lut`` path.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from . import fractal as F
from .domain import (BlockDomain, GeneralizedFractalDomain,
                     SierpinskiDomain)

#: halo order shared by the layout tables, the GridPlan neighbour specs
#: and the CA kernel: north, south, west, east (dx, dy).
NEIGHBOR_OFFSETS = ((0, -1), (0, 1), (-1, 0), (1, 0))

#: full 8-neighbour halo (the first four rows are NEIGHBOR_OFFSETS, so
#: 4-neighbour consumers index the same table): N S W E, then the
#: corners NW NE SW SE.  Temporal CA fusion needs the corners: after T
#: fused steps a block's footprint is every cell within L1 distance T,
#: which reaches into the diagonal blocks for T >= 2.
NEIGHBOR_OFFSETS8 = NEIGHBOR_OFFSETS + ((-1, -1), (1, -1), (-1, 1), (1, 1))


def _is_host(x) -> bool:
    return isinstance(x, (int, np.integer, np.ndarray))


def _clip(x, lo, hi):
    if _is_host(x):
        return np.clip(x, lo, hi)
    return jnp.clip(x, lo, hi)


class CompactLayout:
    """Packed storage layout for a :class:`BlockDomain`'s member blocks.

    The packed array holds ``num_slots >= num_blocks`` blocks arranged as
    a 2-D grid of ``grid_shape = (scols, srows)`` blocks; member block
    ``i`` of the domain's canonical enumeration lives at ``slot_linear(i)``.
    For fractal domains this is exactly the Lemma 2 orthotope
    (``num_slots == num_blocks``); generic domains get a near-square
    row-major grid with at most ``scols - 1`` unused pad slots.
    """

    def __init__(self, domain: BlockDomain):
        self.domain = domain
        spec = self._fractal_spec()
        if spec is not None:
            self._k, self._r = spec.k, domain.r_b
            self.grid_shape = spec.orthotope_shape(domain.r_b)
        else:
            self._k = self._r = None
            n = domain.num_blocks
            scols = max(1, math.isqrt(n))
            if scols * scols < n:
                scols += 1
            srows = -(-n // scols)
            self.grid_shape = (scols, srows)
        self._slots_host = None
        self._neighbors_host = None

    def _fractal_spec(self):
        if isinstance(self.domain, SierpinskiDomain):
            return F.SIERPINSKI
        if isinstance(self.domain, GeneralizedFractalDomain):
            return self.domain.spec
        return None

    @property
    def num_slots(self) -> int:
        return self.grid_shape[0] * self.grid_shape[1]

    # -- addressing (traceable on host ints, numpy, and traced scalars) -----

    def slot_linear(self, i):
        """Linear enumeration index -> (sx, sy) packed block coords."""
        if self._k is not None:
            return F.deinterleave_linear(i, self._k, self._r)
        scols = self.grid_shape[0]
        return i % scols, i // scols

    def slot(self, bx, by):
        """Embedded block coords -> (sx, sy) packed block coords.

        Non-member coords decode to *some* in-range slot (the kernel
        discards those steps); members decode to their true slot.
        """
        if isinstance(self.domain, SierpinskiDomain):
            return F.lambda_inverse(bx, by, self._r)
        spec = self._fractal_spec()
        if spec is not None:
            return spec.lambda_inverse(bx, by, self._r)
        i = _clip(self.domain.linear_index(bx, by), 0,
                  self.domain.num_blocks - 1)
        return self.slot_linear(i)

    def neighbor_slot(self, bx, by, dx, dy):
        """Traceable (sx, sy, valid) of embedded neighbour (bx+dx,
        by+dy); invalid (out of range / non-member) neighbours point at
        slot (0, 0) with valid false."""
        nbx, nby = self.domain.bounding_box
        x, y = bx + dx, by + dy
        xc = _clip(x, 0, nbx - 1)
        yc = _clip(y, 0, nby - 1)
        ok = (x >= 0) & (x < nbx) & (y >= 0) & (y < nby) \
            & self.domain.contains(xc, yc)
        sx, sy = self.slot(xc, yc)
        where = np.where if _is_host(bx) else jnp.where
        return where(ok, sx, 0), where(ok, sy, 0), ok

    # -- host tables ---------------------------------------------------------

    def slots_host(self) -> np.ndarray:
        """(num_blocks, 2) int32 (sx, sy) per canonical enumeration index."""
        if self._slots_host is None:
            i = np.arange(self.domain.num_blocks, dtype=np.int64)
            sx, sy = self.slot_linear(i)
            t = np.stack([np.asarray(sx), np.asarray(sy)], -1)
            t = t.astype(np.int32)
            t.setflags(write=False)
            self._slots_host = t
        return self._slots_host

    def neighbor_slots_host(self) -> np.ndarray:
        """(num_blocks, 8, 3) int32: per compact block and
        N/S/W/E/NW/NE/SW/SE neighbour (``NEIGHBOR_OFFSETS8`` order, so
        rows [:4] are the von-Neumann halo) the (sx, sy, valid) triple;
        invalid neighbours point at slot (0, 0) with valid = 0.  This is
        the lambda^-1-resolved halo table the ``prefetch_lut`` lowering
        ships to the scalar core."""
        if self._neighbors_host is None:
            coords = self.domain.coords_host().astype(np.int64)
            out = np.zeros((len(coords), 8, 3), np.int32)
            for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS8):
                sx, sy, ok = self.neighbor_slot(coords[:, 0], coords[:, 1],
                                                dx, dy)
                out[:, j, 0] = np.asarray(sx)
                out[:, j, 1] = np.asarray(sy)
                out[:, j, 2] = np.asarray(ok)
            out.setflags(write=False)
            self._neighbors_host = out
        return self._neighbors_host

    # -- shapes / accounting -------------------------------------------------

    def array_shape(self, block: int, trailing: Tuple[int, ...] = ()):
        """Cell shape of the packed array for block x block tiles."""
        scols, srows = self.grid_shape
        return (srows * block, scols * block) + tuple(trailing)

    def embedded_shape(self, block: int, trailing: Tuple[int, ...] = ()):
        nbx, nby = self.domain.bounding_box
        return (nby * block, nbx * block) + tuple(trailing)

    def num_cells(self, block: int) -> int:
        return self.num_slots * block * block

    def embedded_cells(self, block: int) -> int:
        nbx, nby = self.domain.bounding_box
        return nbx * nby * block * block

    # -- pack / unpack bridges ----------------------------------------------

    def pack(self, arr: jnp.ndarray, block: int, fill=0) -> jnp.ndarray:
        """Gather an embedded (nby*block, nbx*block, ...) array into the
        packed (srows*block, scols*block, ...) layout."""
        nbx, nby = self.domain.bounding_box
        scols, srows = self.grid_shape
        arr = jnp.asarray(arr)
        trailing = arr.shape[2:]
        if arr.shape[:2] != (nby * block, nbx * block):
            raise ValueError(
                f"embedded array shape {arr.shape[:2]} does not match the "
                f"domain's {nby}x{nbx} grid of {block}x{block} blocks")
        blocks = jnp.moveaxis(
            arr.reshape((nby, block, nbx, block) + trailing), 1, 2)
        coords = self.domain.coords_host()
        slots = self.slots_host()
        sel = blocks[coords[:, 1], coords[:, 0]]
        out = jnp.full((srows, scols, block, block) + trailing, fill,
                       arr.dtype)
        out = out.at[slots[:, 1], slots[:, 0]].set(sel)
        return jnp.moveaxis(out, 2, 1).reshape(
            (srows * block, scols * block) + trailing)

    def unpack(self, packed: jnp.ndarray, block: int, fill=0) -> jnp.ndarray:
        """Scatter the packed layout back into the embedded array; cells
        outside the domain's member blocks get ``fill``."""
        nbx, nby = self.domain.bounding_box
        scols, srows = self.grid_shape
        packed = jnp.asarray(packed)
        trailing = packed.shape[2:]
        if packed.shape[:2] != (srows * block, scols * block):
            raise ValueError(
                f"packed array shape {packed.shape[:2]} does not match "
                f"the layout's {srows}x{scols} grid of {block}x{block} "
                f"blocks")
        blocks = jnp.moveaxis(
            packed.reshape((srows, block, scols, block) + trailing), 1, 2)
        coords = self.domain.coords_host()
        slots = self.slots_host()
        sel = blocks[slots[:, 1], slots[:, 0]]
        out = jnp.full((nby, nbx, block, block) + trailing, fill,
                       packed.dtype)
        out = out.at[coords[:, 1], coords[:, 0]].set(sel)
        return jnp.moveaxis(out, 2, 1).reshape(
            (nby * block, nbx * block) + trailing)


# ---------------------------------------------------------------------------
# Superblock coarsening geometry: each coarse grid step owns an s x s
# embedded tile of fine blocks (s = m**j), amortizing the lambda decode
# by the tile's member count (k**j for a fractal).  In the packed
# orthotope the members of one coarse block occupy a contiguous
# k**ceil(j/2) x k**floor(j/2) sub-rectangle of fine slots, because the
# low j base-k digits of the lambda-linear index deinterleave into the
# LOW digits of (w_x, w_y) while the high digits are exactly the coarse
# domain's own orthotope coordinate (transposed when j is odd, since the
# alternating unrolling flips parity by j levels).
# ---------------------------------------------------------------------------


class SuperTiling:
    """Coarsened schedule geometry for a *fractal* block domain.

    Parameters
    ----------
    domain:  a SierpinskiDomain / GeneralizedFractalDomain at level r.
    s:       embedded fine blocks per superblock side; must be m**j for
             the fractal's subdivision factor m, with 1 <= j <= r.

    Exposes the coarse domain (same fractal family at level r - j), the
    packed sub-rectangle shape, traceable coarse-tile addressing for
    ``BlockSpec.index_map`` code, and the static fine-block permutation
    between packed and embedded arrangement of one supertile.
    """

    def __init__(self, domain: BlockDomain, s: int):
        if isinstance(domain, SierpinskiDomain):
            spec = F.SIERPINSKI
        elif isinstance(domain, GeneralizedFractalDomain):
            spec = domain.spec
        else:
            raise ValueError(
                f"coarsen={s} needs a fractal domain (the lambda decode "
                f"being amortized); got {domain.name!r}")
        j = int(round(math.log(s, spec.m)))
        if s < 2 or spec.m ** j != s:
            raise ValueError(
                f"coarsen={s} must be a power >= {spec.m} of the "
                f"fractal's subdivision factor m={spec.m}")
        if j > domain.r_b:
            raise ValueError(
                f"coarsen={s} exceeds the domain's {spec.m ** domain.r_b} "
                f"blocks per side")
        self.fine = domain
        self.spec = spec
        self.s, self.j = s, j
        n_b = spec.m ** domain.r_b
        if isinstance(domain, SierpinskiDomain):
            self.coarse: BlockDomain = SierpinskiDomain(n_b // s)
        else:
            self.coarse = GeneralizedFractalDomain(spec, n_b // s)
        k = spec.k
        #: packed sub-rectangle of one supertile, in fine blocks
        #: (cols = w_x gets the even low levels, rows = w_y the odd).
        self.sub_shape = (k ** (j // 2), k ** ((j + 1) // 2))  # (bw, bh)
        self._coarse_layout = CompactLayout(self.coarse)
        self._tile_map = None
        self._tiles_host = None
        self._neighbor_tiles_host = None

    @property
    def members_per_tile(self) -> int:
        return self.spec.k ** self.j

    def tile_index(self, BX, BY):
        """Coarse embedded block coords -> (tx, ty) packed supertile
        index (traceable; the fine orthotope is tiled by supertiles of
        ``sub_shape`` fine slots).  When j is odd the alternating digit
        unrolling flips parity, so the coarse orthotope coordinate lands
        transposed."""
        wx, wy = self._coarse_layout.slot(BX, BY)
        return (wx, wy) if self.j % 2 == 0 else (wy, wx)

    def neighbor_tile(self, BX, BY, dx, dy):
        """Traceable (tx, ty, valid) of the coarse neighbour supertile
        (clamped to tile (0, 0) when out of range / non-member)."""
        nbx, nby = self.coarse.bounding_box
        x, y = BX + dx, BY + dy
        xc = _clip(x, 0, nbx - 1)
        yc = _clip(y, 0, nby - 1)
        ok = (x >= 0) & (x < nbx) & (y >= 0) & (y < nby) \
            & self.coarse.contains(xc, yc)
        tx, ty = self.tile_index(xc, yc)
        where = np.where if _is_host(BX) else jnp.where
        return where(ok, tx, 0), where(ok, ty, 0), ok

    def tile_map(self):
        """Static fine-block permutation of one supertile: a tuple of
        ``((oy, ox), (ey, ex))`` pairs mapping packed sub-rect position
        (ox, oy) to embedded offset (ex, ey) in fine-block units, one
        per member (the same for every supertile: the low lambda digits
        do not depend on the coarse block)."""
        if self._tile_map is None:
            k, j = self.spec.k, self.j
            pairs = []
            for i in range(k ** j):
                ox, oy = F.deinterleave_linear(i, k, j)
                ex, ey = self.spec.lambda_map_linear(i, j)
                pairs.append(((int(oy), int(ox)), (int(ey), int(ex))))
            self._tile_map = tuple(pairs)
        return self._tile_map

    # -- host tables (the prefetch_lut payload under coarsening) -------------

    def tiles_host(self) -> np.ndarray:
        """(coarse.num_blocks, 2) int32 (tx, ty) per coarse enumeration
        index."""
        if self._tiles_host is None:
            c = self.coarse.coords_host().astype(np.int64)
            tx, ty = self.tile_index(c[:, 0], c[:, 1])
            t = np.stack([np.asarray(tx), np.asarray(ty)], -1)
            t = t.astype(np.int32)
            t.setflags(write=False)
            self._tiles_host = t
        return self._tiles_host

    def neighbor_tiles_host(self) -> np.ndarray:
        """(coarse.num_blocks, 8, 3) int32 of (tx, ty, valid) per
        NEIGHBOR_OFFSETS8 coarse neighbour."""
        if self._neighbor_tiles_host is None:
            c = self.coarse.coords_host().astype(np.int64)
            out = np.zeros((len(c), 8, 3), np.int32)
            for jj, (dx, dy) in enumerate(NEIGHBOR_OFFSETS8):
                tx, ty, ok = self.neighbor_tile(c[:, 0], c[:, 1], dx, dy)
                out[:, jj, 0] = np.asarray(tx)
                out[:, jj, 1] = np.asarray(ty)
                out[:, jj, 2] = np.asarray(ok)
            out.setflags(write=False)
            self._neighbor_tiles_host = out
        return self._neighbor_tiles_host


# ---------------------------------------------------------------------------
# Cell-level neighbour tables (block = 1 cell): the XLA gather path used
# by the CA benchmark and the Ising example at scales where even the
# dense table *build* cannot afford an n x n scratch array.
# ---------------------------------------------------------------------------

def cell_neighbor_tables(r: int, spec: F.FractalSpec = F.SIERPINSKI
                         ) -> np.ndarray:
    """(4, k**r) int32: for each member cell (linear lambda order) the
    packed index of its N/S/W/E embedded neighbour, or ``k**r`` (a zero
    ghost slot) when absent.  Sort-based lookup: O(k^r log k^r) time and
    O(k^r) memory -- no dense n x n scratch, so it scales to n = 2**16
    where the embedded grid is unallocatable."""
    n = spec.m ** r
    vol = spec.k ** r
    i = np.arange(vol, dtype=np.int64)
    lx, ly = spec.lambda_map_linear(i, r)
    lx, ly = np.asarray(lx, np.int64), np.asarray(ly, np.int64)
    keys = ly * n + lx
    order = np.argsort(keys)
    skeys = keys[order]
    tables = np.full((4, vol), vol, np.int32)
    for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS):
        x, y = lx + dx, ly + dy
        ok = (x >= 0) & (x < n) & (y >= 0) & (y < n)
        nk = y * n + x
        pos = np.clip(np.searchsorted(skeys, nk), 0, vol - 1)
        hit = ok & (skeys[pos] == nk)
        tables[j] = np.where(hit, order[pos], vol).astype(np.int32)
    return tables


# ---------------------------------------------------------------------------
# Compact KV support for the attention kernels: the 1-D analogue of the
# packing above.  An attention block domain touches key blocks
# [lo, hi); storing only that support is the sliding-window KV-cache
# truncation (exact for the rectangular decode-convention BandDomain,
# identity for causal / full / square-band whose support is all of m_k).
# ---------------------------------------------------------------------------

def key_block_support(domain: BlockDomain) -> Tuple[int, int]:
    """[lo, hi) key-block (column) support of an attention block domain."""
    c = domain.coords_host()
    if len(c) == 0:
        return 0, 0
    return int(c[:, 0].min()), int(c[:, 0].max()) + 1


def pack_kv(kv: jnp.ndarray, domain: BlockDomain, block: int) -> jnp.ndarray:
    """Trim a (..., sk, d) K or V tensor to the domain's key-block
    support: the compact KV the ``storage='compact'`` flash path reads."""
    lo, hi = key_block_support(domain)
    return kv[..., lo * block:hi * block, :]


# ---------------------------------------------------------------------------
# Memoized constructors: layout/tiling geometry (and the host tables
# the instances cache) is pure in the domain, so repeated traces and
# multi-host startup share one instance per (domain[, s]) instead of
# rebuilding -- see repro.core.memo.
# ---------------------------------------------------------------------------

def compact_layout(domain: BlockDomain) -> CompactLayout:
    """The (memoized) :class:`CompactLayout` of a domain."""
    from . import memo
    return memo.cached("compact-layout", domain, (),
                       lambda: CompactLayout(domain))


def super_tiling(domain: BlockDomain, s: int) -> "SuperTiling":
    """The (memoized) :class:`SuperTiling` of (domain, s)."""
    from . import memo
    return memo.cached("super-tiling", domain, (int(s),),
                       lambda: SuperTiling(domain, s))
