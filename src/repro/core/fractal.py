"""Faithful implementation of the paper's block-space Sierpinski map.

Notation follows Navarro, Bustos, Vega, Hitschfeld (2017),
"Block-space GPU Mapping for Embedded Sierpinski Gasket Fractals":

* the discrete gasket of scale level ``r`` lives embedded in an
  ``n x n`` grid with ``n = 2**r``, origin at the top-left, ``y``
  increasing downwards.  Membership test (paper SS III.D.3):
  ``x & (n - 1 - y) == 0``.
* the gasket packs into a 2-orthotope of ``3**ceil(r/2) x 3**floor(r/2)``
  blocks (Lemma 2) via an alternating base-3 digit unrolling: odd scale
  levels consume base-3 digits of ``w_y``, even levels of ``w_x``.
* ``lambda(w)`` (Eq. 4-10) accumulates, per scale level ``mu``, a region
  offset ``tau^mu = Delta_mu * 2**(mu-1)`` with region index
  ``beta_mu(w) in {0, 1, 2}`` (0 = top, 1 = bottom-left, 2 = bottom-right).

Everything here is pure index math on jnp int32 arrays so the same code
runs (a) on host for table construction, (b) inside jit, and (c) inside
Pallas ``BlockSpec.index_map`` scalar code (via the *_py variants which
unroll at trace time).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

HAUSDORFF = math.log2(3.0)  # H = log2(3) ~ 1.5849625 (Lemma 1)


# ---------------------------------------------------------------------------
# Scalar / host-side helpers
# ---------------------------------------------------------------------------

def scale_level(n: int) -> int:
    """r = log2(n); n must be a power of two (paper: r = log_{1/s}(n), s=1/2)."""
    r = int(round(math.log2(n)))
    if 2 ** r != n:
        raise ValueError(f"n={n} is not a power of two")
    return r


def gasket_volume(n: int) -> int:
    """V(F_n^{3,1/2}) = 3**r = n**H   (Lemma 1)."""
    return 3 ** scale_level(n)


def orthotope_shape(r: int) -> Tuple[int, int]:
    """Packing orthotope (width_x, height_y) of the level-r gasket (Lemma 2).

    Odd scale levels mu=1,3,5,... consume base-3 digits of w_y, so w_y has
    ceil(r/2) digits; even levels consume digits of w_x -> floor(r/2) digits.
    The orthotope is therefore 3**floor(r/2) wide and 3**ceil(r/2) tall,
    matching the paper's (quasi-)regular 3**ceil(r/2) x 3**floor(r/2) up to
    the (width, height) naming convention.
    """
    return 3 ** (r // 2), 3 ** ((r + 1) // 2)


def is_member(x, y, n: int):
    """Embedded-space membership bit test: x & (n - 1 - y) == 0.

    Apex at (0,0); left edge x == 0 always member; bottom row y == n-1 full.
    Works on python ints and jnp arrays alike.
    """
    return (x & (n - 1 - y)) == 0


# ---------------------------------------------------------------------------
# The paper's map, Eq. (4) - (10)
# ---------------------------------------------------------------------------

def beta_mu(wx, wy, mu: int):
    """Region index beta_mu(w) in {0,1,2} at scale level mu  (Eq. 4)."""
    sel = wx * ((mu + 1) % 2) + wy * (mu % 2)      # odd mu -> w_y, even -> w_x
    return (sel // 3 ** ((mu + 1) // 2 - 1)) % 3


def delta_mu(beta):
    """Offset weights (Delta_x, Delta_y) in {0,1}^2 for a region index (Eq. 5)."""
    dx = beta // 2
    dy = beta - dx
    return dx, dy


def lambda_map(wx, wy, r: int):
    """lambda(w): orthotope block coords -> embedded fractal block coords.

    Faithful Eq. (8)-(10): sum over scale levels mu = 1..r of
    tau^mu = Delta_mu * 2**(mu-1).  The mu loop is unrolled at trace time
    (r is static), so inside jit/Pallas-index_map this is straight-line
    scalar int math -- the TPU analogue of the paper's per-block map.

    Accepts ints or jnp int arrays (vectorized over w).
    """
    lx = wx * 0
    ly = wy * 0
    for mu in range(1, r + 1):
        b = beta_mu(wx, wy, mu)
        dx, dy = delta_mu(b)
        lx = lx + dx * 2 ** (mu - 1)
        ly = ly + dy * 2 ** (mu - 1)
    return lx, ly


def lambda_map_linear(i, r: int):
    """lambda over a *linear* grid index i in [0, 3**r).

    Pallas grids are iterated linearly; rather than first splitting i into
    (w_x, w_y) and re-extracting alternating base-3 digits, note that the
    digit stream of i in base 3 IS the sequence (beta_1, beta_2, ..., beta_r)
    under the paper's alternating unrolling (odd digits come from w_y, even
    from w_x; concatenating them is exactly i = interleave(w_y, w_x) in
    base 3).  This is the same bijection with one fewer divmod chain.
    """
    lx = i * 0
    ly = i * 0
    for mu in range(1, r + 1):
        b = (i // 3 ** (mu - 1)) % 3
        dx, dy = delta_mu(b)
        lx = lx + dx * 2 ** (mu - 1)
        ly = ly + dy * 2 ** (mu - 1)
    return lx, ly


def lambda_inverse(x, y, r: int):
    """Inverse map: embedded fractal block coords -> orthotope coords.

    For each scale level mu the region is recovered from bit mu-1 of (x, y):
    (0,0) -> beta 0, (0,1) -> beta 1, (1,1) -> beta 2.  ((1,0) never occurs
    for members.)  The betas are then re-packed into the alternating base-3
    digits of (w_x, w_y).
    """
    wx = x * 0
    wy = y * 0
    px = x * 0 + 1  # 3**(even-digit position)
    py = y * 0 + 1
    for mu in range(1, r + 1):
        bx = (x >> (mu - 1)) & 1
        by = (y >> (mu - 1)) & 1
        b = bx + by  # (0,0)->0 (0,1)->1 (1,1)->2
        if mu % 2 == 1:
            wy = wy + b * py
            py = py * 3
        else:
            wx = wx + b * px
            px = px * 3
    return wx, wy


# ---------------------------------------------------------------------------
# Generalized F^{k,s} fractals (paper SS V, future-work question 1)
# ---------------------------------------------------------------------------

class FractalSpec:
    """A self-similar fractal built from k copies at scale s with integer
    per-copy offsets, generalizing the gasket's (k=3, s=1/2).

    offsets: tuple of (dx, dy) unit offsets in {0..m-1}^2 where m = 1/s is
    the integer subdivision factor.  Level-mu copy c sits at
    offsets[c] * m**(mu-1).
    """

    def __init__(self, name: str, k: int, m: int, offsets):
        if len(offsets) != k:
            raise ValueError("need one offset per copy")
        self.name, self.k, self.m = name, k, m
        self.offsets = tuple(tuple(o) for o in offsets)
        self._grid_cache = {}  # n -> dense membership grid (oracle)

    @property
    def hausdorff(self) -> float:
        return math.log(self.k) / math.log(self.m)

    @property
    def cache_key(self):
        """Value identity for :mod:`repro.core.memo`: the mma digit-basis
        builders memoize per spec geometry, not per instance."""
        return ("fractal-spec", self.name, self.k, self.m, self.offsets)

    def scale_level(self, n: int) -> int:
        r = int(round(math.log(n, self.m)))
        if self.m ** r != n:
            raise ValueError(f"n={n} is not a power of m={self.m}")
        return r

    def volume(self, n: int) -> int:
        return self.k ** self.scale_level(n)

    def lambda_map_linear(self, i, r: int):
        """Generalized digit-unrolled map: base-k digits of i choose copies.

        The copy-offset lookup is a select chain over the k static
        offsets (not a gather from a table), so the same code runs on
        host ints/numpy AND inside Pallas ``BlockSpec.index_map`` scalar
        code, which must not capture array constants."""
        where = np.where if isinstance(i, (int, np.integer, np.ndarray)) \
            else jnp.where
        lx = i * 0
        ly = i * 0
        for mu in range(1, r + 1):
            c = (i // self.k ** (mu - 1)) % self.k
            dx, dy = c * 0, c * 0
            for j, (ox, oy) in enumerate(self.offsets):
                dx = where(c == j, ox, dx)
                dy = where(c == j, oy, dy)
            lx = lx + dx * self.m ** (mu - 1)
            ly = ly + dy * self.m ** (mu - 1)
        return lx, ly

    def lambda_map(self, wx, wy, r: int):
        """Generalized lambda over *orthotope* coords (w_x, w_y) ->
        embedded fractal coords, the F^{k,s} analogue of module-level
        :func:`lambda_map`: odd scale levels mu = 1, 3, ... consume
        base-k digits of w_y, even levels of w_x (the Lemma 2
        alternating unrolling).  Straight-line int math usable on host
        ints/numpy and inside Pallas index maps; this is the decode the
        sharded orthotope-row-slab enumeration runs (row-major over
        packed slots instead of over the linear lambda order)."""
        host = all(isinstance(v, (int, np.integer, np.ndarray))
                   for v in (wx, wy))
        where = np.where if host else jnp.where
        lx = wx * 0
        ly = wy * 0
        for mu in range(1, r + 1):
            if mu % 2 == 1:
                c = (wy // self.k ** ((mu - 1) // 2)) % self.k
            else:
                c = (wx // self.k ** (mu // 2 - 1)) % self.k
            dx, dy = c * 0, c * 0
            for j, (ox, oy) in enumerate(self.offsets):
                dx = where(c == j, ox, dx)
                dy = where(c == j, oy, dy)
            lx = lx + dx * self.m ** (mu - 1)
            ly = ly + dy * self.m ** (mu - 1)
        return lx, ly

    def lambda_inverse(self, x, y, r: int):
        """Inverse map: embedded fractal coords -> orthotope coords.

        Per scale level mu the copy index c is recovered by matching the
        base-m digit pair of (x, y) against the copy offsets (a select
        chain, so the same code runs on host ints/numpy and traced); the
        copy indices are then re-packed into the alternating base-k
        digits of (w_x, w_y), generalizing the gasket's bit-pair trick.
        Non-member inputs decode to *some* in-range orthotope coordinate
        (unmatched digit pairs fall through to copy 0), which is exactly
        what a clamped compact-storage index map needs.
        """
        host = all(isinstance(v, (int, np.integer, np.ndarray))
                   for v in (x, y))
        where = np.where if host else jnp.where
        wx = x * 0
        wy = y * 0
        px = x * 0 + 1   # k**(even-digit position)
        py = y * 0 + 1
        for mu in range(1, r + 1):
            p = self.m ** (mu - 1)
            dx = (x // p) % self.m
            dy = (y // p) % self.m
            c = x * 0
            for j, (ox, oy) in enumerate(self.offsets):
                c = where((dx == ox) & (dy == oy), j, c)
            if mu % 2 == 1:
                wy = wy + c * py
                py = py * self.k
            else:
                wx = wx + c * px
                px = px * self.k
        return wx, wy

    def linear_index(self, x, y, r: int):
        """Embedded fractal coords -> linear index in lambda order (the
        inverse of :meth:`lambda_map_linear`); copy indices become the
        base-k digits of i."""
        host = all(isinstance(v, (int, np.integer, np.ndarray))
                   for v in (x, y))
        where = np.where if host else jnp.where
        i = x * 0
        for mu in range(1, r + 1):
            p = self.m ** (mu - 1)
            dx = (x // p) % self.m
            dy = (y // p) % self.m
            c = x * 0
            for j, (ox, oy) in enumerate(self.offsets):
                c = where((dx == ox) & (dy == oy), j, c)
            i = i + c * self.k ** (mu - 1)
        return i

    def orthotope_shape(self, r: int) -> Tuple[int, int]:
        """Packing orthotope (width_x, height_y): k**floor(r/2) wide by
        k**ceil(r/2) tall (Lemma 2 generalized to F^{k,s})."""
        return self.k ** (r // 2), self.k ** ((r + 1) // 2)

    def is_member(self, x, y, n: int):
        """Traceable membership test: (x, y) is in the level-r fractal iff
        every base-m digit pair of (x, y) is one of the copy offsets.

        Generalizes the gasket's O(1) bit test to any F^{k,s}: O(r * k)
        straight-line int ops, usable on python ints, jnp arrays, and
        inside Pallas kernels / index maps (no dense grid needed)."""
        r = self.scale_level(n)
        ok = None
        for mu in range(r):
            p = self.m ** mu
            dx = (x // p) % self.m
            dy = (y // p) % self.m
            lvl = None
            for (ox, oy) in self.offsets:
                hit = (dx == ox) & (dy == oy)
                lvl = hit if lvl is None else (lvl | hit)
            ok = lvl if ok is None else (ok & lvl)
        if ok is None:  # r == 0: the single cell is the whole fractal
            ok = (x == 0) & (y == 0)
        return ok

    def membership_grid(self, n: int) -> np.ndarray:
        """Dense boolean n x n occupancy via recursive construction (oracle).
        Memoized per instance: re-entered per traced index_map call."""
        if n in self._grid_cache:
            return self._grid_cache[n]
        r = self.scale_level(n)
        g = np.ones((1, 1), dtype=bool)
        for mu in range(1, r + 1):
            size = self.m ** (mu - 1)
            big = np.zeros((size * self.m, size * self.m), dtype=bool)
            for (dx, dy) in self.offsets:
                big[dy * size:(dy + 1) * size, dx * size:(dx + 1) * size] |= g
            g = big
        g.setflags(write=False)
        self._grid_cache[n] = g
        return g


SIERPINSKI = FractalSpec("sierpinski-gasket", k=3, m=2,
                         offsets=((0, 0), (0, 1), (1, 1)))
# Sierpinski carpet: 8 copies at 1/3 scale (center removed), H = log3(8).
CARPET = FractalSpec("sierpinski-carpet", k=8, m=3,
                     offsets=((0, 0), (1, 0), (2, 0),
                              (0, 1), (2, 1),
                              (0, 2), (1, 2), (2, 2)))
# Vicsek cross: 5 copies at 1/3 scale, H = log3(5).
VICSEK = FractalSpec("vicsek-cross", k=5, m=3,
                     offsets=((1, 0), (0, 1), (1, 1), (2, 1), (1, 2)))

FRACTALS = {f.name: f for f in (SIERPINSKI, CARPET, VICSEK)}


def deinterleave_linear(i, k: int, r: int):
    """Linear lambda-order index -> orthotope coords (w_x, w_y).

    The base-k digit stream of i is the alternating digit unrolling of
    (w_y, w_x) (odd scale levels mu = 1, 3, ... are digits of w_y, even
    of w_x), so de-interleaving i's digits recovers the Lemma 2 packing
    coordinate without going through embedded space."""
    wx = i * 0
    wy = i * 0
    px = i * 0 + 1
    py = i * 0 + 1
    for mu in range(1, r + 1):
        d = (i // k ** (mu - 1)) % k
        if mu % 2 == 1:
            wy = wy + d * py
            py = py * k
        else:
            wx = wx + d * px
            px = px * k
    return wx, wy


# ---------------------------------------------------------------------------
# Vectorized/device utilities
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("r",))
def all_block_coords(r: int) -> jnp.ndarray:
    """(3**r, 2) int32 array of embedded coords for every gasket block,
    enumerated in linear lambda order (the canonical compact layout order).
    """
    i = jnp.arange(3 ** r, dtype=jnp.int32)
    lx, ly = lambda_map_linear(i, r)
    return jnp.stack([lx, ly], axis=-1)


def membership_grid(n: int) -> np.ndarray:
    """Dense boolean occupancy of the embedded gasket via the bit test."""
    y, x = np.mgrid[0:n, 0:n]
    return (x & (n - 1 - y)) == 0


def pack_to_orthotope(grid: jnp.ndarray, r: int) -> jnp.ndarray:
    """Gather an embedded n x n array into the compact (3**ceil, 3**floor)
    orthotope layout (Lemma 2).  grid[y, x] -> packed[w_y, w_x]."""
    ox, oy = orthotope_shape(r)
    wy, wx = jnp.mgrid[0:oy, 0:ox]
    lx, ly = lambda_map(wx, wy, r)
    return grid[ly, lx]


def unpack_from_orthotope(packed: jnp.ndarray, r: int, n: int,
                          fill=0) -> jnp.ndarray:
    """Scatter the compact orthotope layout back into the embedded n x n."""
    ox, oy = orthotope_shape(r)
    wy, wx = jnp.mgrid[0:oy, 0:ox]
    lx, ly = lambda_map(wx, wy, r)
    out = jnp.full((n, n) + packed.shape[2:], fill, dtype=packed.dtype)
    return out.at[ly, lx].set(packed)
