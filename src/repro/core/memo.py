"""Process-wide memo for host-built execution tables.

Every trace of a plan-driven kernel used to rebuild its host tables --
decode LUTs, packed-slot and neighbour tables, shard tables, ghost maps
-- from scratch, and a multi-host startup rebuilds them once per
process per trace.  The tables are pure functions of
``(domain, plan axes, shard count, backend structure)``, so they are
memoized here under that key.

Domains opt in by exposing ``cache_key`` (a hashable tuple fully
describing the instance); domains without one -- e.g. a
``BoundingBoxDomain`` closed over an arbitrary membership callable --
are uncacheable and every lookup falls through to the builder.

Entries are host numpy arrays (marked read-only by their builders) or
small frozen helper objects; sizes are bounded by the geometry already
resident per plan, so no eviction is needed -- ``clear()`` exists for
tests.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

_CACHE: dict = {}
#: lookup statistics, readable by tests and the tune/bench harnesses:
#: hits avoid a host-table rebuild.
STATS = {"hits": 0, "misses": 0}


def domain_key(domain) -> Optional[Tuple]:
    """The domain's identity for memoization, or None when the domain
    cannot guarantee one."""
    key = getattr(domain, "cache_key", None)
    return key() if callable(key) else key


def cached(kind: str, domain, extra: Tuple, build: Callable):
    """Return ``build()`` memoized under ``(kind, domain, *extra)``.

    ``extra`` must be hashable and must capture every input of
    ``build`` besides the domain (lowering, storage, coarsen, shard
    count, partition, backend structure...).  A domain without a cache
    key disables memoization for that call.
    """
    dk = domain_key(domain)
    if dk is None:
        STATS["misses"] += 1
        return build()
    key = (kind, dk) + tuple(extra)
    hit = _CACHE.get(key)
    if hit is not None:
        STATS["hits"] += 1
        return hit
    STATS["misses"] += 1
    out = build()
    _CACHE[key] = out
    return out


def clear() -> None:
    _CACHE.clear()
    STATS["hits"] = STATS["misses"] = 0


def size() -> int:
    return len(_CACHE)
