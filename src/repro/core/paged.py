"""Paged block-space KV cache: the lambda-map trick applied to serving.

The paper's central move -- addressing a compact O(n^H) store through a
cheap index translation instead of materializing the bounding box -- is
structurally the same indirection a paged KV cache needs: a per-slot
table from *logical* key blocks to *physical* pages, read per grid step.
This module supplies the three pieces:

``PagedPlan``
    A :class:`~repro.core.plan.GridPlan` whose scalar-prefetch operands
    are led by the page table.  A page-table row per query slot is the
    same shape as the 28-col neighbour LUT the engine already prefetches
    (one i32 row per scheduled block), so the table rides the existing
    mechanism unchanged: on block-indexed (TPU) targets it is prefetch
    operand 0, readable from BlockSpec index maps; on gpu structures it
    becomes the leading HBM operand read in-kernel at ``pl.program_id``
    -- exactly how the decode LUT already travels
    (:mod:`repro.core.backend`).  The base plan's own LUT (when the
    lowering is table-backed) stays the *last* prefetch ref, so
    ``GridPlan._decode`` works untouched.

``PagedKVPool``
    The host-side allocator: a free list over physical pages with page 0
    reserved as the *null page* -- inactive slots route their writes
    there and no reader ever dereferences it, so fully-batched scatters
    need no host-side compaction.  Fragmentation statistics
    (``stats()``) feed the serving benchmarks.

Device-side layout helpers
    The pool array is ``(num_pages, 2*Hkv, page_size, d)`` with the K
    and V heads *interleaved* on the head axis (``[K0,V0,K1,V1,...]``):
    one page-tile read of head-block ``h`` (a ``(1, 2, page_size, d)``
    BlockSpec block at head index ``h``) feeds both attention operands,
    halving the page-table resolves and keeping K/V of one head in one
    contiguous DMA.  :func:`fuse_kv` / :func:`split_kv` convert between
    this layout and the separate ``(B, Hkv, S, d)`` caches;
    :func:`gather_kv` is the XLA gather that reconstructs a contiguous
    cache from the pool (the oracle the bit-identity tests and the
    degradation ladder's paged-xla rung share); :func:`append_token` /
    :func:`write_prefill_pages` are the scatter writes the serving
    decode/prefill steps use.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .plan import GridPlan

#: physical page 0 is never allocated: it is the write target of
#: inactive slots (masked scatters) and the pad entry of page tables.
NULL_PAGE = 0


class PagedPlan(GridPlan):
    """A GridPlan whose prefetch operands are led by the page table.

    ``page_table`` is the ``(num_slots, max_pages)`` i32 device array
    (or tracer: the plan is built inside the kernel's jit trace, where
    the table is an argument).  ``num_scalar_prefetch`` grows by one and
    ``bound_prefetch`` prepends the table, so the emitter routes it
    exactly like the decode LUT: scalar prefetch on TPU structures, a
    leading HBM operand on gpu structures.  Index maps reach it as
    ``refs[0]`` (see :meth:`GridPlan._index_spec`); the base LUT, when
    the lowering is table-backed, remains ``refs[-1]`` so the inherited
    decode is untouched."""

    def __init__(self, *args, page_table=None, **kwargs):
        super().__init__(*args, **kwargs)
        if page_table is None:
            raise ValueError("PagedPlan requires page_table=")
        self.page_table = page_table

    @property
    def num_scalar_prefetch(self) -> int:
        return super().num_scalar_prefetch + 1

    def bound_prefetch(self):
        # not super(): the base implementation keys off the (now +1)
        # num_scalar_prefetch and would bind a table for non-table
        # lowerings too.  The base LUT binds iff the base decode is
        # table-backed, and always *after* the page table.
        base = ()
        if self._table_backed:
            base = (self.mma_table() if self.lowering == "mma"
                    else self.lut(),)
        return (self.page_table,) + base


# ---------------------------------------------------------------------------
# host-side allocator
# ---------------------------------------------------------------------------

class PagedKVPool:
    """Free-list page allocator for one serving process.

    Pure host bookkeeping: the device pool array itself is threaded
    through the jitted decode step by the caller.  Page 0 is reserved
    (:data:`NULL_PAGE`).  Allocation hands out the lowest-numbered free
    pages first, which keeps reuse tight after churn; ``stats`` reports
    the fragmentation the benchmarks track."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free = sorted(range(1, self.num_pages), reverse=True)
        self._used: set[int] = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._used)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        """``n`` physical pages, or ``None`` when the pool cannot serve
        the request (the scheduler's admission signal -- never a raise:
        running out of pages is a load condition, not a bug)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._used.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p == NULL_PAGE:
                continue
            if p not in self._used:
                raise ValueError(f"double free of page {p}")
            self._used.discard(p)
            self._free.append(p)
        self._free.sort(reverse=True)

    def stats(self, seq_lens: Sequence[int] = ()) -> dict:
        """Occupancy + fragmentation.  ``seq_lens`` are the live
        sequence lengths; *internal fragmentation* is the fraction of
        allocated token slots no live token occupies (the tail waste of
        partially-filled last pages), which a contiguous max-len
        preallocation drives toward 1 on mixed-length traffic."""
        cap = self.num_pages - 1
        used = len(self._used)
        tokens = int(sum(seq_lens))
        alloc_tokens = used * self.page_size
        return {
            "num_pages": cap,
            "used_pages": used,
            "free_pages": len(self._free),
            "utilization": used / cap if cap else 0.0,
            "live_tokens": tokens,
            "alloc_tokens": alloc_tokens,
            "fragmentation": (1.0 - tokens / alloc_tokens)
            if alloc_tokens else 0.0,
        }


def pages_for(seq_len: int, page_size: int) -> int:
    """Physical pages needed to hold ``seq_len`` tokens."""
    return -(-int(seq_len) // int(page_size)) if seq_len > 0 else 0


# ---------------------------------------------------------------------------
# device-side layout helpers (head-interleaved fused KV)
# ---------------------------------------------------------------------------

def fuse_kv(k, v):
    """(…, Hkv, S, d) x2 -> (…, 2*Hkv, S, d) with heads interleaved
    ``[K0, V0, K1, V1, ...]`` so one head-block read feeds both
    operands."""
    stacked = jnp.stack([k, v], axis=-3)        # (…, Hkv, 2, S, d)
    shape = stacked.shape
    return stacked.reshape(shape[:-4] + (shape[-4] * 2,) + shape[-2:])


def split_kv(kv):
    """Inverse of :func:`fuse_kv`."""
    shape = kv.shape
    hkv = shape[-3] // 2
    pairs = kv.reshape(shape[:-3] + (hkv, 2) + shape[-2:])
    return pairs[..., 0, :, :], pairs[..., 1, :, :]


def init_pool(num_pages: int, kv_heads: int, page_size: int, d: int,
              dtype=jnp.float32):
    """Zeroed device pool ``(num_pages, 2*Hkv, page_size, d)``."""
    return jnp.zeros((num_pages, 2 * kv_heads, page_size, d), dtype)


def gather_kv(pool, page_table):
    """Reconstruct contiguous caches from the pool (pure XLA gather).

    pool: (P, 2*Hkv, ps, d); page_table: (B, m) -> k, v each
    (B, Hkv, m*ps, d).  Rows mapped to the null page come back as
    whatever page 0 holds -- positions beyond each slot's ``seq_pos``
    are masked by every consumer, so the garbage never reaches an
    output.  This is the oracle of the paged bit-identity tests and the
    degradation ladder's ``paged-xla`` rung."""
    b, m = page_table.shape
    _, h2, ps, d = pool.shape
    tiles = pool[page_table]                     # (B, m, 2Hkv, ps, d)
    kv = tiles.transpose(0, 2, 1, 3, 4).reshape(b, h2, m * ps, d)
    return split_kv(kv)


def append_token(pool, page_table, pos, k_new, v_new, active=None):
    """Scatter one new K/V token per slot into its current page.

    pool: (P, 2*Hkv, ps, d); page_table: (B, m); pos: (B,) the token's
    position; k_new/v_new: (B, Hkv, 1, d).  ``active`` (B,) bool masks
    finished / empty slots by routing their write to the null page
    (page 0 is never read, so the duplicate scatter targets are
    harmless).  Returns the updated pool."""
    b = pos.shape[0]
    ps = pool.shape[2]
    pages = page_table[jnp.arange(b), pos // ps]
    if active is not None:
        pages = jnp.where(active, pages, NULL_PAGE)
    kv = fuse_kv(k_new, v_new)[:, :, 0, :]       # (B, 2Hkv, d)
    return pool.at[pages, :, pos % ps, :].set(
        kv.astype(pool.dtype), mode="drop")


def write_prefill_pages(pool, pages, k, v):
    """Write one request's contiguous prefill KV into its pages.

    pages: (n,) i32 physical page ids (pad entries = null page);
    k/v: (Hkv, S, d) with S <= n*ps -- the tail of the last page is
    left as zero padding (masked by ``seq_pos`` at read time).
    Returns the updated pool."""
    n = pages.shape[0]
    hkv, s, d = k.shape
    ps = pool.shape[2]
    kv = fuse_kv(k, v)                           # (2Hkv, S, d)
    pad = n * ps - s
    if pad:
        kv = jnp.pad(kv, ((0, 0), (0, pad), (0, 0)))
    tiles = kv.reshape(2 * hkv, n, ps, d).transpose(1, 0, 2, 3)
    return pool.at[pages].set(tiles.astype(pool.dtype), mode="drop")


# ---------------------------------------------------------------------------
# host-side page-table assembly (what the scheduler maintains)
# ---------------------------------------------------------------------------

def build_page_table(num_slots: int, max_pages: int,
                     slot_pages: dict[int, Sequence[int]]) -> np.ndarray:
    """(num_slots, max_pages) i32 table from the scheduler's per-slot
    page lists; unmapped entries are the null page."""
    table = np.full((num_slots, max_pages), NULL_PAGE, np.int32)
    for slot, pages in slot_pages.items():
        pages = list(pages)
        if len(pages) > max_pages:
            raise ValueError(
                f"slot {slot} holds {len(pages)} pages, table has room "
                f"for {max_pages}")
        table[slot, :len(pages)] = pages
    return table
