"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline terms.

MUST be run as a module (python -m repro.launch.dryrun ...) so the
device-count override below precedes any jax initialization.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse     # noqa: E402
import json         # noqa: E402
import subprocess   # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import META, SHAPES, cells, get_config  # noqa: E402
from repro.distributed import sharding as shard_lib  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.optim.adamw import AdamWConfig, init_state  # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12         # bf16
HBM_BW = 819e9              # bytes/s
ICI_BW = 50e9               # bytes/s per link (one effective ring link)


def input_specs(cfg, shape_name: str, grad_accum: int = 1):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    tok = jnp.int32
    emb = jnp.dtype(cfg.dtype)
    if sh["kind"] == "train":
        if cfg.input_mode == "tokens":
            inp = jax.ShapeDtypeStruct((b, s), tok)
        else:
            inp = jax.ShapeDtypeStruct((b, s, cfg.d_model), emb)
        lab = jax.ShapeDtypeStruct((b, s), tok)
        batch = {"inputs": inp, "labels": lab}
        if grad_accum > 1:
            batch = {k: jax.ShapeDtypeStruct(
                (grad_accum, v.shape[0] // grad_accum) + v.shape[1:],
                v.dtype) for k, v in batch.items()}
        return batch
    if sh["kind"] == "prefill":
        if cfg.input_mode == "tokens":
            return {"inputs": jax.ShapeDtypeStruct((b, s), tok)}
        return {"inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model), emb)}
    # decode: one new token against a seq_len cache
    if cfg.input_mode == "tokens":
        inp = jax.ShapeDtypeStruct((b, 1), tok)
    else:
        inp = jax.ShapeDtypeStruct((b, 1, cfg.d_model), emb)
    cache = jax.eval_shape(lambda: model_lib.init_cache(cfg, b, s))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"inputs": inp, "cache": cache, "pos": pos}


def model_flops(cfg, shape_name: str) -> float:
    """Useful FLOPs: 6*N_active*D train / 2*N_active*D inference, plus
    attention O(S^2 d) for the causal/local pattern actually configured."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    n_act = cfg.active_param_count()
    attn = 0.0
    if cfg.ssm_kind is None:
        hd, h = cfg.hd, cfg.n_heads
        for i in range(cfg.n_layers):
            kind = cfg.attn_kind(i)
            if sh["kind"] == "decode":
                kv = min(s, cfg.local_window) if kind == "local" else s
                attn += 2 * 2 * b * h * hd * kv          # qk + pv
            else:
                kv = min(s, cfg.local_window) if kind == "local" else s
                attn += 2 * 2 * b * h * hd * s * kv / (
                    1 if kind == "local" else 2)          # causal half
    if sh["kind"] == "train":
        return 6 * n_act * b * s + 3 * attn
    if sh["kind"] == "prefill":
        return 2 * n_act * b * s + attn
    return 2 * n_act * b + attn                            # decode: 1 tok


def _parse_overrides(s):
    """--opt 'attn_schedule=triangular,megatron_sp=true,grad_accum=4'."""
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides=None):
    meta = dict(META[arch])
    cfg = get_config(arch)
    ov = dict(overrides or {})
    for k in ("grad_accum", "fsdp", "seq_shard", "moments"):
        if k in ov:
            meta[k] = ov.pop(k)
    ep_data = bool(ov.pop("ep_data", False))
    if ov:
        cfg = cfg.replace(**ov)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    accum = meta["grad_accum"] if kind == "train" else 1
    # each microbatch must still cover the DP axes
    dp_size = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                           if a in mesh.shape]))
    accum = max(1, min(accum, sh["batch"] // dp_size))

    abs_params = model_lib.abstract_init(cfg)
    fsdp_axes = ("pod", "data") if multi_pod else ("data",)
    pspecs = shard_lib.param_spec_tree(abs_params, cfg, fsdp=meta["fsdp"],
                                       fsdp_axes=fsdp_axes,
                                       ep_data=ep_data)
    pshard = shard_lib.named_sharding_tree(pspecs, mesh)
    acts = shard_lib.act_specs(mesh, seq_shard=meta["seq_shard"],
                               ep_data=ep_data)
    specs = input_specs(cfg, shape_name, grad_accum=accum)
    dp = shard_lib.dp_axes(mesh)

    with mesh, shard_lib.activation_specs(acts):
        if kind == "train":
            from repro.launch.train import TrainConfig, make_train_step
            tcfg = TrainConfig(grad_accum=accum, optimizer=AdamWConfig(
                moment_dtype=meta.get("moments", "float32")))
            step = make_train_step(cfg, tcfg)
            abs_opt = jax.eval_shape(
                lambda: init_state(abs_params, tcfg.optimizer))
            oshard = {"m": pshard, "v": pshard,
                      "count": NamedSharding(mesh, P())}
            lead = (None,) if accum > 1 else ()
            bshard = {
                "inputs": NamedSharding(mesh, P(*lead, dp, *([None] * (
                    1 if cfg.input_mode == "tokens" else 2)))),
                "labels": NamedSharding(mesh, P(*lead, dp, None)),
            }
            fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(abs_params, abs_opt, specs)
        elif kind == "prefill":
            from repro.models.model import prefill
            bshard = NamedSharding(mesh, P(dp, *([None] * (
                1 if cfg.input_mode == "tokens" else 2))))
            fn = jax.jit(lambda p, x: prefill(p, x, cfg),
                         in_shardings=(pshard, bshard))
            lowered = fn.lower(abs_params, specs["inputs"])
        else:  # decode
            from repro.models.model import decode_step
            b = sh["batch"]
            cshard = shard_lib.cache_spec_tree(specs["cache"], cfg, mesh, b)
            dp_size = int(np.prod([mesh.shape[a] for a in dp]))
            bax = dp if (b >= dp_size and b % dp_size == 0) else None
            ishard = NamedSharding(mesh, P(bax, *([None] * (
                1 if cfg.input_mode == "tokens" else 2))))
            fn = jax.jit(
                lambda p, x, c, pos: decode_step(p, x, c, pos, cfg),
                in_shardings=(pshard, ishard, cshard,
                              NamedSharding(mesh, P())),
                donate_argnums=(2,))
            lowered = fn.lower(abs_params, specs["inputs"],
                               specs["cache"], specs["pos"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    cost = hlo_analysis.analyze(txt)
    hlo_out = os.environ.get("DRYRUN_HLO_OUT")
    if hlo_out:
        import gzip
        with gzip.open(hlo_out, "wt") as f:
            f.write(txt)

    useful = model_flops(cfg, shape_name)
    per_dev_useful = useful / chips
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.bytes_accessed / HBM_BW
    coll_s = cost.coll_wire_bytes / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": kind, "grad_accum": accum,
        "compile_s": round(compile_s, 1),
        "mem": {
            "argument_gib": ma.argument_size_in_bytes / 2**30,
            "output_gib": ma.output_size_in_bytes / 2**30,
            "temp_gib": ma.temp_size_in_bytes / 2**30,
            "alias_gib": ma.alias_size_in_bytes / 2**30,
            "peak_est_gib": (ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes
                             - ma.alias_size_in_bytes) / 2**30,
        },
        "hlo": {
            "flops_per_dev": cost.flops,
            "bytes_per_dev": cost.bytes_accessed,
            "coll_bytes_per_dev": cost.coll_bytes,
            "coll_wire_bytes_per_dev": cost.coll_wire_bytes,
            "coll_by_type": dict(cost.coll_by_type),
            "coll_count": dict(cost.coll_count),
            "xla_cost_flops_unrolled_once": ca.get("flops", -1),
        },
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "model_flops_total": useful,
            "model_flops_per_dev": per_dev_useful,
            "useful_ratio": per_dev_useful / max(cost.flops, 1.0),
            "roofline_s": max(compute_s, memory_s, coll_s),
            "roofline_frac": min(1.0, per_dev_useful / PEAK_FLOPS
                                 / max(compute_s, memory_s, coll_s)),
        },
    }


def run_cell_subprocess(arch, shape, mesh_kind, out_path, opt=None):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh_kind, "--json-out", out_path]
    if opt:
        cmd += ["--opt", opt]
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    env["DRYRUN_HLO_OUT"] = out_path.replace(".json", ".hlo.gz")
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=7200)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--results-dir", default="results/dryrun")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--opt", default=None,
                    help="cfg/meta overrides, e.g. "
                         "attn_schedule=triangular,megatron_sp=true")
    args = ap.parse_args()

    if args.all:
        os.makedirs(args.results_dir, exist_ok=True)
        meshes = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
        jobs = []
        for arch, shape, skipped in cells():
            for mk in meshes:
                out = os.path.join(args.results_dir,
                                   f"{arch}__{shape}__{mk}.json")
                if os.path.exists(out):
                    print(f"skip (cached): {out}")
                    continue
                jobs.append((arch, shape, mk, out))
        import concurrent.futures as cf
        with cf.ThreadPoolExecutor(args.jobs) as ex:
            futs = {ex.submit(run_cell_subprocess, *j): j for j in jobs}
            for f in cf.as_completed(futs):
                arch, shape, mk, out = futs[f]
                r = f.result()
                ok = r.returncode == 0 and os.path.exists(out)
                print(f"[{'OK' if ok else 'FAIL'}] {arch} {shape} {mk}")
                if not ok:
                    print(r.stdout[-2000:])
                    print(r.stderr[-4000:])
        return

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    records = []
    for mp in meshes:
        rec = lower_cell(args.arch, args.shape, multi_pod=mp,
                         overrides=_parse_overrides(args.opt))
        if args.opt:
            rec["overrides"] = args.opt
        records.append(rec)
        r = rec["roofline"]
        print(f"== {args.arch} {args.shape} mesh={rec['mesh']} "
              f"compile={rec['compile_s']}s")
        print(f"   mem/device: {rec['mem']['peak_est_gib']:.2f} GiB "
              f"(args {rec['mem']['argument_gib']:.2f} + temps "
              f"{rec['mem']['temp_gib']:.2f})")
        print(f"   roofline: compute={r['compute_s']:.4f}s "
              f"memory={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
              f"-> {r['dominant']}-bound, useful_ratio="
              f"{r['useful_ratio']:.3f} frac={r['roofline_frac']:.3f}")
        print(f"   collectives: {rec['hlo']['coll_count']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(records if len(records) > 1 else records[0], f,
                      indent=2)


if __name__ == "__main__":
    try:
        main()
    except Exception:
        traceback.print_exc()
        sys.exit(1)
