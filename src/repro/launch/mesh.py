"""Mesh construction for the production topology.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; smoke tests see
the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ('data' x 'model'); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU smoke / elastic restart)."""
    n = jax.device_count()
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by tp={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def resolve_cli_mesh(spec: str):
    """One mesh for the whole process, from a CLI flag.

    '' -> None (single device); 'host' -> every visible device as
    (data, model=1); 'DxM' -> an explicit (data, model) shape.  The
    returned mesh is the one :mod:`repro.distributed.sharding` rules
    partition over AND the one the block-space kernels shard over (their
    ``shard_axis`` defaults to this mesh's 'data' axis), so serving and
    training never build a second mesh for the fractal side."""
    if not spec:
        return None
    if spec == "host":
        return make_host_mesh()
    try:
        data, model = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise ValueError(
            f"--mesh expects '', 'host' or 'DATAxMODEL' (e.g. '4x2'); "
            f"got {spec!r}") from None
    return jax.make_mesh((data, model), ("data", "model"))
