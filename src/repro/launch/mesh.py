"""Mesh construction for the production topology.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; smoke tests see
the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ('data' x 'model'); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU smoke / elastic restart)."""
    n = jax.device_count()
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by tp={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
