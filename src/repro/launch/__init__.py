# launch: mesh construction, dry-run, trainer, server.
# NOTE: dryrun must be executed as a script/module so its XLA_FLAGS
# device-count override happens before jax initializes.
from . import mesh
