"""Post-SPMD HLO cost walker for the roofline analysis.

XLA's ``compiled.cost_analysis()`` visits each instruction ONCE -- a
``lax.scan`` over 64 layers contributes a single body's FLOPs (verified
empirically; see tests).  Since every production model here scans its
layer stack, we walk the optimized HLO text ourselves:

  * while loops multiply their body/condition costs by the trip count
    (recovered from the loop-bound constant in the condition);
  * fusions are charged inputs+outputs for memory (XLA's own model) and
    their inner dot/elementwise FLOPs;
  * collectives are tallied per type with BOTH raw operand bytes and an
    estimated wire-traffic byte count (ring algorithms:
    all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n of the full
    tensor, all-to-all (n-1)/n, collective-permute 1x).

All quantities are PER DEVICE (the module is the SPMD-partitioned
per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "negate", "abs", "sign",
    "cosine", "sine", "logistic", "floor", "ceil", "round-nearest-afz",
    "compare", "select", "and", "or", "xor", "not", "clamp", "convert",
    "erf",
}

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "reshape",
    "custom-call", "rng-bit-generator", "optimization-barrier",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # text after opcode


_DEF_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\(.*?\)|[a-z0-9]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\((.*)", re.S)


def parse_module(txt: str):
    """Returns (computations: name -> [Instr], entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for raw in txt.splitlines():
        s = raw.strip()
        if cur is None:
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                m = _DEF_RE.match(s)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    if entry is None:
        # fall back: computation containing no callers
        entry = next(iter(comps))
    return comps, entry


def _trip_count(cond_instrs: List[Instr]) -> int:
    """Loop bound heuristic: max integer constant in the condition."""
    best = 1
    for ins in cond_instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return int(m.group(2))
    # long-form replica_groups={{0,1,2,3},...}
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return 2


def _wire_multiplier(op: str, n: int) -> float:
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter"):
        # operand of all-gather is the shard; result n shards; wire moves
        # (n-1) shards = (n-1) x operand bytes
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: float = 0.0          # raw operand bytes
    coll_wire_bytes: float = 0.0     # algorithm-aware wire traffic
    coll_by_type: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    bytes_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    flops_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes_accessed += mult * other.bytes_accessed
        self.coll_bytes += mult * other.coll_bytes
        self.coll_wire_bytes += mult * other.coll_wire_bytes
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] += mult * v
        for k, v in other.coll_count.items():
            self.coll_count[k] += int(mult * v)
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] += mult * v
        for k, v in other.flops_by_op.items():
            self.flops_by_op[k] += mult * v

    def charge(self, op: str, *, flops: float = 0.0, byts: float = 0.0):
        self.flops += flops
        self.bytes_accessed += byts
        if flops:
            self.flops_by_op[op] += flops
        if byts:
            self.bytes_by_op[op] += byts


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_elems = _shape_elems(ins.type_str)
    # contraction size from lhs shape and lhs_contracting_dims
    ops = re.findall(r"%([\w\.\-]+)", ins.rest.split(")")[0])
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    k = 1
    if ops and m and ops[0] in shapes:
        dims_str = _SHAPE_RE.search(shapes[ops[0]])
        if dims_str:
            dims = [int(d) for d in dims_str.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def analyze(txt: str) -> HloCost:
    comps, entry = parse_module(txt)
    shape_tables = {
        cname: {i.name: i.type_str for i in instrs}
        for cname, instrs in comps.items()
    }
    memo: Dict[str, HloCost] = {}

    def walk(cname: str, top_level: bool) -> HloCost:
        key = cname + ("|t" if top_level else "|f")
        if key in memo:
            return memo[key]
        cost = HloCost()
        shapes = shape_tables.get(cname, {})
        for ins in comps.get(cname, []):
            op = ins.opcode
            base = op.replace("-start", "")
            if op.endswith("-done"):
                continue
            if base in _COLLECTIVES:
                b = _shape_bytes(ins.type_str if base != "all-gather"
                                 else _operand_types(ins, shapes))
                n = _group_size(ins.rest)
                cost.coll_bytes += b
                w = b * _wire_multiplier(base, n)
                cost.coll_wire_bytes += w
                cost.coll_by_type[base] += w
                cost.coll_count[base] += 1
                cost.charge(base, byts=_shape_bytes(ins.type_str))
                continue
            if op == "while":
                body, cond = _while_targets(ins.rest)
                trips = _trip_count(comps.get(cond, []))
                if body:
                    cost.add(walk(body, top_level), trips)
                if cond:
                    cost.add(walk(cond, top_level), trips)
                continue
            if op == "conditional":
                for branch in _cond_targets(ins.rest):
                    cost.add(walk(branch, top_level), 1.0)
                continue
            if op == "fusion":
                callee = _fusion_target(ins.rest)
                reduces = has_dus = False
                if callee:
                    inner = walk(callee, False)
                    cost.charge("fusion:inner", flops=inner.flops)
                    callee_ops = {i.opcode for i in comps.get(callee, [])}
                    reduces = bool(callee_ops & {"reduce", "reduce-window"})
                    has_dus = "dynamic-update-slice" in callee_ops
                if top_level:
                    out_b = _shape_bytes(ins.type_str)
                    op_bytes = [
                        _shape_bytes(shapes.get(nm, ""))
                        for nm in re.findall(r"%([\w\.\-]+)",
                                             ins.rest.split("),")[0])]
                    if has_dus and any(ob == out_b for ob in op_bytes):
                        # in-place cache update threaded through a loop:
                        # traffic = the written window (approximated by
                        # the non-pass-through operands), NOT the buffer
                        rest_b = sum(ob for ob in op_bytes if ob != out_b)
                        cost.charge("fusion:dus", byts=2 * rest_b)
                    else:
                        ops_b = sum(ob if reduces else min(ob, out_b)
                                    for ob in op_bytes)
                        cost.charge("fusion", byts=out_b + ops_b)
                continue
            if op == "call":
                callee = _fusion_target(ins.rest) or _call_target(ins.rest)
                if callee:
                    cost.add(walk(callee, top_level), 1.0)
                continue
            if op == "dot":
                cost.charge("dot", flops=_dot_flops(ins, shapes))
                if top_level:
                    cost.charge("dot", byts=_shape_bytes(ins.type_str)
                                + _operand_bytes(ins, shapes))
                continue
            if op in _ZERO_COST:
                continue
            if op in ("dynamic-update-slice",):
                upd = _operand_type_n(ins, shapes, 1)
                if top_level:
                    cost.charge(op, byts=2 * _shape_bytes(upd))
                continue
            if op in ("dynamic-slice", "copy", "slice", "transpose",
                      "concatenate", "pad", "gather", "scatter",
                      "reverse", "sort", "cumsum"):
                # data-movement ops: traffic ~ read + write of the RESULT
                # (charging operands would bill e.g. a dynamic-slice of
                # the full stacked layer params on every loop iteration)
                if top_level:
                    cost.charge(op, byts=2 * _shape_bytes(ins.type_str))
                continue
            if op == "broadcast":
                if top_level:
                    cost.charge(op, byts=_shape_bytes(ins.type_str))
                continue
            if op in ("reduce", "reduce-window"):
                cost.charge(op, flops=_operand_elems(ins, shapes))
                if top_level:
                    cost.charge(op, byts=_shape_bytes(ins.type_str)
                                + _operand_bytes(ins, shapes))
                continue
            if op in _ELEMENTWISE:
                cost.charge(op, flops=_shape_elems(ins.type_str))
                if top_level:
                    cost.charge(op, byts=_shape_bytes(ins.type_str)
                                + _operand_bytes(ins, shapes))
                continue
            # unknown op: charge memory when top-level, no flops
            if top_level:
                cost.charge("other:" + op,
                            byts=_shape_bytes(ins.type_str))
        memo[key] = cost
        return cost

    def _operand_types(ins: Instr, shapes) -> str:
        names = re.findall(r"%([\w\.\-]+)", ins.rest.split("),")[0])
        return ",".join(shapes.get(n, "") for n in names)

    def _operand_bytes(ins: Instr, shapes) -> int:
        return _shape_bytes(_operand_types(ins, shapes))

    def _operand_elems(ins: Instr, shapes) -> int:
        return _shape_elems(_operand_types(ins, shapes))

    def _operand_type_n(ins: Instr, shapes, n: int) -> str:
        names = re.findall(r"%([\w\.\-]+)", ins.rest.split("),")[0])
        return shapes.get(names[n], "") if len(names) > n else ""

    return walk(entry, True)


def _while_targets(rest: str) -> Tuple[Optional[str], Optional[str]]:
    mb = re.search(r"body=%?([\w\.\-]+)", rest)
    mc = re.search(r"condition=%?([\w\.\-]+)", rest)
    return (mb.group(1) if mb else None, mc.group(1) if mc else None)


def _fusion_target(rest: str) -> Optional[str]:
    m = re.search(r"calls=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _call_target(rest: str) -> Optional[str]:
    m = re.search(r"to_apply=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None
