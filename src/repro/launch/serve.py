"""Batched serving: prefill -> slot-based decode loop with temperature /
greedy sampling and continuous-batching-style slot replacement.

Runnable directly:
    PYTHONPATH=src python -m repro.launch.serve --arch quickstart
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.models import ModelConfig, decode_step, init, prefill
from repro.models import model as model_lib
from repro.distributed import sharding as shard_lib


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0       # 0 = greedy
    top_k: int = 40
    seed: int = 0
    eos_id: int = -1               # -1 = never stop early


class Server:
    """Holds jitted prefill/decode closures over a fixed batch shape."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 mesh: Optional[Mesh] = None):
        self.cfg, self.params, self.scfg, self.mesh = cfg, params, scfg, mesh
        self._prefill = jax.jit(
            partial(prefill, cfg=cfg, max_len=scfg.max_len))
        self._decode = jax.jit(partial(decode_step, cfg=cfg))
        self._rng = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits):
        """logits (B,1,V) -> tokens (B,1)."""
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, 0], axis=-1)[:, None]
        self._rng, k = jax.random.split(self._rng)
        scaled = logits[:, 0].astype(jnp.float32) / self.scfg.temperature
        if self.scfg.top_k:
            v, _ = jax.lax.top_k(scaled, self.scfg.top_k)
            scaled = jnp.where(scaled < v[:, -1:], -1e30, scaled)
        return jax.random.categorical(k, scaled)[:, None]

    def generate(self, prompts: np.ndarray, max_new: int = 32):
        """prompts: (B, S) int tokens (token-input archs).  Returns the
        generated (B, max_new) continuation."""
        ctx = self.mesh if self.mesh is not None else _null()
        with ctx:
            logits, cache = self._prefill(self.params, jnp.asarray(prompts))
            pos = prompts.shape[1] - 1
            tok = self._sample(logits)
            out = [tok]
            for i in range(max_new - 1):
                pos += 1
                logits, cache = self._decode(self.params, tok, cache,
                                             jnp.asarray(pos, jnp.int32))
                tok = self._sample(logits)
                out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))


def throughput_report(server: Server, batch: int, prompt_len: int,
                      max_new: int = 16):
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, server.cfg.vocab_size, (batch, prompt_len))
    t0 = time.perf_counter()
    out = server.generate(prompts, max_new=max_new)
    dt = time.perf_counter() - t0
    return {"tokens": int(out.size), "seconds": dt,
            "tok_per_s": out.size / dt}


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="quickstart")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--grid-lowering", default="",
                    choices=("", "closed_form", "prefetch_lut", "bounding",
                             "compact"),
                    help="GridPlan lowering for the attention block "
                         "domain (default: the arch's attn_schedule)")
    ap.add_argument("--backend", default="",
                    choices=("", "tpu", "gpu", "tpu-interpret",
                             "gpu-interpret", "interpret"),
                    help="kernel emission target for every block-space "
                         "Pallas call (repro.core.backend; default: "
                         "platform / REPRO_BACKEND)")
    ap.add_argument("--decode-kernel", default="",
                    choices=("", "xla", "blockspace"),
                    help="decode attention path: 'blockspace' runs the "
                         "Pallas flash kernel with the run-time seq_pos "
                         "block skip, sharding continuous-batching slot "
                         "groups over the mesh (default: the arch's "
                         "setting, normally 'xla')")
    ap.add_argument("--mesh", default="",
                    help="serve on a device mesh: 'host' (all devices, "
                         "tp=1) or 'DATAxMODEL' (e.g. '4x2').  The same "
                         "mesh drives the sharding.py param/cache specs "
                         "and the block-space kernels' shard_axis "
                         "('data') -- one mesh for the whole process.")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.mesh import resolve_cli_mesh
    cfg = get_config(args.arch, smoke=True)
    if args.grid_lowering:
        cfg = cfg.replace(grid_lowering=args.grid_lowering)
        print(f"grid lowering: {cfg.grid_mode} "
              f"(xla schedule: {cfg.attn_schedule_resolved})")
    if args.backend:
        from repro.core import backend as backend_lib
        backend_lib.set_default(args.backend)
        print(f"kernel backend: {backend_lib.resolve(None).name}")
    if args.decode_kernel:
        cfg = cfg.replace(attn_decode_kernel=args.decode_kernel)
        print(f"decode attention: {cfg.attn_decode_kernel}")
    mesh = resolve_cli_mesh(args.mesh)
    if cfg.attn_decode_kernel == "blockspace":
        from repro.models import attention as attn_lib
        attn_lib.set_decode_mesh(mesh)
    if mesh is not None:
        print(f"mesh: {dict(mesh.shape)} over {len(mesh.devices.flat)} "
              f"devices (kernels shard over axis 'data')")
        param_specs = shard_lib.param_spec_tree(
            model_lib.abstract_init(cfg), cfg)
        init_fn = jax.jit(
            partial(init, cfg=cfg),
            out_shardings=shard_lib.named_sharding_tree(param_specs,
                                                        mesh))
        with mesh:
            params = init_fn(jax.random.PRNGKey(0))
    else:
        params = init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.max_new,
        temperature=args.temperature), mesh=mesh)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len))
    out = server.generate(prompts, max_new=args.max_new)
    print("generated shape:", out.shape)
    print(throughput_report(server, args.batch, args.prompt_len,
                            args.max_new))


if __name__ == "__main__":
    main()
