"""Guarded batched serving: prefill -> slot-based decode loop with
EOS-aware slot masking, replay-deterministic sampling, and a
detect-degrade-recover runtime around every jitted call.

Robustness model (see :mod:`repro.runtime`):

* every prefill/decode call runs under a
  :class:`~repro.runtime.guard.GuardedCall` -- per-call deadline,
  NaN/inf output screens, transient-vs-fatal classification, jittered
  backoff retries;
* sampling keys derive from ``(seed, slot, position)`` via
  ``jax.random.fold_in`` (pure coordinates, no mutated RNG state), so
  a retried or resumed decode step reproduces the identical stream;
* repeated failure walks a :class:`DegradationLadder`
  (blockspace -> xla decode, exotic lowering -> closed_form),
  re-jitting the decode step per rung and recording each transition;
* SIGTERM flips the state machine healthy -> draining: the decode
  state (prompts + generated tokens + position) checkpoints atomically
  and a successor process resumes mid-generation
  (:meth:`Server.resume`), bit-identical to an uninterrupted run;
* exhausted recovery emits a machine-readable
  :class:`~repro.runtime.guard.FailureReport`.

Runnable directly:
    PYTHONPATH=src python -m repro.launch.serve --arch quickstart
Chaos-smoke (deterministic fault injection; see repro.runtime.chaos):
    PYTHONPATH=src python -m repro.launch.serve --chaos-seed 7
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import os
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed import sharding as shard_lib
from repro.distributed.fault_tolerance import PreemptionGuard
from repro.models import ModelConfig, decode_step, init, prefill
from repro.models import model as model_lib
from repro.runtime.guard import (Backoff, DegradationLadder, GuardedCall,
                                 GuardExhausted, ServerState, sample_key,
                                 spot_check, validate_finite)


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0       # 0 = greedy
    top_k: int = 40
    seed: int = 0
    eos_id: int = -1               # -1 = never stop early
    # -- robustness ---------------------------------------------------------
    guard: bool = True             # False = raw jitted calls (no retries)
    retries: int = 3
    backoff_base_s: float = 0.05
    deadline_s: Optional[float] = None
    enforce_deadline: bool = False
    validate: bool = True          # NaN/inf screen on every output
    spot_check_every: int = 0      # decode steps between lambda canaries
    ckpt_dir: Optional[str] = None  # decode-state checkpoint directory
    ckpt_every: int = 0            # decode steps between checkpoints
    report_dir: Optional[str] = None  # failure reports land here


class Server:
    """Holds guarded jitted prefill/decode closures over a fixed batch
    shape, plus the serving state machine (healthy -> degraded ->
    draining) and the degradation ladder."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 mesh: Optional[Mesh] = None, chaos=None):
        self.cfg, self.params, self.scfg, self.mesh = cfg, params, scfg, mesh
        self.chaos = chaos
        self.state = ServerState.HEALTHY
        self.events: list = []
        self.ladder = DegradationLadder(
            self._rungs(cfg),
            on_transition=lambda rec: self.events.append(
                {"kind": "degrade", **rec}))
        self._base_key = jax.random.PRNGKey(scfg.seed)
        self._canary_ref = None
        self._ckpt = None
        if scfg.ckpt_dir:
            from repro.checkpoint.manager import CheckpointManager
            self._ckpt = CheckpointManager(scfg.ckpt_dir, keep=2)
        self._prefill_fn = jax.jit(
            partial(prefill, cfg=cfg, max_len=scfg.max_len))
        self._decode_fn = None
        self._apply_rung(self.ladder.current())
        self._prefill = self._guarded("serve.prefill",
                                      lambda *a: self._prefill_fn(*a))
        self._decode = self._guarded("serve.decode",
                                     lambda *a: self._decode_fn(*a))

    # -- degradation ladder --------------------------------------------------

    @staticmethod
    def _rungs(cfg: ModelConfig) -> list:
        """Fallback configs, as-configured first: blockspace decode
        degrades to the XLA decode path, an exotic attention lowering
        (compact / prefetch_lut / mma) degrades to the inline closed
        form."""
        top = {"decode_kernel": cfg.attn_decode_kernel,
               "grid_lowering": cfg.grid_lowering}
        rungs = [top]
        if cfg.attn_decode_kernel == "blockspace":
            rungs.append({**top, "decode_kernel": "xla"})
        if cfg.grid_lowering in ("compact", "prefetch_lut", "mma"):
            rungs.append({"decode_kernel": "xla",
                          "grid_lowering": "closed_form"})
        return rungs

    def _apply_rung(self, rung: dict) -> None:
        """Re-jit the decode step under this rung's config (prefill and
        the cache layout are rung-independent)."""
        cfg = self.cfg.replace(attn_decode_kernel=rung["decode_kernel"],
                               grid_lowering=rung["grid_lowering"])
        self._decode_fn = jax.jit(partial(decode_step, cfg=cfg))

    # -- guard plumbing ------------------------------------------------------

    def _guarded(self, site: str, fn):
        if self.chaos is not None:
            fn = self.chaos.wrap(site, fn, rung=lambda: self.ladder.level)
        if not self.scfg.guard:
            return fn
        validators = []
        if self.scfg.validate:
            validators.append(lambda o, s=site: validate_finite(o, s))
        return GuardedCall(
            fn, site, retries=self.scfg.retries,
            backoff=Backoff(base_s=self.scfg.backoff_base_s,
                            seed=self.scfg.seed),
            deadline_s=self.scfg.deadline_s,
            enforce_deadline=self.scfg.enforce_deadline,
            validators=validators,
            on_event=self.events.append,
            before_retry=(self.chaos.refresh if self.chaos is not None
                          else None))

    def _decode_step(self, tok, cache, pos):
        """One guarded decode step; on exhausted recovery, walk the
        degradation ladder and re-execute on the lower rung."""
        while True:
            try:
                return self._decode(self.params, tok, cache,
                                    jnp.asarray(pos, jnp.int32))
            except GuardExhausted as e:
                if not self.ladder.step_down(reason=str(e)):
                    e.report.transitions = list(self.ladder.transitions)
                    self._write_report(e.report)
                    raise
                self.state = ServerState.DEGRADED
                self._apply_rung(self.ladder.current())

    def _write_report(self, report) -> Optional[str]:
        if not self.scfg.report_dir:
            return None
        path = os.path.join(self.scfg.report_dir,
                            f"failure_{report.name.replace('.', '_')}.json")
        return report.write(path)

    # -- lambda canary -------------------------------------------------------

    def check_substrate(self) -> None:
        """Spot-check the Pallas substrate: rerun a tiny known-good
        block-space launch and demand a bit-identical result (the repo
        invariant).  Raises ValidationError on mismatch."""
        from repro.kernels.sierpinski_write import sierpinski_write

        def canary():
            return sierpinski_write(jnp.zeros((16, 16), jnp.float32), 1.0,
                                    block=4, grid_mode="closed_form",
                                    coarsen=1, num_stages=1)

        out = canary()
        if self._canary_ref is None:
            self._canary_ref = np.asarray(out)
            return
        spot_check(self._canary_ref, "lambda canary")(out)

    # -- sampling ------------------------------------------------------------

    def _sample(self, logits, pos: int):
        """logits (B,1,V) -> tokens (B,1).  Keys are a pure function of
        (seed, slot, position): a retried / replayed step samples the
        identical token."""
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, 0], axis=-1)[:, None]
        scaled = logits[:, 0].astype(jnp.float32) / self.scfg.temperature
        if self.scfg.top_k:
            v, _ = jax.lax.top_k(scaled, self.scfg.top_k)
            scaled = jnp.where(scaled < v[:, -1:], -1e30, scaled)
        keys = sample_key(self._base_key, pos, scaled.shape[0])
        return jax.vmap(jax.random.categorical)(keys, scaled)[:, None]

    # -- decode-state checkpointing ------------------------------------------

    def _save_decode_state(self, prompts, out, pos: int,
                           max_new: int) -> None:
        if self._ckpt is None:
            return
        tokens = np.concatenate([np.asarray(t) for t in out], axis=1)
        state = {"prompts": np.asarray(prompts, np.int32),
                 "tokens": tokens.astype(np.int32)}
        self._ckpt.save(len(out), state,
                        extra={"pos": int(pos), "max_new": int(max_new),
                               "batch": int(tokens.shape[0]),
                               "prompt_len": int(np.shape(prompts)[1]),
                               "num_tokens": int(tokens.shape[1])})

    # -- generation ----------------------------------------------------------

    def generate(self, prompts: np.ndarray, max_new: int = 32):
        """prompts: (B, S) int tokens (token-input archs).  Returns the
        generated (B, T) continuation, T = max_new unless every slot
        hit ``eos_id`` (or a preemption drained the server) earlier;
        finished slots pad with ``eos_id``."""
        if self.state == ServerState.DRAINING:
            raise RuntimeError("server is draining; start a successor "
                               "and resume() from the decode checkpoint")
        scfg = self.scfg
        ctx = self.mesh if self.mesh is not None else _null()
        with PreemptionGuard() as preempt, ctx:
            logits, cache = self._prefill(self.params,
                                          jnp.asarray(prompts))
            batch = np.shape(prompts)[0]
            pos = np.shape(prompts)[1] - 1
            finished = np.zeros((batch,), bool)
            tok, finished = self._next_token(logits, pos, finished)
            out = [tok]
            for i in range(max_new - 1):
                if scfg.eos_id >= 0 and finished.all():
                    break
                if preempt.fired:
                    self._drain(prompts, out, pos, max_new)
                    break
                pos += 1
                logits, cache = self._decode_step(tok, cache, pos)
                tok, finished = self._next_token(logits, pos, finished)
                out.append(tok)
                if (scfg.spot_check_every
                        and (i + 1) % scfg.spot_check_every == 0):
                    self.check_substrate()
                if scfg.ckpt_every and len(out) % scfg.ckpt_every == 0:
                    self._save_decode_state(prompts, out, pos, max_new)
            else:
                if preempt.fired:
                    self._drain(prompts, out, pos, max_new)
        return np.asarray(jnp.concatenate(out, axis=1))

    def _next_token(self, logits, pos: int, finished: np.ndarray):
        """Sample, then overwrite finished slots with the EOS pad and
        fold newly-finished slots into the mask."""
        tok = self._sample(logits, pos)
        if self.scfg.eos_id < 0:
            return tok, finished
        tok = np.asarray(tok)
        tok = np.where(finished[:, None], self.scfg.eos_id, tok)
        finished = finished | (tok[:, 0] == self.scfg.eos_id)
        return jnp.asarray(tok), finished

    def _drain(self, prompts, out, pos: int, max_new: int) -> None:
        self.state = ServerState.DRAINING
        self.events.append({"kind": "drain", "pos": int(pos),
                            "tokens": len(out), "time": time.time()})
        self._save_decode_state(prompts, out, pos, max_new)

    # -- resume --------------------------------------------------------------

    def resume(self):
        """Resume a drained/preempted generation from the decode-state
        checkpoint: replay the saved tokens through prefill + decode to
        rebuild the KV cache (feeding the *saved* token at each replayed
        position -- no re-sampling, no drift), then keep sampling with
        the same (seed, slot, position) keys.  The full returned stream
        is bit-identical to an uninterrupted run."""
        if self._ckpt is None:
            raise RuntimeError("resume() needs ServeConfig.ckpt_dir")
        meta = self._ckpt.read_meta()
        e = meta["extra"]
        template = {
            "prompts": np.zeros((e["batch"], e["prompt_len"]), np.int32),
            "tokens": np.zeros((e["batch"], e["num_tokens"]), np.int32)}
        _, state, _, _ = self._ckpt.restore(meta["step"], template)
        prompts, saved = state["prompts"], np.asarray(state["tokens"])
        max_new = e["max_new"]
        ctx = self.mesh if self.mesh is not None else _null()
        with ctx:
            logits, cache = self._prefill(self.params,
                                          jnp.asarray(prompts))
            pos = prompts.shape[1] - 1
            finished = np.zeros((prompts.shape[0],), bool)
            out = []
            tok = jnp.asarray(saved[:, 0:1])
            out.append(tok)
            for i in range(1, saved.shape[1]):
                pos += 1
                logits, cache = self._decode_step(tok, cache, pos)
                tok = jnp.asarray(saved[:, i:i + 1])
                out.append(tok)
            if self.scfg.eos_id >= 0:
                finished = (saved == self.scfg.eos_id).any(axis=1)
            for _ in range(saved.shape[1], max_new):
                if self.scfg.eos_id >= 0 and finished.all():
                    break
                pos += 1
                logits, cache = self._decode_step(tok, cache, pos)
                tok, finished = self._next_token(logits, pos, finished)
                out.append(tok)
        self.state = ServerState.HEALTHY
        self.events.append({"kind": "resume", "replayed": saved.shape[1],
                            "total": len(out), "time": time.time()})
        return np.asarray(jnp.concatenate(out, axis=1))


# ---------------------------------------------------------------------------
# paged continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PagedServeConfig(ServeConfig):
    """ServeConfig plus the paged-pool knobs.  ``num_pages`` includes
    the reserved null page, so usable capacity is ``(num_pages - 1) *
    page_size`` tokens across all slots; ``max_len`` bounds one
    request's prompt + generation (it sizes the page table width, not
    any per-slot preallocation -- that is the whole point)."""
    num_slots: int = 4
    page_size: int = 16
    num_pages: int = 64


@dataclasses.dataclass
class _PagedRequest:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    pages: list = dataclasses.field(default_factory=list)
    next_pos: int = 0       # where the next fed token's KV lands
    seq: int = -1           # admission order (eviction priority)
    preemptions: int = 0


class PagedServer:
    """Continuous-batching serving over the paged KV pool.

    The decode batch is a fixed set of ``num_slots`` *slots* (static
    jitted shapes); requests stream through them.  Admission runs an
    unpadded prefill for one request, allocates ``ceil(len / page_size)``
    physical pages from the free list, and scatters the prefill KV into
    them (:func:`repro.models.model.scatter_prefill_pages`); every
    decode step then advances *all* active slots one token at their own
    positions (the per-row ``seq_pos`` vector) while inactive slots
    write to the null page.  Pages are allocated on demand as slots
    cross page boundaries; when the pool runs dry the youngest active
    request is preempted -- its pages freed, the request requeued with
    its generated tokens kept, to be re-admitted by replaying
    prompt + generated through prefill (recompute-style preemption).

    Sampling keys derive from ``(seed, request_id, position)``, so a
    preempted-and-readmitted request keeps drawing the same stream --
    eviction composes with the replay-deterministic robustness story of
    :class:`Server`.  Repeated decode failure walks the degradation
    ladder paged-blockspace -> paged-xla (the
    :func:`~repro.models.attention.decode_attention_paged_xla` gather
    rung), re-jitting the step like :meth:`Server._apply_rung` does.
    """

    _guarded = Server._guarded
    _write_report = Server._write_report
    check_substrate = Server.check_substrate

    def __init__(self, cfg: ModelConfig, params, scfg: PagedServeConfig,
                 chaos=None):
        from repro.core import paged as paged_lib

        model_lib._check_paged(cfg)
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.chaos = chaos
        self.mesh = None
        self.state = ServerState.HEALTHY
        self.events: list = []
        self.stats_history: list = []
        self._paged_lib = paged_lib
        self.alloc = paged_lib.PagedKVPool(scfg.num_pages, scfg.page_size)
        self.max_pages = -(-scfg.max_len // scfg.page_size)
        self.pools = model_lib.init_paged_cache(
            cfg, scfg.num_pages, scfg.page_size)
        self.table = np.full((scfg.num_slots, self.max_pages),
                             paged_lib.NULL_PAGE, np.int32)
        self.slots: list = [None] * scfg.num_slots
        self.pending: collections.deque = collections.deque()
        self.done: dict = {}
        self._admit_seq = 0
        self.ladder = DegradationLadder(
            self._rungs(cfg),
            on_transition=lambda rec: self.events.append(
                {"kind": "degrade", **rec}))
        self._base_key = jax.random.PRNGKey(scfg.seed)
        self._canary_ref = None
        self._prefill_fn = jax.jit(partial(prefill, cfg=cfg))
        self._scatter_fn = jax.jit(
            partial(model_lib.scatter_prefill_pages, cfg=cfg))
        self._decode_fn = None
        self._apply_rung(self.ladder.current())
        self._prefill = self._guarded("serve.prefill",
                                      lambda *a: self._prefill_fn(*a))
        self._decode = self._guarded("serve.decode",
                                     lambda *a: self._decode_fn(*a))

    @staticmethod
    def _rungs(cfg: ModelConfig) -> list:
        top = {"decode_kernel": cfg.attn_decode_kernel}
        rungs = [top]
        if cfg.attn_decode_kernel == "blockspace":
            rungs.append({"decode_kernel": "xla"})  # paged-xla gather
        return rungs

    def _apply_rung(self, rung: dict) -> None:
        cfg = self.cfg.replace(attn_decode_kernel=rung["decode_kernel"])
        self._decode_fn = jax.jit(
            partial(model_lib.decode_step_paged, cfg=cfg))

    # -- host bookkeeping ----------------------------------------------------

    def _verify_table(self) -> None:
        if not self.scfg.validate:
            return
        from repro.analysis.verifier import verify_page_table
        verify_page_table(
            self.table,
            seq_lens=[(r.next_pos if r is not None else 0)
                      for r in self.slots],
            page_size=self.scfg.page_size,
            num_pages=self.scfg.num_pages,
            free_pages=self.alloc._free)

    def pool_stats(self) -> dict:
        return self.alloc.stats(
            [r.next_pos for r in self.slots if r is not None])

    # -- request lifecycle ---------------------------------------------------

    def submit(self, rid: int, prompt: np.ndarray, max_new: int) -> None:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + max_new > self.scfg.max_len:
            raise ValueError(
                f"request {rid}: prompt {len(prompt)} + max_new "
                f"{max_new} exceeds max_len {self.scfg.max_len}")
        self.pending.append(_PagedRequest(
            rid=int(rid), prompt=prompt, max_new=int(max_new)))

    def _sample_token(self, logits_row, rid: int, pos: int) -> int:
        """One token from a (V,) logits row.  The key is a pure
        function of (seed, request id, position): a preempted and
        re-admitted request draws the identical stream."""
        scfg = self.scfg
        if scfg.temperature <= 0:
            return int(np.argmax(np.asarray(logits_row)))
        scaled = np.asarray(logits_row, np.float32) / scfg.temperature
        if scfg.top_k:
            kth = np.sort(scaled)[-scfg.top_k]
            scaled = np.where(scaled < kth, -1e30, scaled)
        key = jax.random.fold_in(
            jax.random.fold_in(self._base_key, rid), pos)
        return int(jax.random.categorical(key, jnp.asarray(scaled)))

    def _admit_one(self) -> bool:
        """Admit the head-of-line request if a slot and enough pages
        are free.  Returns True on admission."""
        if not self.pending:
            return False
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        if not free_slots:
            return False
        req = self.pending[0]
        tokens = np.concatenate(
            [req.prompt, np.asarray(req.out, np.int32)])
        need = self._paged_lib.pages_for(len(tokens), self.scfg.page_size)
        if not self.alloc.can_alloc(need):
            return False
        self.pending.popleft()
        pages = self.alloc.alloc(need)
        slot = free_slots[0]
        logits, caches = self._prefill(
            self.params, jnp.asarray(tokens[None]))
        self.pools = self._scatter_fn(
            self.pools, caches, jnp.asarray(pages, jnp.int32))
        req.pages = list(pages)
        req.seq = self._admit_seq
        self._admit_seq += 1
        req.next_pos = len(tokens)
        self.table[slot] = self._paged_lib.NULL_PAGE
        self.table[slot, :len(pages)] = pages
        self.slots[slot] = req
        self._verify_table()
        tok = self._sample_token(np.asarray(logits)[0, 0], req.rid,
                                 len(tokens) - 1)
        req.out.append(tok)
        if self._finished(slot, tok):
            return True
        self.events.append({"kind": "admit", "rid": req.rid,
                            "slot": slot, "pages": len(pages),
                            "replayed": len(req.out) - 1})
        return True

    def _finished(self, slot: int, tok: int) -> bool:
        req = self.slots[slot]
        if len(req.out) >= req.max_new or (
                self.scfg.eos_id >= 0 and tok == self.scfg.eos_id):
            self.alloc.free(req.pages)
            self.table[slot] = self._paged_lib.NULL_PAGE
            self.slots[slot] = None
            self.done[req.rid] = np.asarray(req.out, np.int32)
            self.events.append({"kind": "finish", "rid": req.rid,
                                "tokens": len(req.out),
                                "preemptions": req.preemptions})
            self._verify_table()
            return True
        return False

    def _preempt(self, slot: int) -> None:
        req = self.slots[slot]
        self.alloc.free(req.pages)
        req.pages = []
        req.preemptions += 1
        self.table[slot] = self._paged_lib.NULL_PAGE
        self.slots[slot] = None
        self.pending.appendleft(req)  # re-admit first
        self.events.append({"kind": "preempt", "rid": req.rid,
                            "slot": slot, "generated": len(req.out)})
        # no _verify_table here: surviving slots may already hold the
        # look-ahead page grown for the write this step, which the
        # verifier would flag as tail-null until next_pos advances.
        # step() verifies once the step is quiescent.

    def _grow(self, slot: int) -> bool:
        """Ensure the slot owns the page its next KV write lands in."""
        req = self.slots[slot]
        while req.next_pos // self.scfg.page_size >= len(req.pages):
            got = self.alloc.alloc(1)
            if got is None:
                return False
            self.table[slot, len(req.pages)] = got[0]
            req.pages += got
        return True

    def _decode_step(self, toks, posv, act):
        while True:
            try:
                return self._decode(self.params, toks, self.pools,
                                    jnp.asarray(self.table), posv, act)
            except GuardExhausted as e:
                if not self.ladder.step_down(reason=str(e)):
                    e.report.transitions = list(self.ladder.transitions)
                    self._write_report(e.report)
                    raise
                self.state = ServerState.DEGRADED
                self._apply_rung(self.ladder.current())

    def step(self) -> bool:
        """One decode step for every active slot.  Returns False when
        nothing is active."""
        active = [i for i in range(len(self.slots))
                  if self.slots[i] is not None]
        if not active:
            return False
        # on-demand page growth, oldest slots first; preempt the
        # youngest active request until the survivors fit
        for i in sorted(active, key=lambda j: self.slots[j].seq):
            while self.slots[i] is not None and not self._grow(i):
                victims = [j for j in range(len(self.slots))
                           if self.slots[j] is not None]
                victim = max(victims, key=lambda j: self.slots[j].seq)
                if victim == i and len(victims) == 1:
                    raise RuntimeError(
                        f"pool of {self.scfg.num_pages} pages cannot "
                        f"hold a single request; raise num_pages or "
                        f"page_size")
                self._preempt(victim)
        active = [i for i in range(len(self.slots))
                  if self.slots[i] is not None]
        if not active:
            return False
        B = self.scfg.num_slots
        toks = np.zeros((B, 1), np.int32)
        posv = np.zeros((B,), np.int32)
        act = np.zeros((B,), bool)
        for i in active:
            req = self.slots[i]
            toks[i, 0] = req.out[-1]
            posv[i] = req.next_pos
            act[i] = True
        logits, self.pools = self._decode_step(
            jnp.asarray(toks), jnp.asarray(posv), jnp.asarray(act))
        logits = np.asarray(logits)
        # advance every slot before any finish check: the decode step
        # already wrote position next_pos for all of them, so a
        # mid-loop _verify_table must not see a stale next_pos
        sampled = []
        for i in active:
            req = self.slots[i]
            tok = self._sample_token(logits[i, 0], req.rid, req.next_pos)
            req.next_pos += 1
            req.out.append(tok)
            sampled.append((i, tok))
        for i, tok in sampled:
            self._finished(i, tok)
        self._verify_table()
        self.stats_history.append(self.pool_stats())
        return True

    def run(self, requests, max_new: int = 32) -> dict:
        """Serve ``requests`` (a list of 1-D prompt token arrays) to
        completion.  Returns {rid: generated np.int32 array}."""
        for rid, prompt in enumerate(requests):
            self.submit(rid, prompt, max_new)
        while self.pending or any(s is not None for s in self.slots):
            while self._admit_one():
                pass
            if not self.step() and self.pending:
                raise RuntimeError(
                    "no active slots and the head-of-line request "
                    "cannot be admitted; pool too small")
        return self.done


def paged_throughput_report(server: PagedServer, requests,
                            max_new: int = 16) -> dict:
    t0 = time.perf_counter()
    out = server.run(requests, max_new=max_new)
    dt = time.perf_counter() - t0
    tokens = int(sum(len(v) for v in out.values()))
    frag = [s["fragmentation"] for s in server.stats_history] or [0.0]
    util = [s["utilization"] for s in server.stats_history] or [0.0]
    return {"tokens": tokens, "seconds": dt, "tok_per_s": tokens / dt,
            "requests": len(out),
            "preemptions": sum(1 for e in server.events
                               if isinstance(e, dict)
                               and e.get("kind") == "preempt"),
            "mean_fragmentation": float(np.mean(frag)),
            "peak_utilization": float(np.max(util))}


def throughput_report(server: Server, batch: int, prompt_len: int,
                      max_new: int = 16):
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, server.cfg.vocab_size, (batch, prompt_len))
    t0 = time.perf_counter()
    out = server.generate(prompts, max_new=max_new)
    dt = time.perf_counter() - t0
    return {"tokens": int(out.size), "seconds": dt,
            "tok_per_s": out.size / dt}


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="quickstart")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop a slot early when it samples this token "
                         "(-1 = never)")
    ap.add_argument("--retries", type=int, default=3,
                    help="guarded-call retry budget per step")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-call deadline in seconds (recorded; "
                         "enforcement via ServeConfig)")
    ap.add_argument("--ckpt-dir", default="",
                    help="decode-state checkpoint directory (enables "
                         "preemption-safe draining + resume)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="serve under deterministic randomized fault "
                         "injection (repro.runtime.chaos) with this "
                         "seed -- the serving smoke CI runs")
    ap.add_argument("--grid-lowering", default="",
                    choices=("", "closed_form", "prefetch_lut", "bounding",
                             "mma", "compact"),
                    help="GridPlan lowering for the attention block "
                         "domain (default: the arch's attn_schedule)")
    ap.add_argument("--backend", default="",
                    choices=("", "tpu", "gpu", "tpu-interpret",
                             "gpu-interpret", "interpret"),
                    help="kernel emission target for every block-space "
                         "Pallas call (repro.core.backend; default: "
                         "platform / REPRO_BACKEND)")
    ap.add_argument("--decode-kernel", default="",
                    choices=("", "xla", "blockspace"),
                    help="decode attention path: 'blockspace' runs the "
                         "Pallas flash kernel with the run-time seq_pos "
                         "block skip, sharding continuous-batching slot "
                         "groups over the mesh (default: the arch's "
                         "setting, normally 'xla')")
    ap.add_argument("--mesh", default="",
                    help="serve on a device mesh: 'host' (all devices, "
                         "tp=1) or 'DATAxMODEL' (e.g. '4x2').  The same "
                         "mesh drives the sharding.py param/cache specs "
                         "and the block-space kernels' shard_axis "
                         "('data') -- one mesh for the whole process.")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV pool + continuous-"
                         "batching scheduler (PagedServer) instead of "
                         "the fixed-batch contiguous server; --batch "
                         "becomes the request count and prompts get "
                         "mixed lengths in [4, --prompt-len]")
    ap.add_argument("--num-slots", type=int, default=4,
                    help="paged: concurrently decoding slots (the "
                         "static batch shape of the decode step)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged: tokens per KV page (the autotuned "
                         "knob; see repro.core.tune.autotune_paged)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged: physical pages in the pool incl. the "
                         "reserved null page (0 = enough for num_slots "
                         "requests at max_len)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.mesh import resolve_cli_mesh
    cfg = get_config(args.arch, smoke=True)
    if args.grid_lowering:
        cfg = cfg.replace(grid_lowering=args.grid_lowering)
        print(f"grid lowering: {cfg.grid_mode} "
              f"(xla schedule: {cfg.attn_schedule_resolved})")
    if args.backend:
        from repro.core import backend as backend_lib
        backend_lib.set_default(args.backend)
        print(f"kernel backend: {backend_lib.resolve(None).name}")
    if args.decode_kernel:
        cfg = cfg.replace(attn_decode_kernel=args.decode_kernel)
        print(f"decode attention: {cfg.attn_decode_kernel}")
    mesh = resolve_cli_mesh(args.mesh)
    if cfg.attn_decode_kernel == "blockspace":
        from repro.models import attention as attn_lib
        attn_lib.set_decode_mesh(mesh)
    if mesh is not None:
        print(f"mesh: {dict(mesh.shape)} over {len(mesh.devices.flat)} "
              f"devices (kernels shard over axis 'data')")
        param_specs = shard_lib.param_spec_tree(
            model_lib.abstract_init(cfg), cfg)
        init_fn = jax.jit(
            partial(init, cfg=cfg),
            out_shardings=shard_lib.named_sharding_tree(param_specs,
                                                        mesh))
        with mesh:
            params = init_fn(jax.random.PRNGKey(0))
    else:
        params = init(jax.random.PRNGKey(0), cfg)
    chaos = None
    if args.chaos_seed is not None:
        from repro.runtime.chaos import ChaosInjector, FaultPlan
        plan = FaultPlan.from_seed(
            args.chaos_seed, sites=("serve.prefill", "serve.decode"),
            horizon=args.max_new)
        chaos = ChaosInjector(plan)
        print(f"chaos: {len(plan.faults)} faults scheduled "
              f"(seed {plan.seed})")
    if args.paged:
        from repro.core.paged import pages_for
        max_len = args.prompt_len + args.max_new
        num_pages = args.num_pages or (
            1 + args.num_slots * pages_for(max_len, args.page_size))
        server = PagedServer(cfg, params, PagedServeConfig(
            max_len=max_len, temperature=args.temperature,
            eos_id=args.eos_id, retries=args.retries,
            deadline_s=args.deadline,
            num_slots=args.num_slots, page_size=args.page_size,
            num_pages=num_pages), chaos=chaos)
        rng = np.random.default_rng(0)
        requests = [rng.integers(0, cfg.vocab_size,
                                 (int(rng.integers(4, args.prompt_len
                                                   + 1)),))
                    for _ in range(args.batch)]
        print(f"paged: {args.num_slots} slots, {num_pages} pages of "
              f"{args.page_size} tokens, {args.batch} mixed-length "
              f"requests")
        rep = paged_throughput_report(server, requests,
                                      max_new=args.max_new)
        if chaos is not None:
            print(f"chaos: {len(chaos.events)} faults fired, "
                  f"state {server.state.value}")
        print(rep)
        return
    server = Server(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.max_new,
        temperature=args.temperature, eos_id=args.eos_id,
        retries=args.retries, deadline_s=args.deadline,
        ckpt_dir=args.ckpt_dir or None,
        ckpt_every=4 if args.ckpt_dir else 0), mesh=mesh, chaos=chaos)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len))
    out = server.generate(prompts, max_new=args.max_new)
    print("generated shape:", out.shape)
    if chaos is not None:
        recov = sum(getattr(g, "recoveries", 0)
                    for g in (server._prefill, server._decode))
        print(f"chaos: {len(chaos.events)} faults fired, "
              f"{recov} recoveries, state {server.state.value}")
        if not np.isfinite(np.asarray(out, np.float64)).all():
            raise SystemExit("chaos smoke: corrupted output escaped")
    print(throughput_report(server, args.batch, args.prompt_len,
                            args.max_new))


if __name__ == "__main__":
    main()
