"""Distributed trainer: pjit train step with DP/TP/EP/SP sharding,
microbatched gradient accumulation, remat, checkpoint/restart, straggler
watchdog, and preemption-safe exit.

Runnable directly:
    PYTHONPATH=src python -m repro.launch.train --arch quickstart --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import sharding as shard_lib
from repro.distributed.fault_tolerance import (Heartbeat, PreemptionGuard,
                                               retry_step)
from repro.models import ModelConfig, init, loss_fn
from repro.models import model as model_lib
from repro.optim.adamw import (AdamWConfig, apply_updates, init_state)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    grad_accum: int = 1
    log_every: int = 10
    ckpt_every: int = 0            # 0 = only at exit
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    seed: int = 0
    fsdp: bool = False
    seq_shard_acts: bool = False
    straggler_deadline_s: float = 600.0
    step_retries: int = 3          # transient-classified retries per step
    retry_backoff_s: float = 0.5   # jittered-exponential backoff base
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics).
    batch arrays have a leading grad_accum axis when accum > 1."""

    def single(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)

    def step(params, opt_state, batch):
        if tcfg.grad_accum == 1:
            (loss, metrics), grads = single(params, batch)
        else:
            def micro(carry, mb):
                acc_g, acc_l = carry
                (l, _), g = single(params, mb)
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), batch)
            inv = 1.0 / tcfg.grad_accum
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            metrics = {"loss": loss, "aux_loss": jnp.zeros(()),
                       "tokens": jnp.asarray(0., jnp.float32)}
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, tcfg.optimizer)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return step


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)

        abs_params = model_lib.abstract_init(cfg)
        self.param_specs = shard_lib.param_spec_tree(
            abs_params, cfg, fsdp=tcfg.fsdp)
        if mesh is not None:
            self.param_shardings = shard_lib.named_sharding_tree(
                self.param_specs, mesh)
            self.batch_shardings = shard_lib.batch_specs(
                mesh, cfg.input_mode)
            self.act = shard_lib.act_specs(
                mesh, seq_shard=tcfg.seq_shard_acts)
        else:
            self.param_shardings = None
            self.batch_shardings = None
            self.act = None

        step = make_train_step(cfg, tcfg)
        if mesh is not None:
            opt_shard = {"m": self.param_shardings,
                         "v": self.param_shardings,
                         "count": NamedSharding(mesh, P())}
            bshard = dict(self.batch_shardings)
            if tcfg.grad_accum > 1:
                bshard = {k: NamedSharding(
                    mesh, P(None, *v.spec)) for k, v in bshard.items()}
            ns = NamedSharding(mesh, P())
            self._step = jax.jit(
                step,
                in_shardings=(self.param_shardings, opt_shard, bshard),
                out_shardings=(self.param_shardings, opt_shard,
                               {"loss": ns, "aux_loss": ns, "tokens": ns,
                                "grad_norm": ns, "lr": ns}),
                donate_argnums=(0, 1))
        else:
            self._step = jax.jit(step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init_params(self):
        if self.mesh is not None:
            init_fn = jax.jit(partial(init, cfg=self.cfg),
                              out_shardings=self.param_shardings)
            with self.mesh:
                params = init_fn(jax.random.PRNGKey(self.tcfg.seed))
        else:
            params = init(jax.random.PRNGKey(self.tcfg.seed), self.cfg)
        opt_state = init_state(params, self.tcfg.optimizer)
        return params, opt_state

    def restore_or_init(self, pipeline=None):
        """Elastic restore: the checkpoint re-lays-out onto this mesh."""
        try:
            abs_params = model_lib.abstract_init(self.cfg)
            step, params, opt_state, meta = self.ckpt.restore(
                None, abs_params, None, shardings=self.param_shardings)
            if opt_state is None:
                opt_state = init_state(params, self.tcfg.optimizer)
            if pipeline is not None and meta.get("data_state"):
                pipeline.load_state_dict(meta["data_state"])
            return step, params, opt_state
        except FileNotFoundError:
            params, opt_state = self.init_params()
            return 0, params, opt_state

    def _device_batch(self, batch: Dict[str, np.ndarray]):
        if self.tcfg.grad_accum > 1:
            def reshape(x):
                a = self.tcfg.grad_accum
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])
            batch = {k: reshape(v) for k, v in batch.items()}
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, batch)
        sh = self.batch_shardings
        if self.tcfg.grad_accum > 1:
            sh = {k: NamedSharding(self.mesh, P(None, *v.spec))
                  for k, v in sh.items()}
        return {k: jax.device_put(v, sh[k]) for k, v in batch.items()}

    def _write_failure(self, step: int, exc: BaseException) -> str:
        """Publish a machine-readable failure report next to the
        checkpoints before the train loop dies."""
        from repro.runtime.guard import FailureReport, classify_error
        report = FailureReport(
            name="train.step", error=str(exc),
            error_type=type(exc).__name__,
            classification=classify_error(exc),
            attempts=1 + self.tcfg.step_retries, time=time.time())
        path = os.path.join(self.tcfg.ckpt_dir,
                            f"failure_step_{step:010d}.json")
        try:
            return report.write(path)
        except OSError:
            return ""

    def run(self, pipeline: SyntheticPipeline, steps: Optional[int] = None):
        steps = steps or self.tcfg.steps
        start, params, opt_state = self.restore_or_init(pipeline)
        hb = Heartbeat(self.tcfg.straggler_deadline_s,
                       on_straggle=lambda dt: print(
                           f"[straggler] step exceeded deadline: {dt:.1f}s"))
        history = []
        ctx = self.mesh if self.mesh is not None else _nullcontext()
        act_ctx = (shard_lib.activation_specs(self.act)
                   if self.act else _nullcontext())
        with PreemptionGuard() as guard, ctx, act_ctx:
            step = start - 1  # a restored ckpt at/past `steps` skips the loop
            for step in range(start, steps):
                batch = self._device_batch(pipeline.next_batch())
                t0 = time.perf_counter()
                try:
                    params, opt_state, metrics = retry_step(
                        self._step, params, opt_state, batch,
                        retries=self.tcfg.step_retries,
                        backoff_s=self.tcfg.retry_backoff_s,
                        seed=self.tcfg.seed,
                        on_retry=lambda a, e: print(
                            f"[retry] step {step} attempt {a}: {e}"))
                except Exception as e:
                    self._write_failure(step, e)
                    raise
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step_time_s"] = time.perf_counter() - t0
                hb.beat()
                history.append(metrics)
                if step % self.tcfg.log_every == 0:
                    print(f"step {step}: loss={metrics['loss']:.4f} "
                          f"gnorm={metrics['grad_norm']:.3f} "
                          f"lr={metrics['lr']:.2e} "
                          f"t={metrics['step_time_s']:.3f}s")
                if (self.tcfg.ckpt_every
                        and step and step % self.tcfg.ckpt_every == 0):
                    self.ckpt.save(step, params, opt_state,
                                   pipeline.state_dict())
                if guard.fired:
                    print("[preemption] SIGTERM received; checkpointing")
                    break
            final_step = step + 1 if not guard.fired else step
            self.ckpt.save(final_step, params, opt_state,
                           pipeline.state_dict())
        return params, opt_state, history


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="quickstart")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--grid-lowering", default="",
                    choices=("", "closed_form", "prefetch_lut", "bounding",
                             "mma", "compact"),
                    help="GridPlan lowering for the attention block "
                         "domain (default: the arch's attn_schedule)")
    ap.add_argument("--mesh", default="",
                    help="train on a device mesh: 'host' (all devices, "
                         "tp=1) or 'DATAxMODEL' (e.g. '4x2').  Shared "
                         "by the sharding.py rules and the block-space "
                         "kernels (shard_axis 'data').")
    ap.add_argument("--backend", default="",
                    choices=("", "tpu", "gpu", "tpu-interpret",
                             "gpu-interpret", "interpret"),
                    help="kernel emission target for every block-space "
                         "Pallas call (repro.core.backend; default: "
                         "platform / REPRO_BACKEND)")
    args = ap.parse_args()

    from repro.configs import get_config
    cfg = get_config(args.arch, smoke=True if args.smoke else None)
    if args.grid_lowering:
        cfg = cfg.replace(grid_lowering=args.grid_lowering)
        print(f"grid lowering: {cfg.grid_mode} "
              f"(xla schedule: {cfg.attn_schedule_resolved})")
    if args.backend:
        from repro.core import backend as backend_lib
        backend_lib.set_default(args.backend)
        print(f"kernel backend: {backend_lib.resolve(None).name}")

    tcfg = TrainConfig(
        steps=args.steps, grad_accum=args.grad_accum,
        ckpt_dir=args.ckpt_dir,
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(1, args.steps // 10)))
    pipe = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, input_mode=cfg.input_mode,
        d_model=cfg.d_model))
    from repro.launch.mesh import resolve_cli_mesh
    mesh = resolve_cli_mesh(args.mesh)
    if mesh is not None:
        print(f"mesh: {dict(mesh.shape)} over {len(mesh.devices.flat)} "
              f"devices (kernels shard over axis 'data')")
    trainer = Trainer(cfg, tcfg, mesh=mesh)
    trainer.run(pipe)


if __name__ == "__main__":
    main()
