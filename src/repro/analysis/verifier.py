"""Static plan verification: machine-checked proofs of the block-space
invariants, per emitted plan.

Given any :class:`~repro.core.plan.GridPlan` (or
:class:`~repro.core.shard.ShardedPlan`), every decode the kernels run --
``_decode``, ``storage_index``, ``neighbor_index``, ``_step_valid`` --
is also evaluable on host numpy arrays, so the verifier enumerates the
*entire* launch grid per device and checks, exhaustively:

``coverage``
    Every block of the scheduled domain is decoded by exactly one live
    grid step per launch (union over devices for sharded plans),
    against a ground-truth enumeration built only from
    ``domain.contains`` over the bounding box -- never from the decode
    path under test.

``race``
    The storage tile write-set is pairwise disjoint across live steps
    of one launch (per device): the gpu structure stores at computed
    offsets from unordered program ids, so a storage-index collision is
    a data race, not just a perf bug.

``table``
    Host-built decode tables -- the 28-column LUT, packed-slot and
    neighbour-slot tables, shard tables, ghost maps, HaloPlan rounds,
    phase tables -- are re-derived from ``linear_index`` /
    ``lambda_inverse`` / membership and diffed entry-by-entry.  The
    neighbour check is semantic: a neighbour slot must *invert* (via
    the slot -> coords table) to exactly the embedded neighbour.

``bounds``
    ``storage_index`` / ``neighbor_index`` are evaluated over **all**
    grid steps (dead and pad steps still drive index maps and
    ``pl.load``) and the exact [min, max] hull per axis is checked
    against the operand tile grid.

``alias``
    ``input_output_aliases`` write-in-place: for each aliased input,
    its modelled read tiles at live step ``s`` must never intersect the
    output write tile of a different live step ``t`` (the CA
    double-buffer invariant -- the stale buffer is aliased but never
    read -- is what makes the 9-point stencil safe; aliasing the state
    instead is flagged).

``verify_plan`` runs everything applicable and returns a
:class:`Report`; ``verify_or_raise`` raises
:class:`PlanVerificationError` (a ``ValueError``, so autotune treats a
failing candidate as inviable) on any finding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compact import NEIGHBOR_OFFSETS8
from repro.core.plan import (_LUT_BX, _LUT_BY, _LUT_NBR, _LUT_SX,
                             _LUT_SY, GridPlan)
from repro.core.shard import SHARD_COUNT, SHARD_GMAP, SHARD_LO, ShardedPlan


class PlanVerificationError(ValueError):
    """A plan failed static verification.  Subclasses ``ValueError`` so
    :func:`repro.core.tune.autotune` rejects the candidate as inviable
    instead of measuring it."""


@dataclasses.dataclass
class Finding:
    """One verified invariant violation."""

    check: str                      # coverage|race|table|bounds|alias
    detail: str
    device: Optional[int] = None

    def __str__(self) -> str:
        where = f" [device {self.device}]" if self.device is not None \
            else ""
        return f"{self.check}{where}: {self.detail}"

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    """Result of one :func:`verify_plan` run."""

    plan: Dict[str, Any]
    checks: Tuple[str, ...]
    findings: List[Finding]

    @property
    def ok(self) -> bool:
        return not self.findings

    def raise_on_findings(self) -> "Report":
        if self.findings:
            lines = "\n  ".join(str(f) for f in self.findings)
            raise PlanVerificationError(
                f"plan verification failed for {self.plan}:\n  {lines}")
        return self

    def to_json(self) -> Dict[str, Any]:
        return {"plan": self.plan, "checks": list(self.checks),
                "ok": self.ok,
                "findings": [f.to_json() for f in self.findings]}


#: per-kernel access models: whether the storage write-set must be
#: race-free (reductions to per-step partials are exempt), whether the
#: kernel reads the 8 halo operands, and the read model of each aliased
#: input ("none" = never read, e.g. the CA stale buffer; "center" =
#: read at the step's own storage tile; "center+neighbors" = the
#: stencil gather).
ACCESS_MODELS: Dict[str, Dict[str, Any]] = {
    "generic": {"race": True, "neighbors": False, "storage": True,
                "alias_reads": ()},
    "write": {"race": True, "neighbors": False, "storage": True,
              "alias_reads": ("center",)},
    "sum": {"race": False, "neighbors": False, "storage": True,
            "alias_reads": ()},
    "ca": {"race": True, "neighbors": True, "storage": True,
           "alias_reads": ("none",)},
    "flash": {"race": False, "neighbors": False, "storage": False,
              "alias_reads": (), "hulls": True},
}


class HostMesh:
    """Geometry-only stand-in for ``jax.sharding.Mesh``: enough to
    build a :class:`ShardedPlan` for host-side verification (its tables
    and decodes never touch a device; only live ``ppermute`` traffic
    would need real devices)."""

    def __init__(self, num_shards: int, axis: str = "data"):
        self.shape = {axis: int(num_shards)}


# ---------------------------------------------------------------------------
# host evaluation helpers
# ---------------------------------------------------------------------------

def _np(x) -> np.ndarray:
    return np.asarray(x)


def _is_sharded(plan: GridPlan) -> bool:
    return isinstance(plan, ShardedPlan)


def _phase(plan: GridPlan):
    return getattr(plan, "phase", None)


def plan_signature(plan: GridPlan) -> Dict[str, Any]:
    sig: Dict[str, Any] = {
        "domain": plan.domain.name,
        "lowering": plan.lowering,
        "storage": plan.storage,
        "coarsen": plan.coarsen,
        "backend": plan.target.name,
    }
    if _is_sharded(plan):
        sig["shards"] = plan.num_shards
        sig["partition"] = plan.partition
        if plan.phase is not None:
            sig["phase"] = plan.phase
    return sig


def num_devices(plan: GridPlan) -> int:
    return plan.num_shards if _is_sharded(plan) else 1


def host_prefetch_refs(plan: GridPlan, device: int = 0) -> Tuple:
    """The decode-table operands device ``device``'s launch receives,
    as host numpy arrays (exactly what ``shard_map`` would slice)."""
    if not _is_sharded(plan):
        if plan.lowering == "prefetch_lut":
            return (np.asarray(plan.lut_host()),)
        if plan._table_backed:  # mma on a block-indexed structure
            return (np.asarray(plan.mma_table_host()),)
        return ()
    refs: Tuple = (np.asarray(plan.shard_table_host()[device]),)
    if plan._table_backed:
        # per-device LUT chunk size is the *base* plan's steps_per_shard
        # (phase views indirect into the same chunk)
        if plan.partition == "storage-rows":
            per = plan.rpd * plan.ncols
        else:
            per = plan.steps_per_shard
        lut = plan.lut_sharded_host()
        if lut is None:
            lut = plan.mma_table_sharded_host()
        refs += (np.asarray(lut[device * per:(device + 1) * per]),)
    if plan.phase is not None:
        it, bt = plan.phase_tables_host()
        tab = it if plan.phase == "interior" else bt
        refs += (np.asarray(tab[device]),)
    return refs


def host_steps(plan: GridPlan) -> Tuple[np.ndarray, ...]:
    """Every grid-step id tuple of one launch as parallel numpy arrays
    (batch dims pinned to 0: the domain decode is batch-invariant)."""
    nb = len(plan.batch_dims)
    grid = plan.grid
    if plan.lowering == "bounding":
        nby, nbx = int(grid[nb]), int(grid[nb + 1])
        gy, gx = np.mgrid[0:nby, 0:nbx]
        dom: Tuple[np.ndarray, ...] = (gy.ravel().astype(np.int64),
                                       gx.ravel().astype(np.int64))
    else:
        dom = (np.arange(int(grid[nb]), dtype=np.int64),)
    zero = np.zeros_like(dom[0])
    return tuple(zero for _ in range(nb)) + dom


def decode_steps(plan: GridPlan, refs: Tuple,
                 ids: Optional[Tuple[np.ndarray, ...]] = None):
    """(ids, bx, by, live): the full host decode of one launch."""
    if ids is None:
        ids = host_steps(plan)
    _, bx, by = plan._decode(ids, refs)
    bx = _np(bx).astype(np.int64)
    by = _np(by).astype(np.int64)
    bx, by = np.broadcast_arrays(bx, by)
    if bx.shape != ids[-1].shape:
        bx = np.broadcast_to(bx, ids[-1].shape)
        by = np.broadcast_to(by, ids[-1].shape)
    valid = plan._step_valid(ids, bx, by, refs)
    if valid is None:
        live = np.ones(ids[-1].shape, bool)
    else:
        live = np.broadcast_to(_np(valid).astype(bool), ids[-1].shape)
    return ids, bx, by, live


def storage_tiles(plan: GridPlan, refs: Tuple,
                  ids: Tuple[np.ndarray, ...]):
    """(row, col) storage tile index per grid step, host-evaluated."""
    r, c = plan.storage_index(ids, refs)
    r = np.broadcast_to(_np(r).astype(np.int64), ids[-1].shape)
    c = np.broadcast_to(_np(c).astype(np.int64), ids[-1].shape)
    return r, c


def neighbor_tiles(plan: GridPlan, refs: Tuple,
                   ids: Tuple[np.ndarray, ...], j: int):
    r, c = plan.neighbor_index(j, ids, refs)
    r = np.broadcast_to(_np(r).astype(np.int64), ids[-1].shape)
    c = np.broadcast_to(_np(c).astype(np.int64), ids[-1].shape)
    return r, c


def members_host(domain) -> Tuple[np.ndarray, np.ndarray]:
    """Ground-truth member blocks from membership alone (independent of
    the enumeration/decode under test)."""
    nbx, nby = domain.bounding_box
    gy, gx = np.mgrid[0:nby, 0:nbx]
    gx = gx.astype(np.int64)
    gy = gy.astype(np.int64)
    if getattr(domain, "always_member", False):
        return gx.ravel(), gy.ravel()
    m = np.broadcast_to(_np(domain.contains(gx, gy)),
                        gx.shape).astype(bool)
    return gx[m], gy[m]


def storage_grid(plan: GridPlan) -> Tuple[int, int]:
    """(rows, cols) of the tile grid the *center* storage index
    addresses (the local slab for sharded compact plans)."""
    if _is_sharded(plan) and plan.storage == "compact":
        return plan.rpd, plan.ncols
    if plan.storage == "compact":
        scols, srows = plan.layout.grid_shape
        if plan._tiling is not None:
            bw, bh = plan._tiling.sub_shape
            return srows // bh, scols // bw
        return srows, scols
    nbx, nby = plan.sched_domain.bounding_box
    return nby, nbx


def neighbor_grid(plan: GridPlan) -> Tuple[int, int]:
    """(rows, cols) tile-grid bound for the halo operand indices: the
    halo-extended slab (ghost rows + dump) under sharded compact."""
    if _is_sharded(plan) and plan.storage == "compact":
        h_max = plan.halo.h_max if plan.halo is not None else 0
        return plan.rpd + h_max + 1, plan.ncols
    return storage_grid(plan)


# ---------------------------------------------------------------------------
# the checks
# ---------------------------------------------------------------------------

def _check_coverage(plan, per_device, findings):
    gx, gy = members_host(plan.sched_domain)
    truth = set(zip(gx.tolist(), gy.tolist()))
    seen: Dict[Tuple[int, int], int] = {}
    for d, (ids, bx, by, live) in enumerate(per_device):
        pts = list(zip(bx[live].tolist(), by[live].tolist()))
        local = set()
        for p in pts:
            if p in local:
                findings.append(Finding(
                    "coverage", f"block {p} decoded by two live steps "
                    f"of one launch", device=d))
            local.add(p)
            seen[p] = seen.get(p, 0) + 1
    extra = [p for p in seen if p not in truth]
    missing = [p for p in truth if p not in seen]
    double = [p for p, k in seen.items() if k > 1]
    for p in extra[:3]:
        findings.append(Finding(
            "coverage", f"live step decodes non-member block {p}"))
    for p in missing[:3]:
        findings.append(Finding(
            "coverage", f"member block {p} is never covered"))
    for p in double[:3]:
        findings.append(Finding(
            "coverage", f"member block {p} covered {seen[p]} times "
            f"across the mesh"))
    if len(extra) > 3 or len(missing) > 3 or len(double) > 3:
        findings.append(Finding(
            "coverage", f"... {len(extra)} extra / {len(missing)} "
            f"missing / {len(double)} multiply-covered blocks total"))


def _check_race(plan, refs_per_device, per_device, findings):
    for d, (ids, bx, by, live) in enumerate(per_device):
        r, c = storage_tiles(plan, refs_per_device[d], ids)
        keys = (r[live] * (c.max() + 2) + c[live]) if live.any() \
            else np.empty(0, np.int64)
        uniq, counts = np.unique(keys, return_counts=True)
        dup = uniq[counts > 1]
        for k in dup[:3]:
            rr, cc = int(k // (c.max() + 2)), int(k % (c.max() + 2))
            findings.append(Finding(
                "race", f"storage tile ({rr}, {cc}) written by "
                f"multiple live steps of one launch", device=d))
        if len(dup) > 3:
            findings.append(Finding(
                "race", f"... {len(dup)} colliding storage tiles "
                f"total", device=d))


def _check_bounds(plan, refs_per_device, per_device, model, findings):
    nr, nc = storage_grid(plan)
    hr, hc = neighbor_grid(plan)
    for d, (ids, bx, by, live) in enumerate(per_device):
        refs = refs_per_device[d]
        r, c = storage_tiles(plan, refs, ids)
        if r.min() < 0 or r.max() >= nr or c.min() < 0 or c.max() >= nc:
            findings.append(Finding(
                "bounds", f"storage index hull "
                f"rows [{r.min()}, {r.max()}] x cols "
                f"[{c.min()}, {c.max()}] exceeds the ({nr}, {nc}) "
                f"tile grid (some pl.load/pl.store may go OOB)",
                device=d))
        if not model["neighbors"]:
            continue
        for j in range(len(NEIGHBOR_OFFSETS8)):
            r, c = neighbor_tiles(plan, refs, ids, j)
            if r.min() < 0 or r.max() >= hr or c.min() < 0 \
                    or c.max() >= hc:
                findings.append(Finding(
                    "bounds", f"neighbor {j} index hull rows "
                    f"[{r.min()}, {r.max()}] x cols "
                    f"[{c.min()}, {c.max()}] exceeds the ({hr}, {hc}) "
                    f"halo tile grid", device=d))


def _check_alias(plan, refs_per_device, per_device, model, findings):
    for read_model in model["alias_reads"]:
        if read_model == "none":
            continue
        for d, (ids, bx, by, live) in enumerate(per_device):
            refs = refs_per_device[d]
            r, c = storage_tiles(plan, refs, ids)
            writes = {}
            for s in np.nonzero(live)[0]:
                writes[(int(r[s]), int(c[s]))] = int(s)
            # "center" reads: step s reads its own write tile -- a
            # cross-step hazard is exactly a write-set collision and is
            # reported by the race check; nothing more to do here.
            if read_model != "center+neighbors":
                continue
            hit = None
            for j in range(len(NEIGHBOR_OFFSETS8)):
                nr_, nc_ = neighbor_tiles(plan, refs, ids, j)
                for s in np.nonzero(live)[0]:
                    t = writes.get((int(nr_[s]), int(nc_[s])))
                    if t is not None and t != int(s):
                        hit = (int(s), j, t)
                        break
                if hit:
                    break
            if hit:
                s, j, t = hit
                findings.append(Finding(
                    "alias", f"aliased input is read at neighbor {j} "
                    f"of step {s}, which is the write tile of step "
                    f"{t}: in-place aliasing makes this a "
                    f"read-after-write hazard within the launch",
                    device=d))


def _check_tables(plan, findings):
    dom = plan.sched_domain
    gx, gy = members_host(dom)
    n = dom.num_blocks
    if len(gx) != n:
        findings.append(Finding(
            "table", f"membership enumerates {len(gx)} blocks but "
            f"num_blocks = {n}"))
        return
    li = _np(dom.linear_index(gx, gy)).astype(np.int64)
    li = np.broadcast_to(li, gx.shape)
    if li.min() < 0 or li.max() >= n or len(np.unique(li)) != n:
        findings.append(Finding(
            "table", "linear_index over the member set is not a "
            "permutation of [0, num_blocks)"))
        return
    # expected coords table, placed via the *inverse* map
    exp = np.zeros((n, 2), np.int64)
    exp[li, 0] = gx
    exp[li, 1] = gy
    # for mma plans, verify the digit-basis matmul table -- the exact
    # decode both structures consume (the gpu structure evaluates the
    # same chains in-kernel, so the table *is* the chain output)
    lut = np.asarray(plan.mma_table_host() if plan.lowering == "mma"
                     else plan.lut_host())
    bad = np.nonzero((lut[:, _LUT_BX] != exp[:, 0])
                     | (lut[:, _LUT_BY] != exp[:, 1]))[0]
    for i in bad[:3]:
        findings.append(Finding(
            "table", f"LUT row {i} decodes to "
            f"({lut[i, _LUT_BX]}, {lut[i, _LUT_BY]}); linear_index "
            f"places ({exp[i, 0]}, {exp[i, 1]}) there"))
    if len(bad) > 3:
        findings.append(Finding(
            "table", f"... {len(bad)} corrupted LUT coordinate rows"))
    if plan.storage != "compact":
        return
    _check_compact_tables(plan, lut, exp, findings)


def _check_compact_tables(plan, lut, exp, findings):
    dom = plan.sched_domain
    n = dom.num_blocks
    if plan._tiling is not None:
        sx, sy = plan._tiling.tile_index(exp[:, 0], exp[:, 1])
    else:
        sx, sy = plan.layout.slot(exp[:, 0], exp[:, 1])
    sx = _np(sx).astype(np.int64)
    sy = _np(sy).astype(np.int64)
    bad = np.nonzero((lut[:, _LUT_SX] != sx)
                     | (lut[:, _LUT_SY] != sy))[0]
    for i in bad[:3]:
        findings.append(Finding(
            "table", f"LUT row {i}: packed slot "
            f"({lut[i, _LUT_SX]}, {lut[i, _LUT_SY]}) != lambda^-1 "
            f"slot ({sx[i]}, {sy[i]})"))
    if len(bad) > 3:
        findings.append(Finding(
            "table", f"... {len(bad)} corrupted slot rows"))
    nr, nc = storage_grid(plan) if not _is_sharded(plan) else \
        (lambda g: (g[1], g[0]))(plan._storage_grid())
    if len(np.unique(sy * nc + sx)) != n or sx.min() < 0 \
            or sx.max() >= nc or sy.min() < 0 or sy.max() >= nr:
        findings.append(Finding(
            "table", "lambda^-1 slots are not an injection into the "
            "storage grid"))
        return
    # semantic neighbour check: every valid neighbour slot must invert
    # (via the slot -> coords table) to exactly the embedded neighbour
    slot2coord = np.full((nr, nc, 2), -1, np.int64)
    slot2coord[sy, sx, 0] = exp[:, 0]
    slot2coord[sy, sx, 1] = exp[:, 1]
    nbx, nby = dom.bounding_box
    nbrs = lut[:, _LUT_NBR:].reshape(n, 8, 3).astype(np.int64)
    for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS8):
        ex = exp[:, 0] + dx
        ey = exp[:, 1] + dy
        inb = (ex >= 0) & (ex < nbx) & (ey >= 0) & (ey < nby)
        mem = np.zeros(n, bool)
        if inb.any():
            mem[inb] = np.broadcast_to(
                _np(dom.contains(ex[inb], ey[inb])),
                ex[inb].shape).astype(bool)
        ok = nbrs[:, j, 2] == 1
        bad = np.nonzero(ok != mem)[0]
        for i in bad[:2]:
            findings.append(Finding(
                "table", f"neighbor table row {i} offset {j}: "
                f"valid={bool(ok[i])} but membership says "
                f"{bool(mem[i])}"))
        if len(bad) > 2:
            findings.append(Finding(
                "table", f"... {len(bad)} wrong neighbour-validity "
                f"entries at offset {j}"))
        nsx, nsy = nbrs[:, j, 0], nbrs[:, j, 1]
        if nsx.min() < 0 or nsx.max() >= nc or nsy.min() < 0 \
                or nsy.max() >= nr:
            findings.append(Finding(
                "table", f"neighbour slots at offset {j} leave the "
                f"storage grid (clamped reads would alias wrong "
                f"tiles)"))
            continue
        sel = np.nonzero(ok & mem)[0]
        got = slot2coord[nsy[sel], nsx[sel]]
        bad = sel[np.nonzero((got[:, 0] != ex[sel])
                             | (got[:, 1] != ey[sel]))[0]]
        for i in bad[:2]:
            findings.append(Finding(
                "table", f"neighbor slot of row {i} offset {j} "
                f"resolves to block "
                f"{tuple(slot2coord[nbrs[i, j, 1], nbrs[i, j, 0]])}, "
                f"expected ({exp[i, 0] + dx}, {exp[i, 1] + dy})"))
        if len(bad) > 2:
            findings.append(Finding(
                "table", f"... {len(bad)} mis-resolved neighbour "
                f"slots at offset {j}"))


# -- sharded table checks ----------------------------------------------------

def _rederived_partition(plan):
    """Independent (lo, count) per device from the partition rule."""
    D = plan.num_shards
    N = plan.sched_domain.num_blocks
    if plan.partition == "storage-rows":
        lo = np.minimum(np.arange(D) * plan.rpd * plan.ncols, N)
        return lo, np.minimum(N - lo, plan.rpd * plan.ncols).clip(min=0)
    if plan.partition == "rows":
        nby = plan.sched_domain.bounding_box[1]
        by = np.sort(members_host(plan.sched_domain)[1])
        row_lo = np.minimum(np.arange(D + 1) * plan.rbd, nby)
        lo = np.searchsorted(by, row_lo, side="left")
        return lo[:-1], np.diff(lo)
    per = -(-N // D)
    lo = np.minimum(np.arange(D) * per, N)
    return lo, np.minimum(N - lo, per).clip(min=0)


def _rederive_halo(plan):
    """(ghost classes, interior steps, boundary steps, column spans)
    per device, re-derived from the (already verified) neighbour
    tables.  Spans map (ghost row, class) -> the half-open slot-column
    span of that row's readers."""
    if plan._tiling is not None:
        own = plan._tiling.tiles_host()
        nbrs = plan._tiling.neighbor_tiles_host()
    else:
        own = plan.layout.slots_host()
        nbrs = plan.layout.neighbor_slots_host()
    D, rpd = plan.num_shards, plan.rpd
    strips = plan.tile_map() is None
    ghosts, ints, bnds, spans = [], [], [], []
    for d in range(D):
        lo, hi = d * rpd, min((d + 1) * rpd, plan.nrows)
        sel = (own[:, 1] >= lo) & (own[:, 1] < hi)
        nb, mine = nbrs[sel], own[sel]
        cls: Dict[int, set] = {}
        span: Dict[tuple, tuple] = {}
        for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS8):
            rem = (nb[:, j, 2] == 1) \
                & ((nb[:, j, 1] < lo) | (nb[:, j, 1] >= hi))
            gr, gc = nb[:, j, 1][rem], nb[:, j, 0][rem]
            c = "top" if strips and dy == 1 else \
                "bot" if strips and dy == -1 else "full"
            for g in np.unique(gr):
                cols = gc[gr == g]
                cls.setdefault(int(g), set()).add(c)
                key = (int(g), c)
                clo, chi = int(cols.min()), int(cols.max()) + 1
                if key in span:
                    plo, phi = span[key]
                    span[key] = (min(plo, clo), max(phi, chi))
                else:
                    span[key] = (clo, chi)
        for g, s in cls.items():
            if "full" in s:
                merged = [span.pop((g, c)) for c in s if (g, c) in span]
                cls[g] = {"full"}
                span[(g, "full")] = (min(x for x, _ in merged),
                                     max(y for _, y in merged))
        ghosts.append(cls)
        spans.append(span)
        remote = (nb[..., 2] == 1) \
            & ((nb[..., 1] < lo) | (nb[..., 1] >= hi))
        t_ids = (mine[:, 1] - lo) * plan.ncols + mine[:, 0]
        bnd = remote.any(axis=1)
        ints.append(sorted(int(t) for t in t_ids[~bnd]))
        bnds.append(sorted(int(t) for t in t_ids[bnd]))
    return ghosts, ints, bnds, spans


def _check_shard_tables(plan, findings):
    D = plan.num_shards
    tbl = np.asarray(plan.shard_table_host())
    lo, count = _rederived_partition(plan)
    exp_lo = np.arange(D) * plan.rpd \
        if plan.partition == "storage-rows" else lo
    if not np.array_equal(tbl[:, SHARD_LO], exp_lo):
        findings.append(Finding(
            "table", f"shard table lo column {tbl[:, SHARD_LO]} != "
            f"re-derived {exp_lo}"))
    if not np.array_equal(tbl[:, SHARD_COUNT], count):
        findings.append(Finding(
            "table", f"shard table count column "
            f"{tbl[:, SHARD_COUNT]} != re-derived {count}"))
    if plan.partition != "storage-rows":
        return
    ghosts, ints, bnds, spans = _rederive_halo(plan)
    halo = plan.halo
    rpd = plan.rpd
    with_halo = halo is not None and halo.int_steps is not None
    if not with_halo and any(g for g in ghosts):
        # write/sum plans skip the halo: nothing more to check
        ghosts = [dict() for _ in range(D)]
    h_max = max((len(g) for g in ghosts), default=0)
    dump = rpd + h_max
    for d in range(D):
        gmap = tbl[d, SHARD_GMAP:]
        exp = np.full(plan.nrows_pad, dump, np.int64)
        for i in range(rpd):
            if d * rpd + i < plan.nrows_pad:
                exp[d * rpd + i] = i
        for p, g in enumerate(sorted(ghosts[d])):
            exp[g] = rpd + p
        if not np.array_equal(gmap, exp):
            bad = np.nonzero(gmap != exp)[0]
            findings.append(Finding(
                "table", f"ghost map rows {bad[:5].tolist()} disagree "
                f"with the re-derived map (got "
                f"{gmap[bad[:5]].tolist()}, expected "
                f"{exp[bad[:5]].tolist()})", device=d))
    if with_halo:
        _check_halo_rounds(plan, ghosts, spans, findings)
        _check_phase_tables(plan, ints, bnds, findings)
    if plan._table_backed:
        _check_sharded_lut(plan, findings)


def _check_halo_rounds(plan, ghosts, spans, findings):
    """Simulate the ppermute rounds and check every ghost row's strip
    requirement is delivered to its slot exactly, with a column window
    that covers its readers' span."""
    halo, D, rpd = plan.halo, plan.num_shards, plan.rpd
    order = [sorted(g) for g in ghosts]
    delivered: List[Dict[int, set]] = [dict() for _ in range(D)]
    for delta, cls, send, recv, scol, rcol, wc in halo.rounds:
        m = send.shape[1]
        for d in range(D):
            src = (d - delta) % D
            for i in range(m):
                slot = int(recv[d, i])
                if slot == halo.h_max:
                    continue  # padding -> dump row
                g = int(send[src, i]) + src * rpd
                if slot >= len(order[d]) or order[d][slot] != g:
                    findings.append(Finding(
                        "table", f"halo round (delta={delta}, "
                        f"{cls}): ghost slot {slot} receives global "
                        f"row {g}, expected "
                        f"{order[d][slot] if slot < len(order[d]) else 'dump'}",
                        device=d))
                    continue
                c0 = int(rcol[d, i])
                if int(scol[src, i]) != c0:
                    findings.append(Finding(
                        "table", f"halo round (delta={delta}, {cls}):"
                        f" ghost row {g} gathered at source column "
                        f"{int(scol[src, i])} but scattered at "
                        f"{c0}", device=d))
                lo_, hi_ = spans[d].get((g, cls), (0, 0))
                if c0 < 0 or c0 + wc > plan.ncols \
                        or not (c0 <= lo_ and hi_ <= c0 + wc):
                    findings.append(Finding(
                        "table", f"halo round (delta={delta}, {cls}):"
                        f" ghost row {g} window [{c0}, {c0 + wc}) "
                        f"misses its reader span [{lo_}, {hi_}) or "
                        f"exceeds [0, {plan.ncols})", device=d))
                delivered[d].setdefault(g, set()).add(cls)
    for d in range(D):
        for g, need in ghosts[d].items():
            got = delivered[d].get(g, set())
            if not need <= got:
                findings.append(Finding(
                    "table", f"ghost row {g} needs strips "
                    f"{sorted(need)} but the rounds deliver "
                    f"{sorted(got)}", device=d))


def _check_phase_tables(plan, ints, bnds, findings):
    tabs = plan.phase_tables_host()
    halo = plan.halo
    for d in range(plan.num_shards):
        if halo.int_steps[d] != ints[d] or halo.bnd_steps[d] != bnds[d]:
            findings.append(Finding(
                "table", "interior/boundary step partition disagrees "
                "with the re-derived remote-neighbour classification",
                device=d))
            continue
        owned = sorted(ints[d] + bnds[d])
        count = int(_rederived_partition(plan)[1][d])
        if owned != list(range(count)):
            findings.append(Finding(
                "table", f"phase step lists do not partition the "
                f"{count} owned steps", device=d))
    if tabs is None:
        return
    it, bt = tabs
    for d in range(plan.num_shards):
        for name, tab, ref in (("interior", it, ints),
                               ("boundary", bt, bnds)):
            k = int(tab[d, 0])
            if k != len(ref[d]) \
                    or tab[d, 1:1 + k].tolist() != ref[d]:
                findings.append(Finding(
                    "table", f"{name} phase table row disagrees with "
                    f"the re-derived step list", device=d))


def _check_sharded_lut(plan, findings):
    """Each device's decode-table chunk must decode its slab row-major:
    chunk row t (t < count) is the member block whose packed slot is
    (t % ncols, lo + t // ncols).  Applies to every table-backed
    lowering (prefetch_lut, or mma on block-indexed structures)."""
    D = plan.num_shards
    if plan.partition != "storage-rows":
        return
    per = plan.rpd * plan.ncols
    lut = plan.lut_sharded_host()
    if lut is None:
        lut = plan.mma_table_sharded_host()
    lut = np.asarray(lut)
    if plan._tiling is not None:
        slot = plan._tiling.tile_index
    else:
        slot = plan.layout.slot
    tbl = np.asarray(plan.shard_table_host())
    _, count = _rederived_partition(plan)
    for d in range(D):
        chunk = lut[d * per:(d + 1) * per]
        c = int(count[d])
        if c == 0:
            continue
        t = np.arange(c)
        sx, sy = slot(chunk[:c, _LUT_BX].astype(np.int64),
                      chunk[:c, _LUT_BY].astype(np.int64))
        sx = _np(sx).astype(np.int64)
        sy = _np(sy).astype(np.int64)
        row0 = int(tbl[d, SHARD_LO])
        bad = np.nonzero((sx != t % plan.ncols)
                         | (sy != row0 + t // plan.ncols))[0]
        for i in bad[:3]:
            findings.append(Finding(
                "table", f"sharded LUT chunk row {i} decodes to slot "
                f"({sx[i]}, {sy[i]}), expected "
                f"({i % plan.ncols}, {row0 + i // plan.ncols})",
                device=d))
        if len(bad) > 3:
            findings.append(Finding(
                "table", f"... {len(bad)} misplaced sharded LUT rows",
                device=d))


def _check_phase_views(plan, findings):
    """Interior + boundary launches together must cover each owned step
    exactly once, with decodes equal to the base launch's."""
    if plan.phase_tables_host() is None:
        return
    views = [plan.phase_view("interior"), plan.phase_view("boundary")]
    for d in range(plan.num_shards):
        base_refs = host_prefetch_refs(plan, d)
        ids, bx, by, live = decode_steps(plan, base_refs)
        base = {}
        for s in np.nonzero(live)[0]:
            base[int(ids[-1][s])] = (int(bx[s]), int(by[s]))
        covered: Dict[int, int] = {}
        for view in views:
            refs = host_prefetch_refs(view, d)
            vids, vbx, vby, vlive = decode_steps(view, refs)
            ptab = refs[-1]
            for s in np.nonzero(vlive)[0]:
                t = int(ptab[1 + int(vids[-1][s])])
                covered[t] = covered.get(t, 0) + 1
                if base.get(t) != (int(vbx[s]), int(vby[s])):
                    findings.append(Finding(
                        "coverage", f"phase {view.phase} step {s} "
                        f"decodes {(int(vbx[s]), int(vby[s]))} but "
                        f"base step {t} decodes {base.get(t)}",
                        device=d))
        if covered != {t: 1 for t in base}:
            findings.append(Finding(
                "coverage", "interior+boundary phases do not cover "
                "each owned step exactly once", device=d))


def _check_flash_hulls(plan, findings):
    """Flash q/k window hulls.  The gpu-structured flash kernel walks
    key blocks ``start..end`` of each query row with an in-kernel
    ``fori_loop``, so correctness needs (a) every block row of the
    domain to be a *contiguous* span -- a hole would be visited and
    attended to -- and (b) the row-extents source the lowering consumes
    to equal the hull re-derived from membership: the host
    ``row_extents`` table (bound under ``prefetch_lut``) and, for
    ``mma`` plans, the device digit-basis chain
    (:func:`repro.core.mma.row_extents_chain`).  ``closed_form``
    computes the bounds analytically in-kernel; its hull is implied by
    (a) plus the coverage check, and ``bounding`` walks the full range
    with where-guards.  Both hull sources must also stay inside the
    block grid (an out-of-range extent would clamp KV loads onto wrong
    tiles)."""
    dom = plan.sched_domain
    gx, gy = members_host(dom)
    nbx, nby = dom.bounding_box
    exp = np.zeros((nby, 2), np.int64)
    exp[:, 1] = -1
    for row in range(nby):
        xs = np.unique(gx[gy == row])
        if not len(xs):
            continue
        exp[row, 0], exp[row, 1] = xs.min(), xs.max()
        if len(xs) != exp[row, 1] - exp[row, 0] + 1:
            findings.append(Finding(
                "hull", f"block row {row} has holes: the flash key "
                f"loop over [{exp[row, 0]}, {exp[row, 1]}] would "
                f"attend to non-member tiles"))
    sources = [("row_extents", plan.row_extents())]
    if plan.lowering == "mma":
        import jax

        from repro.core import mma
        # eager: this check runs inside kernel jit traces
        with jax.ensure_compile_time_eval():
            chain = np.asarray(mma.row_extents_chain(plan.domain))
        sources.append(("mma.row_extents_chain", chain))
    for name, ext in sources:
        ext = np.asarray(ext).astype(np.int64)
        if ext.shape != (nby, 2):
            findings.append(Finding(
                "hull", f"{name} has shape {ext.shape}, expected "
                f"{(nby, 2)}"))
            continue
        occ = exp[:, 1] >= exp[:, 0]
        if np.any((ext[occ, 0] < 0) | (ext[occ, 1] >= nbx)):
            findings.append(Finding(
                "hull", f"{name} leaves the {nbx}-wide block grid"))
        bad = np.nonzero((ext[:, 0] != exp[:, 0])
                         | (ext[:, 1] != exp[:, 1]))[0]
        for row in bad[:3]:
            findings.append(Finding(
                "hull", f"{name} row {row} = "
                f"[{ext[row, 0]}, {ext[row, 1]}] but the membership "
                f"hull is [{exp[row, 0]}, {exp[row, 1]}]"))
        if len(bad) > 3:
            findings.append(Finding(
                "hull", f"... {len(bad)} wrong {name} rows"))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def verify_plan(plan: GridPlan, *, kernel: str = "generic",
                checks: Optional[Sequence[str]] = None) -> Report:
    """Run every applicable static check for ``plan`` under the named
    kernel access model (see :data:`ACCESS_MODELS`); returns a
    :class:`Report` (``.ok`` / ``.findings``)."""
    import jax

    # host-side static analysis even when invoked from inside a kernel's
    # jit trace (the verify= debug flag): the mma lowering's decode
    # chains are jnp, and staging them would make every re-derived
    # value a tracer.
    with jax.ensure_compile_time_eval():
        return _verify_plan_host(plan, kernel, checks)


def _verify_plan_host(plan, kernel, checks):
    model = ACCESS_MODELS[kernel]
    all_checks = ("coverage", "race", "table", "bounds", "alias", "hull")
    selected = tuple(checks) if checks is not None else all_checks
    findings: List[Finding] = []
    D = num_devices(plan)
    refs_per_device = [host_prefetch_refs(plan, d) for d in range(D)]
    per_device = [decode_steps(plan, refs_per_device[d])
                  for d in range(D)]

    if "coverage" in selected and _phase(plan) is None:
        _check_coverage(plan, per_device, findings)
    if "table" in selected:
        _check_tables(plan, findings)
        if _is_sharded(plan) and _phase(plan) is None:
            _check_shard_tables(plan, findings)
    if model["storage"]:
        if "race" in selected and model["race"]:
            _check_race(plan, refs_per_device, per_device, findings)
        if "bounds" in selected:
            _check_bounds(plan, refs_per_device, per_device, model,
                          findings)
        if "alias" in selected and model["alias_reads"]:
            _check_alias(plan, refs_per_device, per_device, model,
                         findings)
    if "hull" in selected and model.get("hulls"):
        _check_flash_hulls(plan, findings)
    if "coverage" in selected and _is_sharded(plan) \
            and _phase(plan) is None \
            and plan.partition == "storage-rows" \
            and plan.halo is not None \
            and plan.halo.int_steps is not None \
            and plan.lowering != "bounding":
        _check_phase_views(plan, findings)
    return Report(plan=plan_signature(plan), checks=selected,
                  findings=findings)


def verify_or_raise(plan: GridPlan, *, kernel: str = "generic",
                    checks: Optional[Sequence[str]] = None) -> Report:
    """``verify_plan`` + raise :class:`PlanVerificationError` on any
    finding -- the ``verify=`` debug-flag entry point of the kernels."""
    return verify_plan(plan, kernel=kernel,
                       checks=checks).raise_on_findings()


# ---------------------------------------------------------------------------
# paged KV page tables (the serving scheduler's host invariants)
# ---------------------------------------------------------------------------

def verify_page_table(table, seq_lens, *, page_size: int,
                      num_pages: int, free_pages=(),
                      null_page: int = 0) -> Report:
    """Re-derive the page-table invariants of the paged KV pool from
    first principles and report violations (the host-side analogue of
    the plan LUT checks -- the table *is* a decode LUT pointed at
    physical memory).

    table:      (num_slots, max_pages) i32; seq_lens: per-slot live
    token counts (0 = inactive).  Each slot's *active extent* is its
    first ``ceil(len / page_size)`` entries.  Checks:

    * **bounds** -- every entry in [0, num_pages);
    * **null-in-extent** -- no active extent maps the null page (a
      reader would consume trash-page garbage);
    * **double-map** -- no physical page owned by two active extents
      (a write in one request would corrupt another's KV);
    * **stale-free** -- no active extent maps a page on the free list
      (the allocator would hand it to the next admission: a
      use-after-free);
    * **tail-null** -- entries past the active extent are the null
      page (a stale mapping there is a freed-page leak waiting for a
      ``seq_pos`` bug to read it).
    """
    table = np.asarray(table)
    findings: List[Finding] = []
    if table.ndim != 2:
        raise ValueError(f"page table must be 2-D, got {table.shape}")
    if len(seq_lens) != table.shape[0]:
        raise ValueError(f"{len(seq_lens)} seq_lens for "
                         f"{table.shape[0]} slots")
    free = set(int(p) for p in free_pages)
    bad = (table < 0) | (table >= num_pages)
    if bad.any():
        s, j = map(int, np.argwhere(bad)[0])
        findings.append(Finding(
            "bounds", f"slot {s} entry {j} = {int(table[s, j])} outside "
            f"[0, {num_pages})"))
    owner: Dict[int, int] = {}
    for s, n in enumerate(seq_lens):
        ext = -(-int(n) // page_size)
        for j in range(ext):
            p = int(table[s, j])
            if p == null_page:
                findings.append(Finding(
                    "null-in-extent",
                    f"slot {s} ({n} tokens) maps the null page at "
                    f"entry {j}"))
                continue
            if p in owner and owner[p] != s:
                findings.append(Finding(
                    "double-map",
                    f"page {p} mapped by slots {owner[p]} and {s}"))
            owner[p] = s
            if p in free:
                findings.append(Finding(
                    "stale-free",
                    f"slot {s} entry {j} maps freed page {p}"))
        tail = table[s, ext:]
        if (tail != null_page).any():
            j = ext + int(np.argmax(tail != null_page))
            findings.append(Finding(
                "tail-null",
                f"slot {s} ({n} tokens, extent {ext}) still maps page "
                f"{int(table[s, j])} at entry {j}"))
    plan_sig = {"kind": "page-table", "slots": int(table.shape[0]),
                "max_pages": int(table.shape[1]),
                "page_size": int(page_size),
                "num_pages": int(num_pages)}
    return Report(plan=plan_sig,
                  checks=("bounds", "null-in-extent", "double-map",
                          "stale-free", "tail-null"),
                  findings=findings).raise_on_findings()
