"""Static + dynamic verification of block-space execution plans.

``verifier``  -- host-side static checks over any GridPlan/ShardedPlan:
                 race freedom, exactly-once coverage, table fidelity,
                 index bounds, aliasing safety.
``sanitizer`` -- interpret-mode access sanitizer: instruments emitted
                 ``pallas_call``s (BlockSpec index maps, ``pl.load`` /
                 ``pl.store``) and cross-checks the recorded traces
                 against the statically computed read/write sets.
``verify``    -- the CLI: ``python -m repro.analysis.verify --matrix``
                 sweeps the feature matrix and emits a JSON report.
"""
from .sanitizer import AccessTrace, verify_launches
from .verifier import (Finding, PlanVerificationError, Report,
                       verify_or_raise, verify_page_table, verify_plan)

__all__ = [
    "AccessTrace",
    "Finding",
    "PlanVerificationError",
    "Report",
    "verify_launches",
    "verify_or_raise",
    "verify_page_table",
    "verify_plan",
]
