"""Interpret-mode access sanitizer: the dynamic backstop to the static
verifier.

:class:`AccessTrace` installs itself as the emit hook of
:mod:`repro.core.backend`, so every *interpreted* ``pallas_call`` the
engine lowers while the trace is active gets instrumented:

* every ``BlockSpec`` index map is wrapped to record, per grid step,
  the block index it actually returned (``jax.debug.callback`` fires
  with the concrete runtime values even under ``jit``);
* ``pl.load`` / ``pl.store`` are shimmed for the duration, so the
  gpu structure's computed-offset accesses -- the ones no BlockSpec
  describes -- are recorded as concrete (offset, size) windows per ref
  shape.

``crosscheck()`` then compares the recorded traces against the
*statically* computed access sets:

* each operand's recorded index-map trace must equal the host
  evaluation of the original index map over the full grid (the
  block-indexed structure's complete read/write set);
* every recorded load/store window must be in-bounds for its ref;
* for kernels with a storage access model ("write", "ca"), the set of
  dynamically stored tiles must equal the static write set
  (``plan.storage_index`` over live steps), and every loaded tile must
  lie in the static read set (center + valid-clamped neighbours).

Launches of :class:`~repro.core.shard.ShardedPlan` are observed but not
instrumented: under ``shard_map`` one trace serves every device, so a
single record stream cannot be attributed to a device; the static
verifier covers those per-device.

``verify_launches(fn, *args, kernel=...)`` is the convenience wrapper:
run ``fn`` under a trace and raise
:class:`~repro.analysis.verifier.PlanVerificationError` on any
mismatch.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.experimental import pallas as pl

from repro.core import backend as backend_lib
from repro.core.shard import ShardedPlan

from .verifier import (ACCESS_MODELS, Finding, host_prefetch_refs,
                       neighbor_tiles, plan_signature, storage_grid,
                       storage_tiles)


def _full_steps(plan) -> Tuple[np.ndarray, ...]:
    """Every grid-step id tuple of one launch, batch dims included."""
    grids = np.meshgrid(*[np.arange(int(g)) for g in plan.grid],
                        indexing="ij") if plan.grid else []
    return tuple(g.ravel().astype(np.int64) for g in grids)


class _Launch:
    """One instrumented emission and everything recorded about it."""

    def __init__(self, lid: int, record):
        self.lid = lid
        self.record = record
        self.specs: List[Tuple[str, Any]] = []   # (opid, original spec)
        self.im_trace: Dict[str, set] = {}       # opid -> {(ids + idx)}
        self.accesses: set = set()   # (kind, shape, starts, sizes)
        self.operand_shapes: Optional[Tuple] = None
        self.out_shapes: Tuple = ()

    @property
    def plan(self):
        return self.record.plan


class AccessTrace:
    """Context manager recording the accesses of every interpreted
    launch emitted (and executed) inside the ``with`` block.

    >>> with AccessTrace() as tr:
    ...     out = sierpinski_write(8, block=4)
    >>> findings = tr.crosscheck(kernel="write")
    """

    def __init__(self, kernel: str = "generic"):
        self.kernel = kernel
        self.launches: List[_Launch] = []
        self._active = False
        self._prev_hook = None
        self._orig_load = None
        self._orig_store = None
        self._stack: List[_Launch] = []

    # -- lifecycle -----------------------------------------------------------

    def __enter__(self) -> "AccessTrace":
        self._prev_hook = backend_lib.set_emit_hook(self)
        self._orig_load, self._orig_store = pl.load, pl.store
        pl.load = self._shim_load
        pl.store = self._shim_store
        self._active = True
        # previously traced configs would reuse cached, un-instrumented
        # executables: force a re-trace of everything run in the block
        jax.clear_caches()
        return self

    def __exit__(self, *exc):
        self._active = False
        backend_lib.set_emit_hook(self._prev_hook)
        pl.load, pl.store = self._orig_load, self._orig_store
        # drop the instrumented executables so later calls re-trace
        # clean (the recording callbacks hold a reference to us)
        jax.clear_caches()
        return False

    # -- emit-hook protocol --------------------------------------------------

    def instrument(self, record, kernel, in_specs, out_specs):
        launch = _Launch(len(self.launches), record)
        self.launches.append(launch)
        if isinstance(record.plan, ShardedPlan):
            return kernel, in_specs, out_specs

        def kernel_wrapped(coords, *refs):
            self._stack.append(launch)
            try:
                kernel(coords, *refs)
            finally:
                self._stack.pop()

        new_in = [self._wrap_spec(launch, f"in{i}", s)
                  for i, s in enumerate(in_specs)]
        if isinstance(out_specs, (list, tuple)):
            new_out = type(out_specs)(
                self._wrap_spec(launch, f"out{i}", s)
                for i, s in enumerate(out_specs))
        else:
            new_out = self._wrap_spec(launch, "out0", out_specs)
        return kernel_wrapped, new_in, new_out

    def wrap_call(self, record, fn):
        launch = next(ln for ln in reversed(self.launches)
                      if ln.record is record)
        if isinstance(record.plan, ShardedPlan):
            return fn

        def call(*operands):
            if launch.operand_shapes is None:
                launch.operand_shapes = tuple(
                    tuple(op.shape) for op in operands)
                shp = record.out_shape
                if not isinstance(shp, (list, tuple)):
                    shp = (shp,)
                launch.out_shapes = tuple(tuple(s.shape) for s in shp)
            return fn(*operands)

        return call

    # -- recording -----------------------------------------------------------

    def _wrap_spec(self, launch, opid, spec):
        bs = getattr(spec, "block_shape", None)
        im = getattr(spec, "index_map", None)
        if bs is None or im is None:
            return spec          # SMEM / ANY / whole-operand specs
        launch.specs.append((opid, spec))
        launch.im_trace.setdefault(opid, set())
        ngrid = len(launch.plan.grid)
        trace = self

        def index_map(*args):
            idx = im(*args)
            idx_t = idx if isinstance(idx, tuple) else (idx,)
            jax.debug.callback(trace._on_im, launch.lid, opid,
                               *args[:ngrid], *idx_t)
            return idx

        return pl.BlockSpec(bs, index_map)

    def _on_im(self, lid, opid, *vals):
        if not self._active:
            return
        launch = self.launches[int(lid)]
        launch.im_trace[opid].add(
            tuple(int(np.asarray(v)) for v in vals))

    def _shim_load(self, ref, idx=None, *args, **kwargs):
        self._record_access("load", ref, idx)
        return self._orig_load(ref, idx, *args, **kwargs)

    def _shim_store(self, ref, idx, val, *args, **kwargs):
        self._record_access("store", ref, idx)
        return self._orig_store(ref, idx, val, *args, **kwargs)

    def _record_access(self, kind, ref, idx):
        if not self._stack or not self._active:
            return
        launch = self._stack[-1]
        shape = tuple(int(s) for s in ref.shape)
        if idx is None:
            idx = tuple(slice(None) for _ in shape)
        starts, sizes = [], []
        for dim, i in zip(shape, idx):
            if isinstance(i, slice):
                starts.append(0 if i.start is None else i.start)
                sizes.append(dim if i.stop is None else i.stop)
            elif hasattr(i, "start") and hasattr(i, "size"):
                starts.append(i.start)
                sizes.append(int(i.size))
            else:
                starts.append(i)
                sizes.append(1)
        trace = self

        def rec(*vals):
            if not trace._active:
                return
            launch.accesses.add(
                (kind, shape, tuple(int(np.asarray(v)) for v in vals),
                 tuple(sizes)))

        jax.debug.callback(rec, *starts)

    # -- crosscheck ----------------------------------------------------------

    def crosscheck(self, kernel: Optional[str] = None) -> List[Finding]:
        """Diff every launch's recorded trace against its static access
        sets; returns the findings (empty = traces match)."""
        jax.effects_barrier()
        model = ACCESS_MODELS[kernel or self.kernel]
        findings: List[Finding] = []
        for launch in self.launches:
            if isinstance(launch.plan, ShardedPlan):
                continue
            if launch.operand_shapes is None:
                continue         # emitted but never called
            self._check_im_trace(launch, findings)
            self._check_accesses(launch, model, findings)
        return findings

    def _host_im(self, launch, spec, ids):
        refs = host_prefetch_refs(launch.plan)
        idx = spec.index_map(*ids, *refs)
        idx_t = idx if isinstance(idx, tuple) else (idx,)
        return [np.broadcast_to(np.asarray(v).astype(np.int64),
                                ids[-1].shape) for v in idx_t]

    def _check_im_trace(self, launch, findings):
        ids = _full_steps(launch.plan)
        sig = plan_signature(launch.plan)
        for opid, spec in launch.specs:
            exp_idx = self._host_im(launch, spec, ids)
            expected = set(zip(*[a.tolist() for a in ids],
                               *[a.tolist() for a in exp_idx]))
            got = launch.im_trace[opid]
            if got == expected:
                continue
            for t in sorted(got - expected)[:2]:
                findings.append(Finding(
                    "sanitizer", f"{sig}: operand {opid} index map "
                    f"returned {t[len(ids):]} at step {t[:len(ids)]}; "
                    f"the static evaluation never produces it"))
            for t in sorted(expected - got)[:2]:
                findings.append(Finding(
                    "sanitizer", f"{sig}: operand {opid} never "
                    f"recorded the statically expected block index "
                    f"{t[len(ids):]} at step {t[:len(ids)]}"))

    def _check_accesses(self, launch, model, findings):
        sig = plan_signature(launch.plan)
        for kind, shape, starts, sizes in launch.accesses:
            for dim, s, z in zip(shape, starts, sizes):
                if s < 0 or s + z > dim:
                    findings.append(Finding(
                        "sanitizer", f"{sig}: {kind} window "
                        f"[{s}, {s + z}) out of bounds for axis of "
                        f"extent {dim} (ref shape {shape})"))
        if not model["storage"] or not model["race"]:
            return
        plan = launch.plan
        nr, nc = storage_grid(plan)
        refs = host_prefetch_refs(plan)
        from .verifier import decode_steps
        ids, bx, by, live = decode_steps(plan, refs)
        r, c = storage_tiles(plan, refs, ids)
        write_tiles = set(zip(r[live].tolist(), c[live].tolist()))
        read_tiles = set(write_tiles)
        if model["neighbors"]:
            from repro.core.compact import NEIGHBOR_OFFSETS8
            for j in range(len(NEIGHBOR_OFFSETS8)):
                jr, jc = neighbor_tiles(plan, refs, ids, j)
                read_tiles |= set(zip(jr[live].tolist(),
                                      jc[live].tolist()))
        for kind, shape, starts, sizes in launch.accesses:
            if len(shape) != 2:
                continue
            th, tw = sizes
            if th <= 0 or tw <= 0 or shape[0] % th or shape[1] % tw:
                continue
            if (shape[0] // th, shape[1] // tw) != (nr, nc):
                continue         # not the storage-tiled state array
            if starts[0] % th or starts[1] % tw:
                findings.append(Finding(
                    "sanitizer", f"{sig}: {kind} at {starts} is not "
                    f"tile-aligned to the ({th}, {tw}) storage tiling"))
                continue
            tile = (starts[0] // th, starts[1] // tw)
            if kind == "store" and tile not in write_tiles:
                findings.append(Finding(
                    "sanitizer", f"{sig}: store to tile {tile} is "
                    f"outside the static write set"))
            if kind == "load" and tile not in read_tiles:
                findings.append(Finding(
                    "sanitizer", f"{sig}: load of tile {tile} is "
                    f"outside the static read set"))
        # completeness: every static write tile must have been stored
        stored = set()
        for kind, shape, starts, sizes in launch.accesses:
            if kind != "store" or len(shape) != 2:
                continue
            th, tw = sizes
            if th > 0 and tw > 0 and not (shape[0] % th or shape[1] % tw) \
                    and (shape[0] // th, shape[1] // tw) == (nr, nc) \
                    and not (starts[0] % th or starts[1] % tw):
                stored.add((starts[0] // th, starts[1] // tw))
        if stored and stored != write_tiles:
            missing = sorted(write_tiles - stored)[:3]
            if missing:
                findings.append(Finding(
                    "sanitizer", f"{sig}: static write set expects "
                    f"stores to tiles {missing} that never happened"))


def verify_launches(fn, *args, kernel: str = "generic",
                    strict: bool = True, **kwargs):
    """Run ``fn(*args, **kwargs)`` under an :class:`AccessTrace` and
    cross-check.  Returns ``(result, findings)``; with ``strict`` (the
    default) raises on any finding instead."""
    with AccessTrace(kernel=kernel) as tr:
        out = fn(*args, **kwargs)
        out = jax.block_until_ready(out)
    findings = tr.crosscheck()
    if strict and findings:
        from .verifier import PlanVerificationError
        lines = "\n  ".join(str(f) for f in findings)
        raise PlanVerificationError(
            f"access sanitizer found mismatches:\n  {lines}")
    return out, findings
