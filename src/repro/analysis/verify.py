"""CLI: statically verify every plan the execution engine can emit.

``python -m repro.analysis.verify --matrix`` sweeps the registered
domain zoo across every lowering, storage, coarsening factor and
(emulated) shard count, runs the five static checks of
:mod:`repro.analysis.verifier` on each resulting plan, then drives the
interpret-mode access sanitizer (:mod:`repro.analysis.sanitizer`) over
real kernel launches on both interpret targets.  The result is a JSON
report (``--out``) and a nonzero exit status when any combination
produced a finding -- which is what lets CI gate merges on it.

``--smoke`` cuts the sweep to a representative subset so the gate runs
in seconds; the nightly/full run drops the flag.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Iterator, Optional, Tuple

from .verifier import HostMesh, verify_plan

#: domains whose lambda map is a digit-unrolled fractal -- the ones
#: with a compact storage layout and a coarsening axis.
FRACTAL_DOMAINS = ("sierpinski", "carpet", "vicsek")

#: coarsening factor exercised per fractal (one supertile level: the
#: gasket contracts by 2, the k=8/k=5 carpets by 3).
COARSEN = {"sierpinski": 2, "carpet": 3, "vicsek": 3}

#: shard counts emulated through :class:`HostMesh` (no devices needed).
SHARD_COUNTS = (1, 2, 3)


def registered_domains(size: str = "small") -> dict:
    """The domain zoo the matrix sweeps: every BlockDomain family the
    repo ships, at sizes small enough that exhaustive host enumeration
    of the grid stays fast."""
    from repro.core import fractal as F
    from repro.core.domain import (BandDomain, BoundingBoxDomain,
                                   GeneralizedFractalDomain,
                                   SierpinskiDomain, TriangularDomain)
    if size != "small":
        raise ValueError(f"unknown matrix size {size!r}")
    return {
        "sierpinski": SierpinskiDomain(8),
        "carpet": GeneralizedFractalDomain(F.CARPET, 9),
        "vicsek": GeneralizedFractalDomain(F.VICSEK, 9),
        "triangular": TriangularDomain(6),
        "band": BandDomain(8, 3),
        "bounding-box": BoundingBoxDomain(4, 3),
    }


def matrix_plans(smoke: bool = False) -> Iterator[Tuple[str, object, str]]:
    """Yield ``(label, plan, kernel_model)`` for every combination the
    matrix covers: unsharded x {lowering, storage}, coarsened fractals,
    and sharded plans across partitions / halo modes / shard counts."""
    from repro.core.plan import LOWERINGS, GridPlan
    from repro.core.shard import ShardedPlan

    domains = registered_domains("small")
    names = ("sierpinski", "triangular") if smoke else tuple(domains)
    # -- unsharded: every domain x lowering x applicable storage -------------
    for name in names:
        dom = domains[name]
        storages = ("embedded", "compact") if name in FRACTAL_DOMAINS \
            else ("embedded",)
        for lowering in LOWERINGS:
            for storage in storages:
                plan = GridPlan(dom, lowering, storage=storage)
                yield (f"{name}/{lowering}/{storage}", plan, "write")
    # -- coarsened fractals --------------------------------------------------
    coarse = ("sierpinski",) if smoke else FRACTAL_DOMAINS
    for name in coarse:
        dom, c = domains[name], COARSEN[name]
        for lowering in LOWERINGS:
            for storage in ("embedded", "compact"):
                plan = GridPlan(dom, lowering, storage=storage, coarsen=c)
                yield (f"{name}/{lowering}/{storage}/coarsen={c}",
                       plan, "write")
    # -- sharded: emulated meshes, every partition x halo mode ---------------
    sharded = ("sierpinski",) if smoke else ("sierpinski", "carpet")
    counts = (1, 2) if smoke else SHARD_COUNTS
    variants = (("compact", "storage-rows", True),
                ("compact", "storage-rows", False),
                ("embedded", "linear", False))
    for name in sharded:
        dom = domains[name]
        for d in counts:
            mesh = HostMesh(d, axis="data")
            for lowering in LOWERINGS:
                for storage, partition, halo in variants:
                    plan = ShardedPlan(dom, lowering, storage=storage,
                                       mesh=mesh, axis="data",
                                       partition=partition, halo=halo)
                    tag = f"halo={int(halo)}" if partition == \
                        "storage-rows" else partition
                    yield (f"{name}/{lowering}/{storage}/D={d}/{tag}",
                           plan, "write")


def run_static_matrix(smoke: bool = False, verbose: bool = True) -> list:
    """Verify every matrix plan; returns ``[(label, Report)]``."""
    out = []
    for label, plan, kernel in matrix_plans(smoke=smoke):
        report = verify_plan(plan, kernel=kernel)
        out.append((label, report))
        if verbose:
            status = "ok" if report.ok else \
                f"FAIL ({len(report.findings)} findings)"
            print(f"  static {label}: {status}")
            for f in report.findings:
                print(f"    - {f}")
    return out


def run_sanitizer_smoke(smoke: bool = False, verbose: bool = True) -> list:
    """Drive real kernel launches under the access sanitizer on both
    interpret targets; returns ``[(label, findings)]``."""
    import jax.numpy as jnp

    from repro.core.compact import compact_layout
    from repro.core.domain import make_fractal_domain
    from repro.kernels.sierpinski_ca import ca_run
    from repro.kernels.sierpinski_write import sierpinski_write
    from .sanitizer import verify_launches

    dom = make_fractal_domain("sierpinski-gasket", 8)
    lay = compact_layout(dom)
    block = 3
    operands = {"embedded": jnp.zeros((24, 24), jnp.float32),
                "compact": jnp.zeros(lay.array_shape(block), jnp.float32)}
    grid_modes = ("closed_form", "mma") if smoke \
        else ("closed_form", "prefetch_lut", "bounding", "mma")
    out = []
    for bk in ("gpu-interpret", "tpu-interpret"):
        for storage in ("embedded", "compact"):
            for gm in grid_modes:
                label = f"write/{bk}/{storage}/{gm}"
                _, findings = verify_launches(
                    sierpinski_write, operands[storage], 1.0, block=block,
                    grid_mode=gm, storage=storage, domain=dom,
                    num_stages=1, backend=bk, kernel="write",
                    strict=False)
                out.append((label, findings))
                _say(label, findings, verbose)
        state = operands["compact"]
        label = f"ca/{bk}/compact/closed_form"
        _, findings = verify_launches(
            ca_run, state, jnp.zeros_like(state), 2, fuse=1, block=block,
            grid_mode="closed_form", storage="compact", domain=dom,
            num_stages=1, backend=bk, kernel="ca", strict=False)
        out.append((label, findings))
        _say(label, findings, verbose)
    return out


def _say(label: str, findings: list, verbose: bool) -> None:
    if verbose:
        status = "ok" if not findings else f"FAIL ({len(findings)})"
        print(f"  sanitize {label}: {status}")
        for f in findings:
            print(f"    - {f}")


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.verify",
        description=__doc__.splitlines()[0])
    ap.add_argument("--matrix", action="store_true",
                    help="sweep the full domain/lowering/storage/shard "
                         "matrix")
    ap.add_argument("--smoke", action="store_true",
                    help="representative subset (CI gate)")
    ap.add_argument("--no-sanitize", action="store_true",
                    help="static checks only, skip interpret-mode "
                         "sanitizer launches")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    if not args.matrix:
        ap.error("nothing to do: pass --matrix")
    verbose = not args.quiet

    static = run_static_matrix(smoke=args.smoke, verbose=verbose)
    sanitized = [] if args.no_sanitize else \
        run_sanitizer_smoke(smoke=args.smoke, verbose=verbose)

    n_findings = sum(len(r.findings) for _, r in static) + \
        sum(len(fs) for _, fs in sanitized)
    report = {
        "ok": n_findings == 0,
        "num_static": len(static),
        "num_sanitized": len(sanitized),
        "num_findings": n_findings,
        "static": [{"label": label, **r.to_json()} for label, r in static],
        "sanitizer": [{"label": label, "ok": not fs,
                       "findings": [f.to_json() for f in fs]}
                      for label, fs in sanitized],
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    print(f"verified {len(static)} plans statically, "
          f"{len(sanitized)} sanitized launches: "
          f"{n_findings} findings")
    return 0 if n_findings == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
