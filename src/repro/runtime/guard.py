"""Guarded execution: detect, degrade, recover.

The static verifier (PR 7) proves an emitted plan is correct *before*
it runs; this module is the runtime counterpart for everything the
verifier cannot see -- transient XLA errors, NaN-producing tiles,
stragglers, preemptions.  The pieces compose bottom-up:

``classify_error``     -- transient-vs-fatal triage.  Retrying a shape
                          or compile error just re-raises it slower;
                          retrying a preempted / flaky-interconnect
                          step usually succeeds.
``Backoff``            -- deterministic jittered exponential backoff
                          (seeded, so a replayed recovery sleeps the
                          same schedule).
``GuardedCall``        -- wraps one step function (prefill / decode /
                          train step) with a per-call deadline, output
                          validation, classified retries, and an event
                          log.  Exhausted retries raise
                          :class:`GuardExhausted` carrying a
                          machine-readable :class:`FailureReport`.
``DegradationLadder``  -- an ordered list of execution configs
                          (blockspace -> xla decode, pipelined -> sync,
                          compact -> embedded); ``step_down`` records
                          each transition so the evidence trail
                          survives the incident.
``ServerState``        -- the serving state machine's states
                          (healthy -> degraded -> draining).

Nothing here imports the kernels or the model stack: the serving and
training layers wrap their own callables.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import os
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------

class TransientFault(RuntimeError):
    """An error known to be transient (injected faults, explicit
    retryable conditions).  Always classified ``transient``."""


class ValidationError(RuntimeError):
    """A guarded call produced output that failed validation (NaN/inf
    screen, spot-check mismatch).  Classified ``transient``: the step
    is re-executed, not the process killed."""


class DeadlineExceeded(TimeoutError):
    """A guarded call overran its per-call deadline."""


class GuardExhausted(RuntimeError):
    """Retries exhausted (or a fatal error was classified); carries the
    structured :class:`FailureReport` as ``.report``."""

    def __init__(self, message: str, report: "FailureReport"):
        super().__init__(message)
        self.report = report


#: substrings (lowercased) marking a generic RuntimeError as transient
#: -- the gRPC/XLA status families that a retry can actually fix.
TRANSIENT_MARKERS = (
    "resource_exhausted", "resource exhausted", "deadline",
    "unavailable", "preempt", "transient", "data loss", "aborted",
    "connection reset", "socket closed", "too many open files",
    "cancelled", "injected",
)

#: substrings marking an XLA runtime error as *fatal* even though the
#: type says runtime: these are trace/compile/shape problems that will
#: fail identically on every retry.
FATAL_MARKERS = (
    "invalid_argument", "invalid argument", "unimplemented",
    "failed_precondition", "shape", "mosaic", "lowering", "dtype",
)


def _jax_runtime_error() -> type:
    try:
        from jax.errors import JaxRuntimeError
        return JaxRuntimeError
    except Exception:  # pragma: no cover - ancient jax
        return RuntimeError


def classify_error(exc: BaseException) -> str:
    """``"transient"`` (worth retrying) or ``"fatal"`` (re-raise now).

    Explicit transient types (:class:`TransientFault`,
    :class:`ValidationError`, :class:`DeadlineExceeded`, timeouts,
    connection errors) are transient.  Python-level programming errors
    (TypeError/ValueError/KeyError/...) are fatal.  XLA runtime errors
    are transient *unless* their message carries a compile/shape-family
    marker; generic RuntimeErrors are fatal unless their message
    carries a transient-family marker.
    """
    if isinstance(exc, (TransientFault, ValidationError, DeadlineExceeded,
                        TimeoutError, ConnectionError, BrokenPipeError)):
        return "transient"
    if isinstance(exc, (TypeError, ValueError, KeyError, IndexError,
                        AttributeError, NotImplementedError,
                        ZeroDivisionError, AssertionError)):
        return "fatal"
    msg = str(exc).lower()
    if isinstance(exc, _jax_runtime_error()):
        if any(m in msg for m in FATAL_MARKERS):
            return "fatal"
        return "transient"
    if isinstance(exc, (OSError, RuntimeError)):
        if any(m in msg for m in TRANSIENT_MARKERS):
            return "transient"
        return "fatal"
    return "fatal"


# ---------------------------------------------------------------------------
# backoff
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Backoff:
    """Jittered exponential backoff with a deterministic schedule.

    ``delay(attempt)`` for attempt 1, 2, ... is
    ``min(base * factor**(attempt-1), max_s)`` scaled by a uniform
    jitter in ``[1 - jitter, 1 + jitter]`` drawn from a seeded
    generator -- two guards with the same seed sleep the same schedule
    (replay determinism), two with different seeds decorrelate (no
    thundering herd after a shared incident)."""

    base_s: float = 0.05
    factor: float = 2.0
    max_s: float = 5.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def delay(self, attempt: int) -> float:
        raw = min(self.base_s * self.factor ** max(attempt - 1, 0),
                  self.max_s)
        if self.jitter <= 0:
            return raw
        lo, hi = 1.0 - self.jitter, 1.0 + self.jitter
        return raw * float(self._rng.uniform(lo, hi))


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def validate_finite(out: Any, what: str = "output") -> None:
    """NaN/inf screen over every floating leaf of ``out``; raises
    :class:`ValidationError` naming the first offending leaf."""
    for path, leaf in jax.tree_util.tree_leaves_with_path(out):
        arr = np.asarray(leaf)
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        if not np.isfinite(arr).all():
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path) or "<leaf>"
            bad = int(arr.size - np.isfinite(arr).sum())
            raise ValidationError(
                f"{what}: {bad} non-finite values in leaf {key} "
                f"(shape {arr.shape})")


def spot_check(reference: Any, what: str = "output",
               atol: float = 0.0) -> Callable[[Any], None]:
    """Validator factory: the guarded output must match ``reference``
    (bit-identical by default -- the repo invariant).  The serving
    layer uses this for periodic lambda-plan spot checks: recompute a
    small known-good launch and compare."""
    ref_leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(
        reference)]

    def check(out: Any) -> None:
        got = [np.asarray(x) for x in jax.tree_util.tree_leaves(out)]
        if len(got) != len(ref_leaves):
            raise ValidationError(
                f"{what}: structure mismatch vs reference "
                f"({len(got)} leaves vs {len(ref_leaves)})")
        for i, (a, b) in enumerate(zip(got, ref_leaves)):
            if a.shape != b.shape:
                raise ValidationError(
                    f"{what}: leaf {i} shape {a.shape} vs reference "
                    f"{b.shape}")
            if atol > 0:
                ok = np.allclose(a, b, atol=atol, equal_nan=False)
            else:
                ok = np.array_equal(a, b)
            if not ok:
                n_bad = int(np.sum(a != b)) if a.shape == b.shape else -1
                raise ValidationError(
                    f"{what}: leaf {i} differs from reference in "
                    f"{n_bad} elements")

    return check


# ---------------------------------------------------------------------------
# structured reporting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GuardEvent:
    """One observation in a guard's life: an attempt, a failure, a
    retry, a recovery, a degradation."""

    name: str                      # call-site name
    kind: str                      # ok | transient | fatal | retry |
    #                                deadline | validation | degrade
    attempt: int = 0
    error: str = ""
    elapsed_s: float = 0.0
    time: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FailureReport:
    """Machine-readable terminal failure record: what failed, how it
    was classified, what was tried, and the full event trail."""

    name: str
    error: str
    error_type: str
    classification: str
    attempts: int
    events: List[GuardEvent] = dataclasses.field(default_factory=list)
    transitions: List[dict] = dataclasses.field(default_factory=list)
    time: float = 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["events"] = [e.to_json() if isinstance(e, GuardEvent) else e
                       for e in self.events]
        return d

    def write(self, path: str) -> str:
        """Atomically publish the report as JSON (tmp + rename)."""
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".report.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path


# ---------------------------------------------------------------------------
# the guarded call
# ---------------------------------------------------------------------------

class GuardedCall:
    """Wrap a step function with deadline, validation, and classified
    jittered retries.

    >>> g = GuardedCall(decode_fn, "decode", retries=2,
    ...                 validators=[validate_finite])
    >>> logits, cache = g(params, tok, cache, pos)

    Semantics per call:

    1. run ``fn``; ``jax.block_until_ready`` the result so async
       dispatch errors surface *here*, inside the guard;
    2. if a ``deadline_s`` is set and the call overran it, record a
       ``deadline`` event (and, with ``enforce_deadline``, treat it as
       a transient failure);
    3. run every validator over the output (raising
       :class:`ValidationError` counts as a transient failure);
    4. on a transient failure: sleep the backoff, call
       ``before_retry`` (the chaos/fault-injection path uses it to
       drop poisoned executable caches), and re-execute -- up to
       ``retries`` times;
    5. on a fatal failure: raise :class:`GuardExhausted` immediately
       with the report;
    6. on exhaustion: raise :class:`GuardExhausted` with the report.

    The event log (``.events``) persists across calls; ``on_event``
    observes each event as it happens.
    """

    def __init__(self, fn: Callable, name: str = "call", *,
                 retries: int = 3, backoff: Optional[Backoff] = None,
                 deadline_s: Optional[float] = None,
                 enforce_deadline: bool = False,
                 validators: Sequence[Callable[[Any], None]] = (),
                 classify: Callable[[BaseException], str] = classify_error,
                 on_event: Optional[Callable[[GuardEvent], None]] = None,
                 before_retry: Optional[Callable[[], None]] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.fn = fn
        self.name = name
        self.retries = int(retries)
        self.backoff = backoff or Backoff()
        self.deadline_s = deadline_s
        self.enforce_deadline = enforce_deadline
        self.validators = tuple(validators)
        self.classify = classify
        self.on_event = on_event
        self.before_retry = before_retry
        self.sleep = sleep
        self.events: List[GuardEvent] = []
        self.calls = 0
        self.recoveries = 0

    # -- internals ----------------------------------------------------------

    def _event(self, kind: str, attempt: int, error: str = "",
               elapsed: float = 0.0) -> GuardEvent:
        ev = GuardEvent(name=self.name, kind=kind, attempt=attempt,
                        error=error, elapsed_s=elapsed, time=time.time())
        self.events.append(ev)
        if self.on_event:
            self.on_event(ev)
        return ev

    def _report(self, exc: BaseException, classification: str,
                attempts: int) -> FailureReport:
        return FailureReport(
            name=self.name, error=str(exc),
            error_type=type(exc).__name__,
            classification=classification, attempts=attempts,
            events=list(self.events), time=time.time())

    # -- the call -----------------------------------------------------------

    def __call__(self, *args, **kwargs):
        self.calls += 1
        attempt = 0
        while True:
            attempt += 1
            t0 = time.perf_counter()
            try:
                out = self.fn(*args, **kwargs)
                out = jax.block_until_ready(out)
                elapsed = time.perf_counter() - t0
                if self.deadline_s is not None and elapsed > self.deadline_s:
                    self._event("deadline", attempt,
                                f"{elapsed:.3f}s > {self.deadline_s:.3f}s",
                                elapsed)
                    if self.enforce_deadline:
                        raise DeadlineExceeded(
                            f"{self.name}: {elapsed:.3f}s exceeded the "
                            f"{self.deadline_s:.3f}s deadline")
                for v in self.validators:
                    v(out)
                self._event("ok", attempt, elapsed=elapsed)
                if attempt > 1:
                    self.recoveries += 1
                return out
            except Exception as e:  # noqa: BLE001 - triage point
                elapsed = time.perf_counter() - t0
                kind = self.classify(e)
                self._event("validation" if isinstance(e, ValidationError)
                            else kind, attempt, str(e), elapsed)
                if kind == "fatal":
                    raise GuardExhausted(
                        f"{self.name}: fatal ({type(e).__name__}): {e}",
                        self._report(e, "fatal", attempt)) from e
                if attempt > self.retries:
                    raise GuardExhausted(
                        f"{self.name}: retries exhausted after "
                        f"{attempt} attempts: {e}",
                        self._report(e, "exhausted", attempt)) from e
                delay = self.backoff.delay(attempt)
                self._event("retry", attempt, f"backoff {delay:.3f}s")
                self.sleep(delay)
                if self.before_retry is not None:
                    self.before_retry()


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

class DegradationLadder:
    """Ordered fallback configs, fastest/most-aggressive first.

    Each rung is an opaque dict the owner knows how to apply
    (``{"decode_kernel": "blockspace", "stages": 2}`` -> ... ->
    ``{"decode_kernel": "xla"}``).  ``step_down(reason)`` moves one
    rung and records the transition; it returns ``False`` at the
    bottom (nothing left to degrade to -- time for the failure
    report)."""

    def __init__(self, rungs: Sequence[Dict[str, Any]],
                 on_transition: Optional[Callable[[dict], None]] = None):
        if not rungs:
            raise ValueError("a ladder needs at least one rung")
        self.rungs = [dict(r) for r in rungs]
        self.level = 0
        self.transitions: List[dict] = []
        self.on_transition = on_transition

    def current(self) -> Dict[str, Any]:
        return dict(self.rungs[self.level])

    @property
    def degraded(self) -> bool:
        return self.level > 0

    def exhausted(self) -> bool:
        return self.level >= len(self.rungs) - 1

    def step_down(self, reason: str = "") -> bool:
        if self.exhausted():
            return False
        rec = {"from_level": self.level, "to_level": self.level + 1,
               "from": self.current(),
               "to": dict(self.rungs[self.level + 1]),
               "reason": reason, "time": time.time()}
        self.level += 1
        self.transitions.append(rec)
        if self.on_transition:
            self.on_transition(rec)
        return True


class ServerState(str, enum.Enum):
    """The serving state machine: HEALTHY serves at the top rung;
    DEGRADED serves on a lower rung after repeated failures; DRAINING
    stops accepting work, checkpoints decode state, and exits so a
    successor can ``elastic_restore`` and resume."""

    HEALTHY = "healthy"
    DEGRADED = "degraded"
    DRAINING = "draining"


# ---------------------------------------------------------------------------
# deterministic sampling keys
# ---------------------------------------------------------------------------

def sample_key(base_key, pos: int, batch: int):
    """Per-slot sampling keys derived from ``(seed, slot, position)``
    via ``fold_in`` -- a pure function of the coordinates, so a retried
    or replayed decode step reproduces the identical token stream
    (stateful key-splitting would advance on every retry)."""
    k = jax.random.fold_in(base_key, int(pos))
    return jax.vmap(lambda s: jax.random.fold_in(k, s))(
        jnp.arange(batch, dtype=jnp.uint32))
