"""Guarded execution + deterministic fault injection.

:mod:`repro.runtime.guard` is the detection/recovery layer (GuardedCall,
classification, backoff, validators, degradation ladder, failure
reports); :mod:`repro.runtime.chaos` is the seeded fault injector and
the ``python -m repro.runtime.chaos --matrix`` proof that every fault
class is caught.
"""
from .chaos import (ALL_FAULTS, ChaosInjector, FaultPlan, FaultSpec,
                    corrupt_tune_cache, tear_checkpoint)
from .guard import (Backoff, DeadlineExceeded, DegradationLadder,
                    FailureReport, GuardedCall, GuardEvent, GuardExhausted,
                    ServerState, TransientFault, ValidationError,
                    classify_error, sample_key, spot_check, validate_finite)

__all__ = [
    "ALL_FAULTS", "Backoff", "ChaosInjector", "DeadlineExceeded",
    "DegradationLadder", "FailureReport", "FaultPlan", "FaultSpec",
    "GuardEvent", "GuardExhausted", "GuardedCall", "ServerState",
    "TransientFault", "ValidationError", "classify_error",
    "corrupt_tune_cache", "sample_key", "spot_check", "tear_checkpoint",
    "validate_finite",
]
