"""Deterministic fault injection for the block-space runtime.

A :class:`FaultPlan` is a seeded, replayable schedule of faults keyed
by *call site* and *call index*: the same seed injects the same faults
at the same points of the same program, so every chaos run -- local,
CI, or a bug reproduction -- is a deterministic experiment, mirroring
how the plan verifier's mutation tests seed one fault class at a time.

:class:`ChaosInjector` realizes a plan at four layers:

Pallas layer (rides the PR 7 ``set_emit_hook``/``EmitRecord`` machinery
of :mod:`repro.core.backend`; interpreted launches only, like the
access sanitizer):

* ``corrupt_table`` -- perturb the decoded :class:`BlockCoords` of one
  grid step, exactly what a corrupted LUT/neighbour-table row would
  decode to (the block lands in / reads from the wrong place);
* ``poison_tile``   -- overwrite one output tile after the kernel body
  with NaN / inf / a sign-flip ("bitflip": finite garbage that only a
  spot-check catches, not the NaN screen).

Collective layer (a ``jax.lax.ppermute`` shim, counted per traced
call):

* ``drop_halo``  -- one halo-exchange round delivers zeros;
* ``delay_halo`` -- one round is applied twice (stale/wrong-source
  ghost rows).

Host layer (``wrap(site, fn)`` around prefill/decode/train steps):

* ``transient_error`` -- raise a transient fault (``mode="jax"``
  raises a real ``jax.errors.JaxRuntimeError``);
* ``fatal_error``     -- raise a ValueError (mis-shaped/compile
  family: must NOT be retried);
* ``poison_result``   -- NaN out every float leaf of the step's output
  (a NaN-producing tile surfacing at the step boundary);
* ``sigterm``         -- deliver SIGTERM to the process mid-step (a
  :class:`~repro.distributed.fault_tolerance.PreemptionGuard` must be
  installed, as serve/train do).

File layer (module functions): :func:`tear_checkpoint` truncates the
latest checkpoint and leaves a torn ``.tmp`` directory behind;
:func:`corrupt_tune_cache` plants a malformed winner entry.

Because Pallas/collective faults are baked into a *trace*, jit cache
hits would replay old faults against a stale call count;
:meth:`ChaosInjector.refresh` (and context entry/exit) clears jax
caches so every instrumented launch re-traces against the live
schedule -- guards pass it as ``before_retry``.

``python -m repro.runtime.chaos --matrix`` runs the chaos matrix: one
scenario per fault class, each asserting the fault is *detected* and
then either *recovered* (bit-identical to the fault-free run) or
*reported* (structured machine-readable failure report) -- the runtime
mirror of ``python -m repro.analysis.verify --matrix``.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import os
import signal
import sys
import tempfile
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_lib

from .guard import (Backoff, GuardedCall, GuardExhausted, TransientFault,
                    spot_check, validate_finite)

#: every fault class the harness can inject, by layer.
PALLAS_FAULTS = ("corrupt_table", "poison_tile")
COLLECTIVE_FAULTS = ("drop_halo", "delay_halo")
HOST_FAULTS = ("transient_error", "fatal_error", "poison_result",
               "sigterm")
FILE_FAULTS = ("torn_checkpoint", "corrupt_tune_cache")
ALL_FAULTS = PALLAS_FAULTS + COLLECTIVE_FAULTS + HOST_FAULTS + FILE_FAULTS

#: the reserved site names of the non-host layers.
PALLAS_SITE = "pallas"
PPERMUTE_SITE = "ppermute"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    kind:  one of :data:`ALL_FAULTS`.
    site:  call-site name -- :data:`PALLAS_SITE` (per instrumented
           emission), :data:`PPERMUTE_SITE` (per traced ppermute), or
           any host site a caller wraps (``"serve.decode"``, ...).
    index: 0-based call index at that site.
    mode:  kind-specific variant (poison: nan|inf|bitflip;
           transient_error: ""|jax).
    step:  grid-step selector for Pallas faults (which step of the
           launch is corrupted).
    rung:  when set, the fault only fires while the caller reports
           this degradation-ladder rung (persistent rung-0 failures
           that vanish after step-down).
    """

    kind: str
    site: str
    index: int
    mode: str = ""
    step: int = 0
    rung: Optional[int] = None

    def __post_init__(self):
        if self.kind not in ALL_FAULTS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {ALL_FAULTS}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class FaultPlan:
    """A replayable per-call-site fault schedule.

    Either list the faults explicitly or derive the whole schedule
    from one seed (:meth:`from_seed`); ``to_json``/``from_json`` make
    a plan portable into a bug report.
    """

    def __init__(self, seed: int, faults: Sequence[FaultSpec] = ()):
        self.seed = int(seed)
        self.faults = list(faults)

    @classmethod
    def from_seed(cls, seed: int, *, sites: Sequence[str],
                  kinds: Sequence[str] = ("transient_error",
                                          "poison_result"),
                  n_faults: int = 4, horizon: int = 16,
                  modes: Sequence[str] = ("", "jax")) -> "FaultPlan":
        """Derive a randomized-but-deterministic schedule: ``n_faults``
        faults drawn over ``sites x kinds x [0, horizon)`` from a
        generator seeded with ``seed`` alone."""
        rng = np.random.default_rng(seed)
        seen, faults = set(), []
        for _ in range(n_faults * 4):
            if len(faults) >= n_faults:
                break
            site = sites[int(rng.integers(len(sites)))]
            kind = kinds[int(rng.integers(len(kinds)))]
            index = int(rng.integers(horizon))
            if (site, index) in seen:
                continue
            seen.add((site, index))
            mode = ""
            if kind == "transient_error":
                mode = modes[int(rng.integers(len(modes)))]
            elif kind == "poison_tile":
                mode = ("nan", "inf", "bitflip")[int(rng.integers(3))]
            faults.append(FaultSpec(kind=kind, site=site, index=index,
                                    mode=mode))
        return cls(seed, faults)

    def for_call(self, site: str, index: int,
                 rung: Optional[int] = None) -> List[FaultSpec]:
        out = []
        for f in self.faults:
            if f.site != site or f.index != index:
                continue
            if f.rung is not None and rung is not None and f.rung != rung:
                continue
            out.append(f)
        return out

    def sites(self) -> set:
        return {f.site for f in self.faults}

    @property
    def has_traced_faults(self) -> bool:
        """True when the plan injects trace-baked (Pallas/collective)
        faults, i.e. retries must re-trace (``injector.refresh``)."""
        return any(f.site in (PALLAS_SITE, PPERMUTE_SITE)
                   for f in self.faults)

    def to_json(self) -> dict:
        return {"seed": self.seed,
                "faults": [f.to_json() for f in self.faults]}

    @classmethod
    def from_json(cls, d: dict) -> "FaultPlan":
        return cls(d["seed"], [FaultSpec(**f) for f in d["faults"]])


# ---------------------------------------------------------------------------
# injection
# ---------------------------------------------------------------------------

def _step_pred(plan, coords, step: int):
    """Predicate selecting one linear grid step of a launch (batch
    grid axes, when present, pinned to 0)."""
    if not coords.grid_ids:
        return None
    try:
        p = plan.linear_step(coords.grid_ids) == step
    except Exception:
        p = coords.grid_ids[-1] == step
    for g in coords.batch:
        p = p & (g == 0)
    return p


def _poison_value(val, mode: str):
    if not jnp.issubdtype(val.dtype, jnp.floating):
        return -val - 1
    if mode == "inf":
        return jnp.full_like(val, jnp.inf)
    if mode == "bitflip":
        # finite garbage: survives the NaN screen, only a spot check
        # (or the sanitizer) catches it
        return -val + jnp.asarray(1.0, val.dtype)
    return jnp.full_like(val, jnp.nan)


class ChaosInjector:
    """Realize a :class:`FaultPlan` against a live program.

    Use as a context manager around the workload: entry installs the
    emit hook (Pallas-layer faults), shims ``jax.lax.ppermute``
    (collective faults), and clears jit caches so instrumented
    launches re-trace; exit restores everything.  Host-layer faults
    need no context -- ``wrap(site, fn)`` consults the plan on every
    call.

    Call counters live on the injector and persist across traces: a
    retried launch consumes the *next* index, so a fault scheduled at
    one index fires exactly once.  ``events`` is the evidence trail
    (what fired, where, when).
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.counters: collections.Counter = collections.Counter()
        self.events: List[dict] = []
        self._prev_hook = None
        self._orig_ppermute = None
        self._active = False

    # -- lifecycle -----------------------------------------------------------

    def __enter__(self) -> "ChaosInjector":
        self._prev_hook = backend_lib.set_emit_hook(self)
        self._orig_ppermute = jax.lax.ppermute
        jax.lax.ppermute = self._ppermute
        self._active = True
        jax.clear_caches()
        return self

    def __exit__(self, *exc):
        self._active = False
        backend_lib.set_emit_hook(self._prev_hook)
        jax.lax.ppermute = self._orig_ppermute
        jax.clear_caches()
        return False

    def refresh(self) -> None:
        """Drop cached executables so the next call re-traces against
        the live fault schedule (guards pass this as
        ``before_retry``)."""
        jax.clear_caches()

    # -- bookkeeping ---------------------------------------------------------

    def _count(self, site: str) -> int:
        idx = self.counters[site]
        self.counters[site] += 1
        return idx

    def _event(self, fault: FaultSpec, site: str, index: int,
               note: str = "") -> None:
        self.events.append({"kind": fault.kind, "site": site,
                            "index": index, "mode": fault.mode,
                            "note": note, "time": time.time()})

    # -- emit-hook protocol (Pallas layer) -----------------------------------

    def instrument(self, record, kernel, in_specs, out_specs):
        from repro.core.shard import ShardedPlan
        idx = self._count(PALLAS_SITE)
        faults = [f for f in self.plan.for_call(PALLAS_SITE, idx)
                  if f.kind in PALLAS_FAULTS]
        if not faults or isinstance(record.plan, ShardedPlan):
            # sharded launches trace once for all devices; a single
            # injection stream cannot be attributed to one device, so
            # Pallas faults are unsharded-only (collective faults
            # cover the sharded paths)
            return kernel, in_specs, out_specs
        from repro.core.plan import BlockCoords
        n_in = len(in_specs)
        for f in faults:
            self._event(f, PALLAS_SITE, idx, "instrumented")

        def kernel_chaos(coords, *refs):
            c = coords
            pred = _step_pred(record.plan, coords, faults[0].step)
            for f in faults:
                if f.kind == "corrupt_table" and pred is not None:
                    # what a corrupt LUT/neighbour row does: this
                    # step's block lands in the wrong place, i.e. its
                    # write never reaches the right tile -- model it
                    # by knocking the step's membership predicate out
                    # (a shifted-coords emulation is no good: the
                    # lambda map's self-similarity makes many wrong
                    # blocks mask-identical)
                    valid = ~pred if c.valid is None else c.valid & ~pred
                    c = BlockCoords(c.batch, c.bx, c.by, valid,
                                    c.first_step, c.grid_ids, c.refs)
            kernel(c, *refs)
            for f in faults:
                if f.kind == "poison_tile" and pred is not None \
                        and n_in < len(refs):
                    out_ref = refs[n_in]

                    def _poison(out_ref=out_ref, mode=f.mode):
                        out_ref[...] = _poison_value(out_ref[...], mode)

                    from jax.experimental import pallas as pl
                    pl.when(pred)(_poison)

        return kernel_chaos, in_specs, out_specs

    def wrap_call(self, record, fn):
        return fn

    # -- collective shim -----------------------------------------------------

    def _ppermute(self, x, axis_name, perm):
        idx = self._count(PPERMUTE_SITE)
        out = self._orig_ppermute(x, axis_name, perm)
        for f in self.plan.for_call(PPERMUTE_SITE, idx):
            if f.kind == "drop_halo":
                self._event(f, PPERMUTE_SITE, idx, "round dropped")
                out = jax.tree.map(jnp.zeros_like, out)
            elif f.kind == "delay_halo":
                self._event(f, PPERMUTE_SITE, idx, "round delayed")
                out = self._orig_ppermute(out, axis_name, perm)
        return out

    # -- host layer ----------------------------------------------------------

    def wrap(self, site: str, fn: Callable,
             rung: Optional[Callable[[], int]] = None) -> Callable:
        """Wrap a step function so scheduled host faults fire at their
        call index.  ``rung`` (a zero-arg callable) reports the current
        degradation-ladder level for rung-conditioned faults."""

        def call(*args, **kwargs):
            idx = self._count(site)
            r = rung() if rung is not None else None
            faults = self.plan.for_call(site, idx, rung=r)
            poison = None
            for f in faults:
                self._event(f, site, idx)
                if f.kind == "transient_error":
                    if f.mode == "jax":
                        raise _injected_jax_error(site, idx)
                    raise TransientFault(
                        f"chaos: injected transient fault at "
                        f"{site}#{idx}")
                if f.kind == "fatal_error":
                    raise ValueError(
                        f"chaos: injected fatal (shape-family) error "
                        f"at {site}#{idx}")
                if f.kind == "sigterm":
                    os.kill(os.getpid(), signal.SIGTERM)
                if f.kind == "poison_result":
                    poison = f
            out = fn(*args, **kwargs)
            if poison is not None:
                out = jax.tree.map(
                    lambda x: jnp.where(
                        jnp.ones_like(x) > 0, jnp.nan, x).astype(x.dtype)
                    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
                    else x, out)
            return out

        return call


def _injected_jax_error(site: str, idx: int) -> Exception:
    """A *real* JaxRuntimeError (UNAVAILABLE family), so the guard's
    classifier is exercised against the genuine type."""
    try:
        from jax.errors import JaxRuntimeError
        return JaxRuntimeError(
            f"UNAVAILABLE: chaos: injected device loss at {site}#{idx}")
    except Exception:  # pragma: no cover
        return TransientFault(f"chaos: injected at {site}#{idx}")


# ---------------------------------------------------------------------------
# file-layer faults
# ---------------------------------------------------------------------------

def tear_checkpoint(directory: str, step: Optional[int] = None,
                    mode: str = "truncate") -> str:
    """Simulate a preemption mid-save: truncate the (latest) step's
    ``params.npz`` mid-file (``mode="truncate"``) or delete its
    ``meta.json`` (``mode="meta"``), and leave a torn ``.tmp``
    directory behind -- the exact debris an interrupted
    :meth:`~repro.checkpoint.manager.CheckpointManager.save` leaves.
    Returns the path of the torn step directory."""
    names = sorted(n for n in os.listdir(directory)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    if step is not None:
        names = [n for n in names if int(n.split("_")[1]) == step]
    if not names:
        raise FileNotFoundError(f"no checkpoints to tear in {directory}")
    victim = os.path.join(directory, names[-1])
    npz = os.path.join(victim, "params.npz")
    if mode == "meta":
        os.unlink(os.path.join(victim, "meta.json"))
    else:
        size = os.path.getsize(npz)
        with open(npz, "rb") as f:
            head = f.read(max(1, size // 2))
        with open(npz, "wb") as f:
            f.write(head)
    # the half-written tmp dir of the save that never finished
    torn_tmp = victim + ".tmp"
    os.makedirs(torn_tmp, exist_ok=True)
    with open(os.path.join(torn_tmp, "params.npz"), "wb") as f:
        f.write(b"not a zipfile")
    return victim


def corrupt_tune_cache(path: str, kernel: str, params: dict) -> str:
    """Plant a malformed winner entry under the exact lookup key the
    kernels' ``grid_mode="auto"`` resolve uses: structurally valid
    JSON whose config is garbage (unknown lowering, non-integer fuse).
    Returns the corrupted key."""
    from repro.core.tune import TuneCache, _with_backend
    key = TuneCache.key(kernel, _with_backend(dict(params)))
    data = {}
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        pass
    data[key] = {"config": {"lowering": "lambda-overflow",
                            "storage": "holographic",
                            "fuse": "many", "coarsen": -3},
                 "us": 0.0, "tuned_at": time.time()}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".chaos.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(data, f)
    os.replace(tmp, path)
    return key


# ---------------------------------------------------------------------------
# the chaos matrix: one scenario per fault class
# ---------------------------------------------------------------------------

def _result(fault: str, status: str, **detail) -> dict:
    return {"fault": fault, "status": status, **detail}


def _no_backoff() -> Backoff:
    return Backoff(base_s=0.0, jitter=0.0)


def scenario_poison_tile(seed: int, smoke: bool) -> dict:
    """NaN-poisoned output tile -> NaN screen -> re-trace -> recover."""
    from repro.kernels.sierpinski_write import sierpinski_write
    n, block = (16, 4) if smoke else (32, 8)
    m = jnp.zeros((n, n), jnp.float32)

    def run():
        return sierpinski_write(m, 1.0, block=block,
                                grid_mode="closed_form", coarsen=1,
                                num_stages=1)

    clean = np.asarray(run())
    plan = FaultPlan(seed, [FaultSpec("poison_tile", PALLAS_SITE, 0,
                                      mode="nan")])
    with ChaosInjector(plan) as chaos:
        guard = GuardedCall(
            run, "write", retries=2, backoff=_no_backoff(),
            validators=[lambda o: validate_finite(o, "write output")],
            before_retry=chaos.refresh)
        out = np.asarray(guard())
    detected = any(e.kind == "validation" for e in guard.events)
    recovered = bool(np.array_equal(out, clean))
    if not chaos.events:
        return _result("poison_tile", "skipped",
                       reason="emit hook inactive (compiled backend)")
    status = "recovered" if (detected and recovered) else "failed"
    return _result("poison_tile", status, detected=detected,
                   bit_identical=recovered,
                   guard_events=[e.kind for e in guard.events])


def scenario_corrupt_table(seed: int, smoke: bool) -> dict:
    """Corrupt LUT row (wrong decoded block) -> spot check -> recover."""
    from repro.kernels.sierpinski_write import sierpinski_write
    n, block = (16, 4) if smoke else (32, 8)
    m = jnp.zeros((n, n), jnp.float32)

    def run():
        return sierpinski_write(m, 1.0, block=block,
                                grid_mode="prefetch_lut", coarsen=1,
                                num_stages=1)

    clean = np.asarray(run())
    plan = FaultPlan(seed, [FaultSpec("corrupt_table", PALLAS_SITE, 0,
                                      step=1)])
    with ChaosInjector(plan) as chaos:
        guard = GuardedCall(
            run, "write", retries=2, backoff=_no_backoff(),
            validators=[spot_check(clean, "lambda-plan spot check")],
            before_retry=chaos.refresh)
        out = np.asarray(guard())
    detected = any(e.kind == "validation" for e in guard.events)
    recovered = bool(np.array_equal(out, clean))
    if not chaos.events:
        return _result("corrupt_table", "skipped",
                       reason="emit hook inactive (compiled backend)")
    status = "recovered" if (detected and recovered) else "failed"
    return _result("corrupt_table", status, detected=detected,
                   bit_identical=recovered)


def scenario_drop_halo(seed: int, smoke: bool) -> dict:
    """A dropped halo ppermute round on an emulated mesh -> spot check
    -> re-trace -> recover."""
    if jax.device_count() < 2:
        return _result("drop_halo", "skipped",
                       reason=f"needs >= 2 devices, have "
                              f"{jax.device_count()} (set XLA_FLAGS="
                              f"--xla_force_host_platform_device_count)")
    from repro.core.compact import CompactLayout
    from repro.core.domain import make_fractal_domain
    from repro.kernels.sierpinski_ca import ca_run
    n, block, steps = 32, 8, 4
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    dom = make_fractal_domain("sierpinski-gasket", n)
    lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                            n // block))
    y, x = np.mgrid[0:n, 0:n]
    mask = np.asarray(dom.cell_member(x, y, n))
    rng = np.random.default_rng(0)
    emb = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                      .astype(np.float32))
    state = lay.pack(emb, block)
    buf = jnp.zeros_like(state)

    def run():
        return ca_run(state, buf, steps, fuse=2, rule="parity",
                      block=block, grid_mode="closed_form",
                      storage="compact", n=n, coarsen=1, num_stages=1,
                      donate=False, mesh=mesh, shard_axis="data")

    clean = np.asarray(run())
    plan = FaultPlan(seed, [FaultSpec("drop_halo", PPERMUTE_SITE, 0)])
    with ChaosInjector(plan) as chaos:
        guard = GuardedCall(
            run, "ca_sharded", retries=2, backoff=_no_backoff(),
            validators=[spot_check(clean, "halo spot check")],
            before_retry=chaos.refresh)
        out = np.asarray(guard())
    if not chaos.events:
        return _result("drop_halo", "skipped",
                       reason="no ppermute round executed")
    detected = any(e.kind == "validation" for e in guard.events)
    recovered = bool(np.array_equal(out, clean))
    status = "recovered" if (detected and recovered) else "failed"
    return _result("drop_halo", status, detected=detected,
                   bit_identical=recovered)


def _tiny_server(scfg=None, chaos=None, decode_kernel: str = ""):
    from repro.configs import get_config
    from repro.launch.serve import ServeConfig, Server
    from repro.models import init
    cfg = get_config("quickstart", smoke=True)
    if decode_kernel:
        cfg = cfg.replace(attn_decode_kernel=decode_kernel)
    params = init(jax.random.PRNGKey(0), cfg)
    scfg = scfg or ServeConfig(max_len=24, temperature=0.7, seed=11,
                               retries=3, backoff_base_s=0.0)
    return cfg, params, Server(cfg, params, scfg, chaos=chaos)


def scenario_transient_runtime(seed: int, smoke: bool) -> dict:
    """Injected JaxRuntimeError mid-decode -> classified transient ->
    retried -> token stream bit-identical to the fault-free run."""
    from repro.launch.serve import Server
    max_new = 4 if smoke else 6
    cfg, params, server = _tiny_server()
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8))
    ref = server.generate(prompts, max_new=max_new)

    plan = FaultPlan(seed, [
        FaultSpec("transient_error", "serve.decode", 1, mode="jax"),
        FaultSpec("transient_error", "serve.prefill", 0)])
    chaos = ChaosInjector(plan)
    faulty = Server(cfg, params, server.scfg, chaos=chaos)
    out = faulty.generate(prompts, max_new=max_new)
    detected = len(chaos.events) >= 2
    recovered = bool(np.array_equal(out, ref))
    status = "recovered" if (detected and recovered) else "failed"
    return _result("transient_error", status, detected=detected,
                   bit_identical=recovered,
                   injected=len(chaos.events))


def scenario_torn_checkpoint(seed: int, smoke: bool) -> dict:
    """Torn checkpoint dir -> restore falls back to the previous good
    step; an explicitly requested torn step raises (reported)."""
    from repro.checkpoint.manager import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        p1 = {"w": np.arange(8, dtype=np.float32)}
        p2 = {"w": np.arange(8, dtype=np.float32) * 2}
        mgr.save(1, p1)
        mgr.save(2, p2)
        tear_checkpoint(d)
        step, params, _, meta = mgr.restore(
            None, {"w": np.zeros(8, np.float32)})
        fell_back = step == 1 and np.array_equal(params["w"], p1["w"])
        skipped = meta.get("skipped_torn_steps") == [2]
        reported = False
        try:
            mgr.restore(2, {"w": np.zeros(8, np.float32)})
        except Exception:
            reported = True
        # a later save must clear the torn .tmp debris
        mgr.save(3, p2)
        debris = [n for n in os.listdir(d) if n.endswith(".tmp")]
    ok = fell_back and skipped and reported and not debris
    return _result("torn_checkpoint", "recovered" if ok else "failed",
                   fell_back=fell_back, skipped_recorded=skipped,
                   explicit_raises=reported, tmp_cleaned=not debris)


def scenario_corrupt_tune_cache(seed: int, smoke: bool) -> dict:
    """Malformed tune-cache winner -> lookup rejects it, kernel runs on
    defaults instead of crashing on garbage knobs."""
    from repro.core import tune
    from repro.kernels.sierpinski_ca import ca_run
    n, block = 16, 4
    params = tune.target_params(
        {"fractal": "sierpinski-gasket", "n": n, "block": block,
         "rule": "parity"}, None)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tune.json")
        old = os.environ.get(tune.CACHE_ENV)
        os.environ[tune.CACHE_ENV] = path
        try:
            corrupt_tune_cache(path, "ca", params)
            got = tune.best("ca", params,
                            default={"lowering": "closed_form"})
            rejected = got == {"lowering": "closed_form"}
            state = jnp.zeros((n, n), jnp.float32)
            out = ca_run(state, jnp.zeros_like(state), 1, fuse="auto",
                         block=block, grid_mode="auto", coarsen="auto",
                         num_stages=1, donate=False)
            ran = bool(np.isfinite(np.asarray(out)).all())
        finally:
            if old is None:
                os.environ.pop(tune.CACHE_ENV, None)
            else:
                os.environ[tune.CACHE_ENV] = old
    ok = rejected and ran
    return _result("corrupt_tune_cache",
                   "recovered" if ok else "failed",
                   entry_rejected=rejected, kernel_ran=ran)


def scenario_sigterm_mid_decode(seed: int, smoke: bool) -> dict:
    """SIGTERM mid-decode -> drain + decode-state checkpoint -> a new
    server elastic-restores and resumes to a bit-identical stream."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.distributed.elastic import elastic_restore
    from repro.launch.serve import ServeConfig, Server
    from repro.models import abstract_init
    max_new = 6 if smoke else 8
    with tempfile.TemporaryDirectory() as d:
        # fault-free reference run (no decode checkpointing: the torn
        # run below must resume from ITS OWN checkpoints)
        cfg, params, server = _tiny_server(
            ServeConfig(max_len=24, temperature=0.7, seed=5,
                        retries=3, backoff_base_s=0.0))
        prompts = np.random.default_rng(1).integers(
            0, cfg.vocab_size, (2, 8))
        ref = server.generate(prompts, max_new=max_new)

        pmgr = CheckpointManager(os.path.join(d, "params"), keep=1)
        pmgr.save(0, params)

        scfg = ServeConfig(max_len=24, temperature=0.7, seed=5,
                           retries=3, backoff_base_s=0.0,
                           ckpt_dir=os.path.join(d, "decode"),
                           ckpt_every=1)
        plan = FaultPlan(seed, [FaultSpec("sigterm", "serve.decode", 2)])
        chaos = ChaosInjector(plan)
        faulty = Server(cfg, params, scfg, chaos=chaos)
        partial = faulty.generate(prompts, max_new=max_new)
        drained = (faulty.state.value == "draining"
                   and partial.shape[1] < max_new)

        # "restart": restore params onto whatever mesh survives and
        # resume from the decode-state checkpoint
        mesh, _, params2, _ = elastic_restore(
            pmgr, abstract_init(cfg), cfg)
        successor = Server(cfg, params2, scfg, mesh=mesh)
        out = successor.resume()
        recovered = bool(np.array_equal(out, ref))
    status = "recovered" if (drained and recovered) else "failed"
    return _result("sigterm", status, drained=drained,
                   bit_identical=recovered,
                   resumed_tokens=int(out.shape[1]))


def scenario_fatal_report(seed: int, smoke: bool) -> dict:
    """A fatal (shape-family) error must NOT be retried: one attempt,
    classified fatal, structured report emitted."""

    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("chaos: injected fatal (shape mismatch)")

    guard = GuardedCall(bad, "train_step", retries=3,
                        backoff=_no_backoff())
    report = None
    try:
        guard()
    except GuardExhausted as e:
        report = e.report
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "failure_report.json")
        written = False
        if report is not None:
            report.write(path)
            with open(path) as f:
                written = json.load(f)["classification"] == "fatal"
    ok = (report is not None and report.classification == "fatal"
          and calls["n"] == 1 and written)
    return _result("fatal_error", "reported" if ok else "failed",
                   attempts=calls["n"],
                   classification=getattr(report, "classification", None))


def scenario_serve_randomized(seed: int, smoke: bool) -> dict:
    """The serve smoke: randomized transient/poison injection across
    prefill+decode; generation must complete with zero corrupted
    outputs, bit-identical to the fault-free run."""
    from repro.launch.serve import Server
    max_new = 6 if smoke else 10
    cfg, params, server = _tiny_server()
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab_size, (2, 8))
    ref = server.generate(prompts, max_new=max_new)

    plan = FaultPlan.from_seed(
        seed, sites=("serve.decode", "serve.prefill"),
        kinds=("transient_error", "poison_result"),
        n_faults=3 if smoke else 4, horizon=max_new)
    chaos = ChaosInjector(plan)
    faulty = Server(cfg, params, server.scfg, chaos=chaos)
    out = faulty.generate(prompts, max_new=max_new)
    finite = bool(np.all(out >= 0))
    recovered = bool(np.array_equal(out, ref))
    status = "recovered" if (recovered and finite) else "failed"
    return _result("serve_randomized", status, bit_identical=recovered,
                   injected=len(chaos.events),
                   plan=plan.to_json())


MATRIX = (
    scenario_poison_tile,
    scenario_corrupt_table,
    scenario_drop_halo,
    scenario_transient_runtime,
    scenario_torn_checkpoint,
    scenario_corrupt_tune_cache,
    scenario_sigterm_mid_decode,
    scenario_fatal_report,
    scenario_serve_randomized,
)


def run_matrix(seed: int = 0, smoke: bool = False,
               only: Optional[Sequence[str]] = None,
               verbose: bool = True) -> List[dict]:
    results = []
    for fn in MATRIX:
        name = fn.__name__.replace("scenario_", "")
        if only and name not in only:
            continue
        try:
            r = fn(seed, smoke)
        except Exception as e:  # noqa: BLE001 - matrix must report
            r = _result(name, "failed", error=f"{type(e).__name__}: {e}")
        results.append(r)
        if verbose:
            extra = "" if r["status"] != "skipped" else \
                f" ({r.get('reason', '')})"
            print(f"  chaos {r['fault']}: {r['status']}{extra}")
    return results


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.chaos",
        description=__doc__.splitlines()[0])
    ap.add_argument("--matrix", action="store_true",
                    help="run the full fault-injection matrix")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="serve smoke under randomized injection only")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced problem sizes (CI gate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default="",
                    help="comma-separated scenario subset")
    ap.add_argument("--out", default=None,
                    help="write the JSON chaos report here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    if not (args.matrix or args.serve_smoke):
        ap.error("nothing to do: pass --matrix or --serve-smoke")
    only = tuple(s for s in args.only.split(",") if s) or None
    if args.serve_smoke and not args.matrix:
        only = ("serve_randomized",)

    results = run_matrix(seed=args.seed, smoke=args.smoke, only=only,
                         verbose=not args.quiet)
    n_failed = sum(r["status"] == "failed" for r in results)
    n_skipped = sum(r["status"] == "skipped" for r in results)
    report = {
        "ok": n_failed == 0,
        "seed": args.seed,
        "backend": backend_lib.resolve(None).name,
        "devices": jax.device_count(),
        "num_scenarios": len(results),
        "num_failed": n_failed,
        "num_skipped": n_skipped,
        "results": results,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    print(f"chaos matrix: {len(results)} scenarios, "
          f"{n_failed} failed, {n_skipped} skipped "
          f"(backend {report['backend']}, {report['devices']} devices)")
    return 0 if n_failed == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
