"""Fault-tolerant checkpointing: atomic writes, keep-k GC, exact resume
(params + optimizer + data-pipeline state + step), and **elastic
restore** -- a checkpoint saved on one mesh can be loaded onto another
(parameters are stored unsharded with their tree paths; the loader
re-applies whatever sharding the new mesh prescribes).

Format: one ``.npz`` per step directory with flattened ``path -> array``
plus a JSON metadata sidecar.  Writes go to ``<dir>.tmp`` then
``os.replace`` (atomic on POSIX), so a preemption mid-save never
corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    paths = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(template):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        paths.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(_tree_def(template), paths)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, params, opt_state=None, data_state=None,
             extra: Optional[Dict[str, Any]] = None):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        if opt_state is not None:
            np.savez(os.path.join(tmp, "opt.npz"), **_flatten(opt_state))
        meta = {"step": step, "time": time.time(),
                "data_state": data_state or {}, "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # torn .tmp dirs are debris from a save that never published
        # (preemption mid-write); any still present belong to no
        # in-flight save and would shadow disk forever
        for name in os.listdir(self.dir):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def read_meta(self, step: Optional[int] = None) -> Dict:
        """The JSON metadata sidecar of ``step`` (default: latest) --
        readable without knowing the parameter tree, which is how the
        serving layer discovers the shapes of a decode-state checkpoint
        before restoring it."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with open(os.path.join(self._step_dir(step), "meta.json")) as f:
            return json.load(f)

    def _restore_one(self, step: int, params_template, opt_template):
        d = self._step_dir(step)
        with np.load(os.path.join(d, "params.npz")) as z:
            params = _unflatten_like(params_template, dict(z))
        opt_state = None
        if opt_template is not None and os.path.exists(
                os.path.join(d, "opt.npz")):
            with np.load(os.path.join(d, "opt.npz")) as z:
                opt_state = _unflatten_like(opt_template, dict(z))
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return params, opt_state, meta

    def restore(self, step: Optional[int], params_template,
                opt_template=None, shardings=None
                ) -> Tuple[int, Any, Any, Dict]:
        """Elastic restore: ``shardings`` (optional pytree of NamedSharding
        for the *new* mesh) re-lays-out each leaf with jax.device_put.

        A torn checkpoint (truncated archive / missing sidecar from a
        crash mid-write) is skipped when the step was auto-selected:
        the restore falls back to the next older readable step and
        records the skipped steps under ``meta["skipped_torn_steps"]``.
        An explicitly requested step is never substituted -- a torn one
        raises."""
        explicit = step is not None
        candidates = [step] if explicit else list(reversed(self.all_steps()))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        skipped = []
        for s in candidates:
            try:
                params, opt_state, meta = self._restore_one(
                    s, params_template, opt_template)
            except (OSError, ValueError, KeyError, EOFError,
                    zipfile.BadZipFile, zlib.error) as e:
                if explicit:
                    raise
                skipped.append((s, f"{type(e).__name__}: {e}"))
                continue
            if shardings is not None:
                params = jax.tree.map(
                    lambda x, sh: jax.device_put(x, sh), params, shardings)
            if skipped:
                meta = dict(meta)
                meta["skipped_torn_steps"] = [t for t, _ in skipped]
                meta["skipped_torn_errors"] = [err for _, err in skipped]
            return s, params, opt_state, meta
        raise FileNotFoundError(
            f"no readable checkpoints in {self.dir}: all "
            f"{len(skipped)} candidates torn "
            f"({'; '.join(err for _, err in skipped)})")
