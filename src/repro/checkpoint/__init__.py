from .manager import CheckpointManager
