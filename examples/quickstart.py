"""Quickstart: the paper's lambda(w) map in 60 seconds.

Renders the embedded Sierpinski gasket three ways and checks they agree:
 1. the membership bit test (bounding-box view),
 2. the block-space map lambda(w) (the paper's contribution),
 3. the Pallas kernel (compact grid, interpret mode on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.core.domain import SierpinskiDomain
from repro.kernels import ops


def ascii_render(grid, max_n=64):
    n = grid.shape[0]
    step = max(1, n // max_n)
    for y in range(0, n, step):
        print("".join("#" if grid[y, x] else "." for x in
                      range(0, n, step)))


def main():
    r = 6
    n = 2 ** r
    print(f"Sierpinski gasket, n={n} (scale level r={r})")
    print(f"cells: {F.gasket_volume(n)} = n^H with H={F.HAUSDORFF:.4f}")
    ox, oy = F.orthotope_shape(r)
    print(f"packs into a {ox} x {oy} orthotope (Lemma 2)\n")

    # 1. bounding-box membership
    bb = F.membership_grid(n)

    # 2. lambda map: paint cells enumerated by the compact map
    lam = np.zeros((n, n), dtype=bool)
    i = np.arange(3 ** r)
    lx, ly = F.lambda_map_linear(i, r)
    lam[np.asarray(ly), np.asarray(lx)] = True
    assert np.array_equal(bb, lam), "lambda image != membership set"

    # 3. Pallas kernel (compact grid over 3^r_b blocks)
    m = jnp.zeros((n, n), jnp.float32)
    out = np.asarray(ops.sierpinski_write(m, 1.0, block=8)) > 0
    assert np.array_equal(bb, out), "kernel != membership set"

    ascii_render(bb)
    d = SierpinskiDomain(n)
    print(f"\nparallel-space efficiency vs bounding box: "
          f"{d.space_efficiency():.4f} "
          f"({d.num_blocks} of {n * n} blocks)")
    print("all three constructions agree ✓")


if __name__ == "__main__":
    main()
