"""Serving example: batched generation with prefill + KV-cache decode,
optionally restoring the checkpoint written by examples/train_lm.py.

Run:  PYTHONPATH=src python examples/serve_lm.py [--from-ckpt DIR]
"""
import argparse

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.launch.serve import ServeConfig, Server, throughput_report
from repro.models import abstract_init, init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--from-ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_config("quickstart", smoke=args.smoke)
    if args.from_ckpt:
        mgr = CheckpointManager(args.from_ckpt)
        _, params, _, _ = mgr.restore(None, abstract_init(cfg))
        print(f"restored step {mgr.latest_step()} from {args.from_ckpt}")
    else:
        params = init(jax.random.PRNGKey(0), cfg)
        print("serving randomly-initialized weights (demo)")

    server = Server(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.max_new,
        temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len))
    out = server.generate(prompts, max_new=args.max_new)
    for i, row in enumerate(out[:2]):
        print(f"request {i}: {row.tolist()}")
    print(throughput_report(server, args.batch, args.prompt_len,
                            args.max_new))


if __name__ == "__main__":
    main()
