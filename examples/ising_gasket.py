"""Ising-model Monte Carlo on the Sierpinski gasket -- the spin-lattice
application from the paper's introduction (Gefen et al., phase
transitions on fractals).

Checkerboard Metropolis sweeps over the embedded gasket: neighbour sums
come from the block-space diffusion kernel machinery; the compact
lambda enumeration gives the n^H active sites.  The gasket famously has
NO finite-temperature phase transition (H < 2): magnetization decays at
every T > 0, which the demo shows qualitatively.

Run:  PYTHONPATH=src python examples/ising_gasket.py [--sweeps 50]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F


def neighbor_sum(s):
    up = jnp.roll(s, 1, 0).at[0, :].set(0)
    down = jnp.roll(s, -1, 0).at[-1, :].set(0)
    left = jnp.roll(s, 1, 1).at[:, 0].set(0)
    right = jnp.roll(s, -1, 1).at[:, -1].set(0)
    return up + down + left + right


def metropolis_sweep(key, spins, mask, beta):
    """Two checkerboard half-sweeps (parallel Metropolis)."""
    n = spins.shape[0]
    yy, xx = jnp.mgrid[0:n, 0:n]
    for parity in (0, 1):
        key, sub = jax.random.split(key)
        nb = neighbor_sum(spins)
        dE = 2.0 * spins * nb
        accept = (jax.random.uniform(sub, spins.shape)
                  < jnp.exp(-beta * dE))
        flip = accept & mask & (((xx + yy) % 2) == parity)
        spins = jnp.where(flip, -spins, spins)
    return key, spins


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=6)
    ap.add_argument("--sweeps", type=int, default=50)
    ap.add_argument("--betas", default="1.0,0.5,0.2")
    args = ap.parse_args()
    n = 2 ** args.r
    mask = jnp.asarray(F.membership_grid(n))
    n_sites = F.gasket_volume(n)
    print(f"gasket n={n}, sites={n_sites} (n^{F.HAUSDORFF:.3f})")

    sweep = jax.jit(metropolis_sweep, static_argnums=())
    for beta in [float(b) for b in args.betas.split(",")]:
        key = jax.random.PRNGKey(0)
        spins = jnp.where(mask, 1.0, 0.0)   # cold start, all up
        for _ in range(args.sweeps):
            key, spins = sweep(key, spins, mask, beta)
        mag = float(jnp.abs(jnp.sum(spins)) / n_sites)
        energy = float(-jnp.sum(spins * neighbor_sum(spins)) / 2 / n_sites)
        print(f"beta={beta:4.2f}:  |m| = {mag:.4f}   E/site = {energy:.4f}")
    print("note: magnetization decays for every beta -- the gasket has no "
          "finite-T transition (H < 2)")


if __name__ == "__main__":
    main()
