"""Ising-model Monte Carlo on the Sierpinski gasket -- the spin-lattice
application from the paper's introduction (Gefen et al., phase
transitions on fractals).

Checkerboard Metropolis sweeps over the gasket, **orthotope-resident**:
spins live in the compact linear-lambda layout (exactly n^H = 3^r
sites), neighbour sums are gathers through the host-built
lambda^-1-resolved cell neighbour tables, and the checkerboard parity
comes from the embedded coordinates of each packed site.  No n x n
array exists at any point, so r is bounded by 3^r sites -- not by the
2^(2r) embedded grid.  The gasket famously has NO finite-temperature
phase transition (H < 2): magnetization decays at every T > 0, which
the demo shows qualitatively.

Run:  PYTHONPATH=src python examples/ising_gasket.py [--sweeps 50]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.core.compact import cell_neighbor_tables


def packed_neighbor_sum(s, tables):
    """Sum of the 4 embedded neighbours of each packed site (ghost
    slot 3^r reads the appended 0)."""
    z = jnp.concatenate([s, jnp.zeros((1,), s.dtype)])
    return z[tables[0]] + z[tables[1]] + z[tables[2]] + z[tables[3]]


def metropolis_sweep(key, spins, parity_bits, tables, beta):
    """Two checkerboard half-sweeps (parallel Metropolis) on the packed
    spin vector."""
    for parity in (0, 1):
        key, sub = jax.random.split(key)
        nb = packed_neighbor_sum(spins, tables)
        dE = 2.0 * spins * nb
        accept = (jax.random.uniform(sub, spins.shape)
                  < jnp.exp(-beta * dE))
        flip = accept & (parity_bits == parity)
        spins = jnp.where(flip, -spins, spins)
    return key, spins


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=6)
    ap.add_argument("--sweeps", type=int, default=50)
    ap.add_argument("--betas", default="1.0,0.5,0.2")
    args = ap.parse_args()
    r = args.r
    n = 2 ** r
    n_sites = F.gasket_volume(n)
    print(f"gasket n={n}, sites={n_sites} (n^{F.HAUSDORFF:.3f}), "
          f"packed {4 * n_sites} B f32 vs embedded {4 * n * n} B")

    tables = jnp.asarray(cell_neighbor_tables(r))
    i = np.arange(n_sites)
    lx, ly = F.lambda_map_linear(i, r)
    parity_bits = jnp.asarray((np.asarray(lx) + np.asarray(ly)) % 2,
                              jnp.int32)

    sweep = jax.jit(metropolis_sweep)
    for beta in [float(b) for b in args.betas.split(",")]:
        key = jax.random.PRNGKey(0)
        spins = jnp.ones((n_sites,), jnp.float32)   # cold start, all up
        for _ in range(args.sweeps):
            key, spins = sweep(key, spins, parity_bits, tables, beta)
        mag = float(jnp.abs(jnp.sum(spins)) / n_sites)
        energy = float(-jnp.sum(spins * packed_neighbor_sum(spins, tables))
                       / 2 / n_sites)
        print(f"beta={beta:4.2f}:  |m| = {mag:.4f}   E/site = {energy:.4f}")
    print("note: magnetization decays for every beta -- the gasket has no "
          "finite-T transition (H < 2)")


if __name__ == "__main__":
    main()
