"""End-to-end driver: train the ~100M-parameter quickstart LM on the
synthetic pipeline for a few hundred steps with checkpoint/restart.

Smoke (seconds):   PYTHONPATH=src python examples/train_lm.py --smoke
Full 100M run:     PYTHONPATH=src python examples/train_lm.py \
                       --steps 300 --global-batch 16 --seq-len 256
"""
import argparse

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.launch.train import TrainConfig, Trainer
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_config("quickstart", smoke=args.smoke)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params)")

    tcfg = TrainConfig(
        steps=args.steps if not args.smoke else 20,
        log_every=10,
        ckpt_every=100,
        ckpt_dir=args.ckpt_dir,
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps))
    pipe = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq_len if not args.smoke else 64,
        global_batch=args.global_batch if not args.smoke else 4))

    trainer = Trainer(cfg, tcfg)
    params, opt_state, history = trainer.run(pipe)
    first = sum(h["loss"] for h in history[:5]) / max(1, len(history[:5]))
    last = sum(h["loss"] for h in history[-5:]) / max(1, len(history[-5:]))
    print(f"\nloss: {first:.3f} -> {last:.3f} over {len(history)} steps")
    print(f"checkpoints in {args.ckpt_dir} (resume by re-running)")


if __name__ == "__main__":
    main()
