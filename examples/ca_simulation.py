"""Cellular-automaton simulation on the embedded Sierpinski gasket --
the data-parallel application class from the paper's introduction
(Wolfram-style parity CA + heat diffusion), running on the block-space
Pallas kernels with the classic double-buffer scheme.

The whole run is ONE jitted, scanned, buffer-donating driver
(``ca_run``): ``--fuse k`` advances k steps per kernel launch (in-kernel
trapezoid loop), so ``--steps T`` costs ceil(T/k) launches and a single
trace -- the old version dispatched T separate ``ca_step`` calls from a
Python loop.  ``--coarsen s`` makes every launch step own an s x s
superblock (lambda decoded once per superblock).  ``--autotune`` first
searches lowering x storage x fuse x coarsen for this (n, block, rule)
and uses (and persists) the winner.

With ``--storage compact`` (the default) the state never materializes
the dense n x n array after the initial seed: both CA buffers live in
the packed orthotope layout of Lemma 2 (O(n^H) memory), and the kernels
resolve their halo gathers through lambda^-1.  ``--storage embedded``
keeps the dense layout for A/B.

Run:  PYTHONPATH=src python examples/ca_simulation.py [--steps 16]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.core import tune
from repro.core.compact import CompactLayout
from repro.core.domain import make_fractal_domain
from repro.kernels import ops, sierpinski_ca


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--rule", default="parity",
                    choices=["parity", "diffusion"])
    ap.add_argument("--storage", default="compact",
                    choices=["embedded", "compact"])
    ap.add_argument("--fuse", default="auto",
                    help="steps per kernel launch (int, or 'auto' for "
                         "the tuned value; untuned default 1)")
    ap.add_argument("--coarsen", default="auto",
                    help="superblock side in blocks (int or 'auto')")
    ap.add_argument("--grid-mode", default="compact",
                    choices=["compact", "closed_form", "prefetch_lut",
                             "bounding", "auto"])
    ap.add_argument("--autotune", action="store_true",
                    help="search the schedule axes for this problem "
                         "first, persist the winner, and run with it")
    ap.add_argument("--shard", type=int, default=0, metavar="D",
                    help="shard the run over D devices (0 = single "
                         "device; D devices must exist, e.g. via "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=D on CPU)")
    args = ap.parse_args()
    n = args.n
    fuse = args.fuse if args.fuse == "auto" else int(args.fuse)
    coarsen = args.coarsen if args.coarsen == "auto" else int(args.coarsen)
    grid_mode = args.grid_mode

    if args.autotune:
        cfg, us, trials = tune.autotune_ca(
            n=n, block=args.block, rule=args.rule,
            storages=(args.storage,), force=False)
        why = f"measured {us:.0f} us over {len(trials)} configs" \
            if us is not None else "tune-cache hit"
        print(f"autotuned: {cfg} ({why})")
        grid_mode, fuse, coarsen = cfg["lowering"], cfg["fuse"], \
            cfg["coarsen"]

    # the same cache lookup ca_run performs, done here so the driver
    # can report the schedule it is about to run
    grid_mode, fuse, coarsen, num_stages = sierpinski_ca.auto_schedule(
        n=n, block=args.block, rule=args.rule, grid_mode=grid_mode,
        fuse=fuse, coarsen=coarsen)

    mask = F.membership_grid(n)
    # seed: single live cell at the bottom-left corner of the gasket
    state = np.zeros((n, n), np.float32)
    state[n - 1, 0] = 1.0
    if args.rule == "diffusion":
        state[n - 1, 0] = 100.0
    a = jnp.asarray(state * mask)
    b = jnp.zeros_like(a)

    layout = None
    if args.storage == "compact":
        layout = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                   n // args.block))
        a, b = layout.pack(a, args.block), layout.pack(b, args.block)
        emb, pk = n * n, layout.num_cells(args.block)
        print(f"orthotope-resident: {pk} cells ({4 * pk} B f32) instead "
              f"of {emb} ({4 * emb} B), x{emb / pk:.2f} smaller")

    mesh = None
    if args.shard:
        import jax
        if jax.device_count() < args.shard:
            raise SystemExit(
                f"--shard {args.shard} needs {args.shard} devices, have "
                f"{jax.device_count()} (on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.shard})")
        mesh = jax.make_mesh((args.shard,), ("data",))
        print(f"sharded over {args.shard} devices "
              f"({'orthotope row slabs + ppermute halo' if args.storage == 'compact' else 'replicated state, disjoint psum'})")

    total0 = float(jnp.sum(a))
    final = ops.ca_run(a, b, args.steps, fuse=fuse, rule=args.rule,
                       block=args.block, grid_mode=grid_mode,
                       storage=args.storage, n=n, coarsen=coarsen,
                       mesh=mesh)
    eff = sierpinski_ca.effective_fuse(fuse, args.steps, args.block,
                                       int(coarsen))
    launches = len(ops.launch_schedule(args.steps, eff))
    print(f"{args.steps} steps in {launches} fused launches "
          f"(one trace, scanned double buffers)")
    live = int(jnp.sum(final > 0))
    print(f"final active cells = {live}")

    if args.rule == "diffusion":
        total = float(jnp.sum(final))
        print(f"heat conserved: {total0:.3f} -> {total:.3f}")
    # zero outside the fractal is an invariant of the kernel
    emb_final = layout.unpack(final, args.block) if layout is not None \
        else final
    assert (np.asarray(emb_final)[~mask] == 0).all()
    print("invariant OK: state is zero outside the gasket")


if __name__ == "__main__":
    main()
