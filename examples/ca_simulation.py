"""Cellular-automaton simulation on the embedded Sierpinski gasket --
the data-parallel application class from the paper's introduction
(Wolfram-style parity CA + heat diffusion), running on the block-space
Pallas kernels with the classic double-buffer scheme.

With ``--storage compact`` (the default) the state never materializes
the dense n x n array after the initial seed: both CA buffers live in
the packed orthotope layout of Lemma 2 (O(n^H) memory), and the kernels
resolve their halo gathers through lambda^-1.  ``--storage embedded``
keeps the dense layout for A/B.

Run:  PYTHONPATH=src python examples/ca_simulation.py [--steps 16]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.core.compact import CompactLayout
from repro.core.domain import make_fractal_domain
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--rule", default="parity",
                    choices=["parity", "diffusion"])
    ap.add_argument("--storage", default="compact",
                    choices=["embedded", "compact"])
    args = ap.parse_args()
    n = args.n

    mask = F.membership_grid(n)
    # seed: single live cell at the bottom-left corner of the gasket
    state = np.zeros((n, n), np.float32)
    state[n - 1, 0] = 1.0
    if args.rule == "diffusion":
        state[n - 1, 0] = 100.0
    a = jnp.asarray(state * mask)
    b = jnp.zeros_like(a)

    layout = None
    if args.storage == "compact":
        layout = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                   n // args.block))
        a, b = layout.pack(a, args.block), layout.pack(b, args.block)
        emb, pk = n * n, layout.num_cells(args.block)
        print(f"orthotope-resident: {pk} cells ({4 * pk} B f32) instead "
              f"of {emb} ({4 * emb} B), x{emb / pk:.2f} smaller")

    total0 = float(jnp.sum(a))
    for t in range(args.steps):
        new = ops.ca_step(a, b, rule=args.rule, block=args.block,
                          grid_mode="compact", storage=args.storage, n=n)
        b, a = a, new
        live = int(jnp.sum(a > 0))
        print(f"step {t + 1:3d}: active cells = {live}")

    if args.rule == "diffusion":
        total = float(jnp.sum(a))
        print(f"heat conserved: {total0:.3f} -> {total:.3f}")
    # zero outside the fractal is an invariant of the kernel
    final = layout.unpack(a, args.block) if layout is not None else a
    assert (np.asarray(final)[~mask] == 0).all()
    print("invariant OK: state is zero outside the gasket")


if __name__ == "__main__":
    main()
