"""Cellular-automaton simulation on the embedded Sierpinski gasket --
the data-parallel application class from the paper's introduction
(Wolfram-style parity CA + heat diffusion), running on the block-space
Pallas kernels with the classic double-buffer scheme.

Run:  PYTHONPATH=src python examples/ca_simulation.py [--steps 16]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--rule", default="parity",
                    choices=["parity", "diffusion"])
    args = ap.parse_args()
    n = args.n

    mask = F.membership_grid(n)
    # seed: single live cell at the bottom-left corner of the gasket
    state = np.zeros((n, n), np.float32)
    state[n - 1, 0] = 1.0
    if args.rule == "diffusion":
        state[n - 1, 0] = 100.0
    a = jnp.asarray(state * mask)
    b = jnp.zeros_like(a)

    total0 = float(jnp.sum(a))
    for t in range(args.steps):
        new = ops.ca_step(a, b, rule=args.rule, block=args.block,
                          grid_mode="compact")
        b, a = a, new
        live = int(jnp.sum(a > 0))
        print(f"step {t + 1:3d}: active cells = {live}")

    if args.rule == "diffusion":
        total = float(jnp.sum(a))
        print(f"heat conserved: {total0:.3f} -> {total:.3f}")
    # zero outside the fractal is an invariant of the kernel
    assert (np.asarray(a)[~mask] == 0).all()
    print("invariant OK: state is zero outside the gasket")


if __name__ == "__main__":
    main()
