"""Backend-parity tests: the gpu (Triton-structured) emission must be
bit-identical to the tpu (Mosaic-structured) emission, both under the
Pallas interpreter, for every kernel x storage x lowering -- plus
capability-descriptor invariants, target resolution rules, and the
host-table memoization the emission layer rides on.

The two structures share the kernel *math* but differ in everything the
BackendTarget describes: operand placement (BlockSpec index maps vs
in-kernel HBM addressing), decode-table transport (scalar prefetch vs
regular operands), run-time scalars (SMEM vs operand), and reduction
state (sequential-grid scratch vs loop carries / ordered partials).
Bit-identity across that divide is the strongest evidence the backend
axis preserved semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import memo
from repro.core.compact import CompactLayout, pack_kv
from repro.core.domain import (make_attention_domain, make_fractal_domain)
from repro.core.plan import LOWERINGS, GridPlan
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.sierpinski_ca import ca_run
from repro.kernels.sierpinski_write import sierpinski_sum, sierpinski_write

RNG = np.random.default_rng(7)
TARGETS = ("tpu-interpret", "gpu-interpret")


# ---------------------------------------------------------------------------
# capability descriptor + resolution invariants
# ---------------------------------------------------------------------------

def test_capability_descriptor_invariants():
    for t in B.TARGETS.values():
        assert t.kind in ("tpu", "gpu")
        # scalar prefetch, SMEM scalars, BlockSpec placement, grid
        # sequencing and scratch are one coherent Mosaic feature set:
        # they must flip together, or kernels would emit half-structures
        tpu = t.kind == "tpu"
        assert t.has_scalar_prefetch == tpu
        assert t.smem_scalar_params == tpu
        assert t.block_indexed == tpu
        assert t.sequential_grid == tpu
        assert t.supports_scratch == tpu
        assert t.memory_space == ("vmem" if tpu else "hbm")
        assert t.emulated().interpret
        assert t.emulated().emulated() is t.emulated()  # idempotent
        assert not t.native().interpret
        assert B.resolve(t) .kind == t.kind
        assert B.TARGETS[t.native().name] is t.native()


def test_resolution_rules():
    # platform default on CPU is the historical tpu-interpret path
    assert jax.default_backend() == "cpu"
    assert B.resolve(None) is B.TPU_INTERPRET
    # a native target off its platform auto-emulates...
    assert B.resolve("tpu") is B.TPU_INTERPRET
    assert B.resolve("gpu") is B.GPU_INTERPRET
    # ...unless the caller pins interpret=False (takes responsibility)
    assert not B.resolve("gpu", interpret=False).interpret
    # interpret=True forces emulation; aliases resolve
    assert B.resolve("triton", interpret=True) is B.GPU_INTERPRET
    assert B.resolve("mosaic") is B.TPU_INTERPRET
    assert B.resolve("interpret").interpret
    with pytest.raises(ValueError):
        B.resolve("cuda")
    # process override (the serve/train --backend flag)
    B.set_default("gpu-interpret")
    try:
        assert B.resolve(None) is B.GPU_INTERPRET
    finally:
        B.set_default(None)
    assert B.resolve(None) is B.TPU_INTERPRET
    with pytest.raises(ValueError):
        B.set_default("not-a-backend")


def test_scalar_and_scratch_capabilities():
    s = B.TPU.scalar_spec()
    from jax.experimental.pallas import tpu as pltpu
    assert s.memory_space == pltpu.SMEM
    g = B.GPU.scalar_spec()
    assert g.block_shape == (1,)
    B.TPU.scratch((8, 8), jnp.float32)  # exists
    with pytest.raises(ValueError):
        B.GPU.scratch((8, 8), jnp.float32)


def test_env_override(monkeypatch):
    monkeypatch.setenv(B.BACKEND_ENV, "gpu-interpret")
    assert B.resolve(None) is B.GPU_INTERPRET


# ---------------------------------------------------------------------------
# bit-identity matrix: write / sum / CA x storage x lowering
# ---------------------------------------------------------------------------

def _fractal_operands(n, block, fractal="sierpinski-gasket"):
    dom = make_fractal_domain(fractal, n // block)
    y, x = np.mgrid[0:n, 0:n]
    mask = np.asarray(dom.cell_member(jnp.asarray(x), jnp.asarray(y), n))
    state = (RNG.integers(0, 2, (n, n)) * mask).astype(np.float32)
    lay = CompactLayout(dom)
    return dom, lay, jnp.asarray(state)


@pytest.mark.parametrize("storage", ("embedded", "compact"))
@pytest.mark.parametrize("lowering", LOWERINGS)
def test_write_and_sum_backend_parity(storage, lowering):
    n, block = 32, 8
    dom, lay, state = _fractal_operands(n, block)
    m = lay.pack(state, block) if storage == "compact" else state
    kw = dict(block=block, grid_mode=lowering, storage=storage, n=n)
    outs, sums = [], []
    for t in TARGETS:
        outs.append(np.asarray(sierpinski_write(m, 7.0, backend=t, **kw)))
        sums.append(np.asarray(sierpinski_sum(m, backend=t, **kw)))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(sums[0], sums[1])
    # and both match the reference oracle
    emb = lay.unpack(jnp.asarray(outs[0]), block) \
        if storage == "compact" else outs[0]
    np.testing.assert_array_equal(
        np.asarray(emb), np.asarray(ref.sierpinski_write_ref(state, 7.0)))
    np.testing.assert_allclose(sums[0], float(jnp.sum(state)), rtol=1e-6)


@pytest.mark.parametrize("storage", ("embedded", "compact"))
@pytest.mark.parametrize("lowering", LOWERINGS)
def test_ca_backend_parity(storage, lowering):
    n, block, steps = 32, 8, 5
    dom, lay, state = _fractal_operands(n, block)
    zero = jnp.zeros((n, n), jnp.float32)
    if storage == "compact":
        a, b = lay.pack(state, block), lay.pack(zero, block)
    else:
        a, b = state, zero
    kw = dict(rule="parity", block=block, grid_mode=lowering,
              storage=storage, n=n, fuse=2, donate=False)
    outs = [np.asarray(ca_run(a, b, steps, backend=t, **kw))
            for t in TARGETS]
    np.testing.assert_array_equal(outs[0], outs[1])
    # reference: unfused sequential oracle
    want = state
    for _ in range(steps):
        want = ref.ca_step_ref(want, rule="parity")
    emb = lay.unpack(jnp.asarray(outs[0]), block) \
        if storage == "compact" else outs[0]
    np.testing.assert_array_equal(np.asarray(emb), np.asarray(want))


@pytest.mark.parametrize("lowering", LOWERINGS)
def test_ca_coarsen_backend_parity(lowering):
    n, block = 32, 4
    dom, lay, state = _fractal_operands(n, block)
    a, b = lay.pack(state, block), lay.pack(
        jnp.zeros((n, n), jnp.float32), block)
    kw = dict(rule="diffusion", block=block, grid_mode=lowering,
              storage="compact", n=n, fuse=2, coarsen=2, donate=False)
    outs = [np.asarray(ca_run(a, b, 4, backend=t, **kw))
            for t in TARGETS]
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# bit-identity matrix: flash attention x kind x lowering (+ compact KV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,window", (("causal", 0), ("local", 32),
                                         ("full", 0)))
@pytest.mark.parametrize("lowering", LOWERINGS)
def test_flash_backend_parity(kind, window, lowering):
    b, h, s, d = 2, 4, 128, 16
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, 2, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, 2, s, d)), jnp.float32)
    kw = dict(kind=kind, window=window, block_q=32, block_k=32,
              grid_mode=lowering)
    outs = [np.asarray(flash_attention(q, k, v, backend=t, **kw))
            for t in TARGETS]
    np.testing.assert_array_equal(outs[0], outs[1])
    want = ref.attention_ref(q, k, v, kind=kind, window=window)
    np.testing.assert_allclose(outs[0], np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("lowering", LOWERINGS)
def test_flash_compact_kv_backend_parity(lowering):
    sq, sk, w, bq = 64, 128, 32, 16
    q = jnp.asarray(RNG.normal(size=(1, 2, sq, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, sk, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, sk, 16)), jnp.float32)
    dom = make_attention_domain("local", sq // bq, sk // bq, w // bq + 1)
    kp, vp = pack_kv(k, dom, bq), pack_kv(v, dom, bq)
    kw = dict(kind="local", window=w, block_q=bq, block_k=bq,
              grid_mode=lowering, storage="compact", kv_seq_len=sk)
    outs = [np.asarray(flash_attention(q, kp, vp, backend=t, **kw))
            for t in TARGETS]
    np.testing.assert_array_equal(outs[0], outs[1])


def test_flash_decode_seq_pos_parity():
    from repro.models.attention import decode_attention
    S = 64
    q = jnp.asarray(RNG.normal(size=(2, 4, 1, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, S, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, S, 16)), jnp.float32)
    for pos in (0, 21, S - 1):
        outs = [np.asarray(flash_attention(
            q, k, v, kind="full", block_q=1, block_k=16,
            seq_pos=jnp.asarray(pos), backend=t)) for t in TARGETS]
        np.testing.assert_array_equal(outs[0], outs[1])
        want = decode_attention(q, k, v, jnp.asarray(pos))
        np.testing.assert_allclose(outs[0], np.asarray(want), atol=2e-6)


def test_seq_pos_requires_kind_full():
    # a band row wholly beyond seq_pos has an empty k-extent: neither
    # structure can produce a defined result, so the combination is
    # rejected (decode rides kind="full" + window=)
    q = jnp.zeros((1, 1, 64, 8), jnp.float32)
    for kind in ("causal", "local"):
        with pytest.raises(ValueError, match="seq_pos"):
            flash_attention(q, q, q, kind=kind, window=16, block_q=16,
                            block_k=16, seq_pos=jnp.asarray(3),
                            backend="tpu-interpret")


def test_decode_attention_flash_windowed():
    from repro.models.attention import (decode_attention,
                                        decode_attention_flash)
    S = 64
    q = jnp.asarray(RNG.normal(size=(2, 4, 1, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, S, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, S, 16)), jnp.float32)
    for kind, w in (("causal", 0), ("local", 24)):
        for pos in (5, 40, S - 1):
            want = decode_attention(q, k, v, jnp.asarray(pos), kind=kind,
                                    window=w)
            for t in TARGETS:
                got = decode_attention_flash(
                    q, k, v, jnp.asarray(pos), kind=kind, window=w,
                    block_k=16, backend=t)
                np.testing.assert_allclose(np.asarray(got),
                                           np.asarray(want), atol=2e-6)


# ---------------------------------------------------------------------------
# explicit-plan parity: GridPlan(backend=...) drives the same emitters
# ---------------------------------------------------------------------------

def test_gridplan_carries_target():
    dom = make_fractal_domain("sierpinski-gasket", 4)
    p_default = GridPlan(dom)
    assert p_default.target is B.resolve(None)
    p_gpu = GridPlan(dom, backend="gpu-interpret")
    assert p_gpu.target is B.GPU_INTERPRET
    assert not p_gpu.target.block_indexed
    # the emitter refuses scratch on gpu structures
    with pytest.raises(ValueError):
        p_gpu.pallas_call(
            lambda coords, o_ref: None, in_specs=[],
            out_specs=B.full_spec((4, 4)),
            out_shape=jax.ShapeDtypeStruct((4, 4), jnp.float32),
            scratch_shapes=[B.TPU.scratch((4, 4), jnp.float32)])(

        )


# ---------------------------------------------------------------------------
# host-table memoization (the multi-host startup satellite)
# ---------------------------------------------------------------------------

def test_lut_host_memoized_per_domain_axes():
    dom = make_fractal_domain("sierpinski-gasket", 8)
    a = GridPlan(dom, "prefetch_lut", storage="compact").lut_host()
    b = GridPlan(dom, "prefetch_lut", storage="compact").lut_host()
    assert a is b  # same table object across plan instances
    c = GridPlan(make_fractal_domain("sierpinski-gasket", 8),
                 "prefetch_lut", storage="compact").lut_host()
    assert a is c  # and across equal domain instances (cache_key)
    d = GridPlan(dom, "prefetch_lut", storage="embedded").lut_host()
    assert d is not a  # storage changes the table

    layA = GridPlan(dom).layout
    layB = GridPlan(make_fractal_domain("sierpinski-gasket", 8)).layout
    assert layA is layB  # CompactLayout shared per domain


def test_shard_tables_memoized():
    from repro.core.shard import ShardedPlan

    class _Dev:
        def __init__(self, i):
            self.id = i

    from jax.sharding import Mesh
    if jax.device_count() >= 2:
        mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    else:
        pytest.skip("needs 2 devices")
    dom = make_fractal_domain("sierpinski-gasket", 8)
    p1 = ShardedPlan(dom, "prefetch_lut", storage="compact", mesh=mesh,
                     axis="data", halo=True)
    p2 = ShardedPlan(dom, "prefetch_lut", storage="compact", mesh=mesh,
                     axis="data", halo=True)
    assert p1.halo is p2.halo
    assert p1.shard_table_host() is p2.shard_table_host()
    assert p1.lut_sharded_host() is p2.lut_sharded_host()


def _mesh_or_skip(D=2):
    from jax.sharding import Mesh
    if jax.device_count() < D:
        pytest.skip(f"needs {D} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count)")
    return Mesh(np.array(jax.devices()[:D]), ("data",))


@pytest.mark.parametrize("storage", ("embedded", "compact"))
def test_sharded_ca_backend_parity(storage):
    """The gpu structure on a mesh (slab halo exchange / psum combine)
    must stay bit-identical to the tpu structure and to the unsharded
    run."""
    mesh = _mesh_or_skip(2)
    n, block = 32, 8
    dom, lay, state = _fractal_operands(n, block)
    zero = jnp.zeros((n, n), jnp.float32)
    if storage == "compact":
        a, b = lay.pack(state, block), lay.pack(zero, block)
    else:
        a, b = state, zero
    base = np.asarray(ca_run(state, zero, 4, rule="parity", block=block,
                             grid_mode="closed_form", fuse=2,
                             donate=False, backend="tpu-interpret"))
    for t in TARGETS:
        got = ca_run(a, b, 4, rule="parity", block=block,
                     grid_mode="closed_form", storage=storage, n=n,
                     fuse=2, donate=False, backend=t, mesh=mesh)
        emb = lay.unpack(got, block) if storage == "compact" else got
        np.testing.assert_array_equal(np.asarray(emb), base)


def test_sharded_flash_backend_parity():
    mesh = _mesh_or_skip(2)
    s = 128
    q = jnp.asarray(RNG.normal(size=(1, 2, s, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, s, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, s, 16)), jnp.float32)
    base = np.asarray(flash_attention(q, k, v, kind="causal",
                                      block_q=32, block_k=32,
                                      backend="tpu-interpret"))
    for lowering in LOWERINGS:
        for t in TARGETS:
            got = flash_attention(q, k, v, kind="causal", block_q=32,
                                  block_k=32, grid_mode=lowering,
                                  backend=t, mesh=mesh)
            np.testing.assert_array_equal(np.asarray(got), base)


def test_memo_stats_count_hits():
    memo.clear()
    dom = make_fractal_domain("sierpinski-gasket", 8)
    GridPlan(dom, storage="compact").lut_host()
    misses = memo.STATS["misses"]
    GridPlan(dom, storage="compact").lut_host()
    assert memo.STATS["hits"] >= 1
    assert memo.STATS["misses"] == misses  # no rebuild


def test_uncacheable_domain_still_works():
    from repro.core.domain import BoundingBoxDomain
    dom = BoundingBoxDomain(4, 4, member=lambda x, y: (x + y) % 2 == 0)
    assert dom.cache_key is None
    a = GridPlan(dom, "prefetch_lut").lut_host()
    b = GridPlan(dom, "prefetch_lut").lut_host()
    np.testing.assert_array_equal(a, b)  # rebuilt, but correct
