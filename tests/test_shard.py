"""Mesh-aware block-space execution (ShardedPlan) tests.

Multi-device behaviour runs in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag must be
set before jax initializes), following test_distributed.py.  Covered:

  * sharded ``ca_run`` is bit-identical to the single-device run per
    lowering x storage x fuse/coarsen x rule, on even and uneven
    domain/device splits (including devices that own nothing);
  * halo correctness: an impulse whose stencil footprint crosses slab
    boundaries propagates identically;
  * ``sierpinski_write``/``sum`` shard with psum combines; flash
    attention shards its query-block axis bit-identically;
  * per-device compact storage is O(n^H / D) + halo (host geometry);
  * TuneCache merge-on-save under concurrent writers + corrupt-file
    recovery; device-count-qualified cache keys;
  * BENCH artifact run metadata.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=1200)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_PRELUDE = """
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import fractal as F
    from repro.core.compact import CompactLayout
    from repro.core.domain import make_fractal_domain
    from repro.kernels import ops

    def fractal_state(n, binary):
        mask = F.membership_grid(n)
        rng = np.random.default_rng(0)
        vals = rng.integers(0, 2, (n, n)) if binary else \\
            rng.normal(size=(n, n))
        return jnp.asarray(np.where(mask, vals, 0).astype(np.float32))
"""


# ---------------------------------------------------------------------------
# sharded ca_run bit-identity (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_sharded_ca_bit_identical_all_lowerings_and_storages():
    # n=32, block=8 -> 4x4 block grid, r=2, 3x3 orthotope: D=2 is an
    # uneven slot-row split (2+1 rows), D=3 exact, D=4 leaves device 3
    # with no rows at all.
    out = run_sub(_PRELUDE + """
    n, block, steps = 32, 8, 5
    lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                            n // block))
    checked = 0
    for D in (2, 3, 4):
        mesh = jax.make_mesh((D,), ("data",))
        for gm in ("closed_form", "prefetch_lut", "bounding", "mma"):
            for storage in ("embedded", "compact"):
                for rule, fuse, coarsen in (("parity", 3, 1),
                                            ("parity", 1, 2),
                                            ("diffusion", 2, 1)):
                    a = fractal_state(n, rule == "parity")
                    b = jnp.zeros_like(a)
                    if storage == "compact":
                        a, b = lay.pack(a, block), lay.pack(b, block)
                    kw = dict(fuse=fuse, rule=rule, block=block,
                              grid_mode=gm, storage=storage, n=n,
                              coarsen=coarsen, donate=False)
                    want = ops.ca_run(a, b, steps, **kw)
                    got = ops.ca_run(a, b, steps, mesh=mesh, **kw)
                    assert np.array_equal(np.asarray(got),
                                          np.asarray(want)), \\
                        (D, gm, storage, rule, fuse, coarsen)
                    checked += 1
    print("OK", checked)
    """)
    assert "OK 72" in out


def test_sharded_ca_larger_domain_uneven_rows():
    # n=64 -> r=3, 9x3 orthotope (9 slot rows): D=2 -> 5+4 rows, D=8
    # -> 8x1 rows with one device idle in the 2-row padding.
    out = run_sub(_PRELUDE + """
    n, block, steps = 64, 8, 6
    lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                            n // block))
    a = fractal_state(n, True); b = jnp.zeros_like(a)
    ap, bp = lay.pack(a, block), lay.pack(b, block)
    for D in (2, 8):
        mesh = jax.make_mesh((D,), ("data",))
        for fuse, coarsen in ((4, 1), (2, 2)):
            kw = dict(fuse=fuse, rule="parity", block=block,
                      grid_mode="closed_form", storage="compact", n=n,
                      coarsen=coarsen, donate=False)
            want = ops.ca_run(ap, bp, steps, **kw)
            got = ops.ca_run(ap, bp, steps, mesh=mesh, **kw)
            assert np.array_equal(np.asarray(got), np.asarray(want)), \\
                (D, fuse, coarsen)
    print("OK")
    """)
    assert "OK" in out


def test_sharded_ca_generalized_fractal():
    out = run_sub(_PRELUDE + """
    n, block, steps = 27, 3, 4
    lay = CompactLayout(make_fractal_domain("sierpinski-carpet",
                                            n // block))
    dom = make_fractal_domain("sierpinski-carpet", n)
    y, x = np.mgrid[0:n, 0:n]
    mask = np.asarray(dom.cell_member(x, y, n))
    rng = np.random.default_rng(1)
    a = jnp.asarray(np.where(mask, rng.integers(0, 2, (n, n)), 0)
                    .astype(np.float32))
    b = jnp.zeros_like(a)
    ap, bp = lay.pack(a, block), lay.pack(b, block)
    mesh = jax.make_mesh((3,), ("data",))
    for gm in ("closed_form", "prefetch_lut"):
        for storage, (x0, y0) in (("embedded", (a, b)),
                                  ("compact", (ap, bp))):
            kw = dict(fuse=2, rule="parity", block=block, grid_mode=gm,
                      fractal="sierpinski-carpet", storage=storage,
                      n=n, donate=False)
            want = ops.ca_run(x0, y0, steps, **kw)
            got = ops.ca_run(x0, y0, steps, mesh=mesh, **kw)
            assert np.array_equal(np.asarray(got), np.asarray(want)), \\
                (gm, storage)
    print("OK")
    """)
    assert "OK" in out


def test_halo_impulse_crosses_shard_boundary():
    # a single live cell seeded at every slab-boundary block in turn
    # must spread identically to the single-device run: the fused
    # kernel's whole footprint comes through the ghost exchange.
    out = run_sub(_PRELUDE + """
    from repro.core.shard import ShardedPlan
    n, block, steps = 32, 8, 6
    dom = make_fractal_domain("sierpinski-gasket", n // block)
    lay = CompactLayout(dom)
    mesh = jax.make_mesh((2,), ("data",))
    plan = ShardedPlan(dom, "closed_form", storage="compact",
                       mesh=mesh, axis="data", halo=True)
    coords = dom.coords_host()
    rows = lay.slots_host()[:, 1]
    # blocks whose slot row is the last row of slab 0 / first of slab 1
    edge = coords[(rows == plan.rpd - 1) | (rows == plan.rpd)]
    mask = F.membership_grid(n)
    for bx, by in edge:
        s = np.zeros((n, n), np.float32)
        s[by * block, bx * block] = 1.0
        a = jnp.asarray(s * mask); b = jnp.zeros_like(a)
        ap, bp = lay.pack(a, block), lay.pack(b, block)
        kw = dict(fuse=3, rule="parity", block=block,
                  grid_mode="closed_form", storage="compact", n=n,
                  donate=False)
        want = ops.ca_run(ap, bp, steps, **kw)
        got = ops.ca_run(ap, bp, steps, mesh=mesh, **kw)
        assert np.array_equal(np.asarray(got), np.asarray(want)), \\
            (int(bx), int(by))
    print("OK", len(edge))
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# write / sum / flash
# ---------------------------------------------------------------------------

def test_sharded_write_and_sum():
    out = run_sub(_PRELUDE + """
    n, block = 32, 8
    lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                            n // block))
    m = fractal_state(n, False)
    mp = lay.pack(m, block)
    for D in (2, 3):
        mesh = jax.make_mesh((D,), ("data",))
        for gm in ("closed_form", "prefetch_lut", "bounding"):
            for storage, arr in (("embedded", m), ("compact", mp)):
                for coarsen in (1, 2):
                    kw = dict(block=block, grid_mode=gm,
                              storage=storage, n=n, coarsen=coarsen)
                    want = ops.sierpinski_write(arr, 7.0, **kw)
                    got = ops.sierpinski_write(arr, 7.0, mesh=mesh, **kw)
                    assert np.array_equal(np.asarray(got),
                                          np.asarray(want)), \\
                        ("write", D, gm, storage, coarsen)
                    sw = float(ops.sierpinski_sum(arr, **kw))
                    sg = float(ops.sierpinski_sum(arr, mesh=mesh, **kw))
                    np.testing.assert_allclose(sg, sw, rtol=1e-5)
    print("OK")
    """)
    assert "OK" in out


def test_sharded_flash_attention_query_axis():
    out = run_sub(_PRELUDE + """
    rng = np.random.default_rng(0)
    b, h, d = 1, 2, 16
    mesh = jax.make_mesh((4,), ("data",))
    for kind, sq, sk, window in (("causal", 128, 128, 0),
                                 ("local", 128, 128, 32),
                                 ("local", 64, 128, 32),
                                 ("full", 128, 128, 0)):
        q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, h, sk, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, h, sk, d)), jnp.float32)
        for gm in ("closed_form", "prefetch_lut", "bounding"):
            kw = dict(kind=kind, window=window, block_q=16, block_k=16,
                      grid_mode=gm)
            want = ops.flash_attention(q, k, v, **kw)
            got = ops.flash_attention(q, k, v, mesh=mesh, **kw)
            assert np.array_equal(np.asarray(got), np.asarray(want)), \\
                (kind, sq, sk, gm)
    # indivisible query-block grids are rejected with a clear error
    q = jnp.zeros((1, 1, 48, 8), jnp.float32)
    try:
        ops.flash_attention(q, q, q, kind="causal", block_q=16,
                            block_k=16, mesh=jax.make_mesh((8,),
                                                           ("data",)))
        raise SystemExit("expected ValueError")
    except ValueError as e:
        assert "divisible" in str(e)
    print("OK")
    """, devices=8)
    assert "OK" in out


# ---------------------------------------------------------------------------
# host geometry: partition + halo plan invariants (no devices needed)
# ---------------------------------------------------------------------------

def _fake_mesh(D):
    """A mesh-shaped stand-in for host-geometry tests: ShardedPlan's
    partition/halo math only reads ``mesh.shape[axis]``, so geometry is
    testable without D real devices."""
    import jax
    if jax.device_count() >= D:
        return jax.make_mesh((D,), ("data",))
    import types
    return types.SimpleNamespace(shape={"data": D})


@pytest.mark.parametrize("n,block,D", [(32, 8, 2), (64, 8, 3),
                                       (64, 8, 5)])
def test_storage_row_partition_covers_domain_once(n, block, D):
    from repro.core.domain import make_fractal_domain
    from repro.core.shard import ShardedPlan
    dom = make_fractal_domain("sierpinski-gasket", n // block)
    plan = ShardedPlan(dom, "closed_form", storage="compact",
                       mesh=_fake_mesh(D), axis="data", halo=True)
    # every slot row owned by exactly one device; counts sum to N
    assert plan.rpd * D >= plan.nrows
    assert int(plan._count.sum()) == dom.num_blocks
    # per-device compact memory is O(n^H / D) + halo: slab rows are the
    # ceil-split of the orthotope and ghosts never exceed the orthotope
    cells = plan.local_storage_shape(block)
    slab_cells = cells[0] * cells[1]
    per_dev = -(-dom.num_blocks // D) * block * block
    assert slab_cells <= per_dev + plan.ncols * block * block  # +pad row
    assert plan.halo.h_max <= plan.nrows
    # the closed-form slot-row decode enumerates exactly the member set
    got = set()
    for d in range(D):
        lo, c = d * plan.rpd, int(plan._count[d])
        for t in range(c):
            col, row = t % plan.ncols, lo + t // plan.ncols
            bx, by = plan._storage_coords(col, row)
            got.add((int(bx), int(by)))
    want = {(int(x), int(y)) for x, y in dom.coords_host()}
    assert got == want


def test_halo_plan_resolves_every_remote_neighbor():
    from repro.core.compact import CompactLayout
    from repro.core.domain import make_fractal_domain
    from repro.core.shard import ShardedPlan
    dom = make_fractal_domain("sierpinski-gasket", 8)
    lay = CompactLayout(dom)
    for D in (2, 3, 4):
        plan = ShardedPlan(dom, "closed_form", storage="compact",
                           mesh=_fake_mesh(D), axis="data", halo=True)
        halo = plan.halo
        rows = lay.slots_host()[:, 1]
        nbrs = lay.neighbor_slots_host()
        for d in range(D):
            lo, hi = d * plan.rpd, min((d + 1) * plan.rpd, plan.nrows)
            sel = (rows >= lo) & (rows < hi)
            need = np.unique(nbrs[sel][..., 1][nbrs[sel][..., 2] == 1])
            for g in need:
                # owned locally or mapped into the ghost region
                m = halo.ghost_map[d, g]
                if lo <= g < hi:
                    assert m == g - lo
                else:
                    assert plan.rpd <= m < plan.rpd + halo.h_max
        # every (ghost row, strip class) is delivered by exactly one
        # ppermute round, from its owner's matching send slot
        delivered = {d: set() for d in range(D)}
        for delta, cls, send, recv, scol, rcol, wc in halo.rounds:
            assert 0 < wc <= plan.ncols
            for d in range(D):
                src = (d - delta) % D
                needs = [g for g in halo.ghost_rows[d]
                         if g // plan.rpd == src
                         and cls in halo.row_class[d][g]]
                for i, g in enumerate(needs):
                    assert send[src][i] == g - src * plan.rpd
                    assert recv[d][i] == halo.ghost_rows[d].index(g)
                    # the shipped column window covers the readers'
                    # span and stays in range, gathered and scattered
                    # at the same clamped start
                    lo_c, hi_c = halo.col_span[d][(g, cls)]
                    c0 = int(rcol[d][i])
                    assert scol[src][i] == c0
                    assert 0 <= c0 <= lo_c and hi_c <= c0 + wc
                    assert c0 + wc <= plan.ncols
                    delivered[d].add((g, cls))
        for d in range(D):
            want = {(g, c) for g in halo.ghost_rows[d]
                    for c in halo.row_class[d][g]}
            assert delivered[d] == want
            # a full-row ship never coexists with a strip ship
            for g in halo.ghost_rows[d]:
                s = halo.row_class[d][g]
                assert s == {"full"} or "full" not in s


def test_sharded_plan_validation():
    from repro.core.domain import TriangularDomain, make_fractal_domain
    from repro.core.shard import ShardedPlan
    dom = make_fractal_domain("sierpinski-gasket", 4)
    mesh = _fake_mesh(2)
    with pytest.raises(ValueError, match="partition"):
        ShardedPlan(dom, mesh=mesh, axis="data", partition="bogus")
    with pytest.raises(ValueError, match="storage-rows"):
        ShardedPlan(dom, mesh=mesh, axis="data",
                    partition="storage-rows")
    with pytest.raises(ValueError, match="packed rows"):
        ShardedPlan(dom, storage="compact", mesh=mesh, axis="data",
                    partition="linear")
    # 'rows' needs a row-major enumeration: fractals are not
    with pytest.raises(ValueError, match="row-major"):
        ShardedPlan(dom, mesh=mesh, axis="data", partition="rows")
    ShardedPlan(TriangularDomain(8), mesh=mesh, axis="data",
                partition="rows")  # triangular is


# ---------------------------------------------------------------------------
# tune cache satellites: merge-on-save + device-qualified keys
# ---------------------------------------------------------------------------

def test_tune_cache_merge_on_save(tmp_path):
    from repro.core import tune
    path = str(tmp_path / "tune.json")
    a = tune.TuneCache(path)
    b = tune.TuneCache(path)
    # interleaved writers: the second save must not clobber the first
    a.put("ca", {"n": 1, "backend": "cpu"}, {"fuse": 2}, 1.0)
    b.put("ca", {"n": 2, "backend": "cpu"}, {"fuse": 4}, 2.0)
    fresh = tune.TuneCache(path)
    assert fresh.get("ca", {"n": 1, "backend": "cpu"}) == {"fuse": 2}
    assert fresh.get("ca", {"n": 2, "backend": "cpu"}) == {"fuse": 4}
    # in-memory entries win over disk on key conflict
    c = tune.TuneCache(path)
    c.put("ca", {"n": 1, "backend": "cpu"}, {"fuse": 8}, 0.5)
    assert tune.TuneCache(path).get(
        "ca", {"n": 1, "backend": "cpu"}) == {"fuse": 8}
    assert len(tune.TuneCache(path)) == 2


def test_tune_cache_recovers_from_corrupt_partial_write(tmp_path):
    from repro.core import tune
    path = tmp_path / "tune.json"
    good = tune.TuneCache(str(path))
    good.put("ca", {"n": 1, "backend": "cpu"}, {"fuse": 2}, 1.0)
    # simulate a torn write: truncate the file mid-JSON
    txt = path.read_text()
    path.write_text(txt[:len(txt) // 2])
    # a new writer must both read (as empty) and save over it cleanly
    c = tune.TuneCache(str(path))
    assert c.get("ca", {"n": 1, "backend": "cpu"}) is None
    c.put("ca", {"n": 3, "backend": "cpu"}, {"fuse": 1}, 3.0)
    fresh = tune.TuneCache(str(path))
    assert fresh.get("ca", {"n": 3, "backend": "cpu"}) == {"fuse": 1}
    assert json.loads(path.read_text())  # valid JSON again


def test_tune_keys_qualified_by_shard_count():
    # a sharded run consults the shard-count-qualified key (the mesh
    # axis size, NOT the process device count); unsharded runs keep the
    # unqualified key, so single-chip winners never answer for sharded
    # runs and different shard counts never collide.
    out = run_sub("""
    import os, tempfile
    os.environ["REPRO_TUNE_CACHE"] = os.path.join(
        tempfile.mkdtemp(), "tune.json")
    import jax
    from repro.core import tune
    from repro.kernels import sierpinski_ca as ca

    base = {"fractal": "sierpinski-gasket", "n": 32, "block": 8,
            "rule": "parity"}
    assert tune.shard_params(base, None, "data") == base
    mesh2 = jax.make_mesh((2,), ("data",))
    assert tune.shard_params(base, mesh2, "data")["devices"] == 2
    # behavioral: auto resolves per key
    cache = tune.default_cache()
    cache.put("ca", tune._with_backend(dict(base)),
              {"lowering": "bounding", "fuse": 1, "coarsen": 1}, 1.0,
              save=False)
    cache.put("ca", tune._with_backend({**base, "devices": 2}),
              {"lowering": "prefetch_lut", "fuse": 4, "coarsen": 1},
              1.0, save=False)
    assert ca.auto_schedule(n=32, block=8)[0] == "bounding"
    assert ca.auto_schedule(n=32, block=8, mesh=mesh2) == \\
        ("prefetch_lut", 4, 1, 1)
    mesh4 = jax.make_mesh((4,), ("data",))  # untuned D: defaults
    assert ca.auto_schedule(n=32, block=8, mesh=mesh4) == \\
        ("closed_form", 1, 1, 1)
    print("OK")
    """, devices=8)
    assert "OK" in out


# ---------------------------------------------------------------------------
# benchmark artifact metadata
# ---------------------------------------------------------------------------

def test_bench_artifact_carries_run_metadata(tmp_path):
    from benchmarks import common
    meta = common.run_metadata()
    for key in ("jax", "backend", "device_count", "platform", "python",
                "recorded_at"):
        assert key in meta, key
    old = list(common.RESULTS)
    try:
        common.RESULTS[:] = []
        common.row("x/y", 1.23, "a=1")
        path = tmp_path / "bench.json"
        common.dump_json(str(path))
        rec = json.loads(path.read_text())
        assert rec["meta"]["device_count"] >= 1
        assert rec["rows"] == [{"name": "x/y", "us_per_call": 1.23,
                                "derived": "a=1"}]
    finally:
        common.RESULTS[:] = old
