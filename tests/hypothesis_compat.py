"""Graceful degradation when ``hypothesis`` is not installed.

The suite's property tests use hypothesis, but the package is an
optional test dependency (``pip install -e .[test]``).  Importing
``given``/``settings``/``st`` from here keeps every example-based test
in the module runnable without it: property tests collect as zero-arg
functions that skip with a clear reason instead of failing collection
of the whole module.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in bare containers
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed (pip install "
                            "-e .[test])")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for hypothesis.strategies at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
