"""Schedule-equivalence and autotuner tests for the fused block-space
scheduling layer (PR 3).

Covered:
  * temporal fusion: ``ca_run(steps=T, fuse=k)`` is bit-identical to T
    sequential ``ca_step`` calls, across lowerings, storages, rules and
    non-dividing remainders, with ceil(T/k) launches from ONE trace;
  * superblock coarsening: ``coarsen=s`` plans are bit-identical to
    ``coarsen=1`` for write and CA (elementwise kernels) and
    float-close for sum (reduction tile changes), across all three
    lowerings and both storages; invalid coarsenings raise;
  * autotuner: cache round-trips through the JSON file, respects
    backend keys, skips inviable candidates, and the kernels'
    ``grid_mode="auto"`` path resolves from it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tune
from repro.core.compact import NEIGHBOR_OFFSETS8, CompactLayout, SuperTiling
from repro.core.domain import (SierpinskiDomain, TriangularDomain,
                               make_fractal_domain)
from repro.core.plan import LOWERINGS, GridPlan
from repro.kernels import ops
from repro.kernels import sierpinski_ca as ca_mod

RNG = np.random.default_rng(7)


def _fractal_state(fractal, n, binary=False):
    dom = make_fractal_domain(fractal, n)
    y, x = np.mgrid[0:n, 0:n]
    mask = np.asarray(dom.cell_member(x, y, n))
    vals = RNG.integers(0, 2, (n, n)) if binary else \
        RNG.normal(size=(n, n))
    return jnp.asarray(np.where(mask, vals, 0), jnp.float32), mask


def _seq_ca(a, b, steps, **kw):
    for _ in range(steps):
        new = ops.ca_step(a, b, **kw)
        b, a = a, new
    return a


# ---------------------------------------------------------------------------
# launch schedule arithmetic
# ---------------------------------------------------------------------------

def test_launch_schedule_math():
    assert ops.launch_schedule(10, 4) == [4, 4, 2]
    assert ops.launch_schedule(8, 4) == [4, 4]
    assert ops.launch_schedule(3, 8) == [3]
    assert ops.launch_schedule(0, 4) == []
    for steps in range(0, 23):
        for fuse in range(1, 9):
            sched = ops.launch_schedule(steps, fuse)
            assert len(sched) == -(-steps // fuse)  # ceil(T/k) launches
            assert sum(sched) == steps
    with pytest.raises(ValueError):
        ops.launch_schedule(4, 0)
    with pytest.raises(ValueError):
        ops.launch_schedule(-1, 2)


def test_ca_run_single_trace_for_remainder_schedule():
    # 10 steps at fuse=4 -> [4, 4, 2]: the remainder launch must reuse
    # the same kernel build (per-launch step count is a run-time
    # scalar), so exactly one pallas_call is constructed.
    n, block = 16, 4
    a, _ = _fractal_state("sierpinski-gasket", n, binary=True)
    b = jnp.zeros_like(a)
    before = dict(ca_mod.TRACE_COUNTER)
    got = ops.ca_run(a, b, 10, fuse=4, rule="parity", block=block,
                     alpha=0.125)  # unique alpha: defeat jit reuse
    assert ca_mod.TRACE_COUNTER["build"] == before["build"] + 1
    assert ca_mod.TRACE_COUNTER["kernel"] == before["kernel"] + 1
    want = _seq_ca(a, b, 10, rule="parity", block=block, alpha=0.125)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# temporal fusion: bit-identity with the sequential driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gm", LOWERINGS)
@pytest.mark.parametrize("storage", ["embedded", "compact"])
@pytest.mark.parametrize("rule", ["parity", "diffusion"])
def test_fused_ca_bit_identical_to_sequential(gm, storage, rule):
    n, block, steps = 32, 8, 5
    a, _ = _fractal_state("sierpinski-gasket", n, binary=rule == "parity")
    b = jnp.zeros_like(a)
    if storage == "compact":
        lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                n // block))
        a, b = lay.pack(a, block), lay.pack(b, block)
    kw = dict(rule=rule, block=block, grid_mode=gm, storage=storage, n=n)
    want = np.asarray(_seq_ca(a, b, steps, **kw))
    for fuse in (1, 2, 4, 8):  # 5 % 2, 5 % 4: remainder launches
        got = np.asarray(ops.ca_run(a, b, steps, fuse=fuse, **kw))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fractal,n,block",
                         [("sierpinski-carpet", 27, 3),
                          ("vicsek-cross", 27, 3)])
def test_fused_ca_generalized_fractals(fractal, n, block):
    a, _ = _fractal_state(fractal, n, binary=True)
    b = jnp.zeros_like(a)
    kw = dict(rule="parity", block=block, fractal=fractal)
    want = np.asarray(_seq_ca(a, b, 4, **kw))
    got = np.asarray(ops.ca_run(a, b, 4, fuse=3, **kw))
    np.testing.assert_array_equal(got, want)


def test_ca_run_zero_steps_is_identity():
    a, _ = _fractal_state("sierpinski-gasket", 16, binary=True)
    out = ops.ca_run(a, jnp.zeros_like(a), 0, fuse=4, block=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a))


# ---------------------------------------------------------------------------
# superblock coarsening: bit-identity with coarsen=1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gm", LOWERINGS)
@pytest.mark.parametrize("storage", ["embedded", "compact"])
@pytest.mark.parametrize("coarsen", [2, 4])
def test_coarsened_write_bit_identical(gm, storage, coarsen):
    n, block = 32, 4
    m, _ = _fractal_state("sierpinski-gasket", n)
    lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                            n // block))
    arr = lay.pack(m, block) if storage == "compact" else m
    kw = dict(block=block, grid_mode=gm, storage=storage, n=n)
    want = np.asarray(ops.sierpinski_write(arr, 7.0, **kw))
    got = np.asarray(ops.sierpinski_write(arr, 7.0, coarsen=coarsen,
                                          **kw))
    np.testing.assert_array_equal(got, want)
    s = float(ops.sierpinski_sum(arr, **kw))
    sc = float(ops.sierpinski_sum(arr, coarsen=coarsen, **kw))
    # coarsening changes the reduction tile, so only float-close
    np.testing.assert_allclose(sc, s, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("gm", LOWERINGS)
@pytest.mark.parametrize("storage", ["embedded", "compact"])
def test_coarsened_fused_ca_bit_identical(gm, storage):
    n, block, steps = 32, 4, 4
    a, _ = _fractal_state("sierpinski-gasket", n, binary=True)
    b = jnp.zeros_like(a)
    if storage == "compact":
        lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                n // block))
        a, b = lay.pack(a, block), lay.pack(b, block)
    kw = dict(rule="parity", block=block, grid_mode=gm, storage=storage,
              n=n)
    want = np.asarray(_seq_ca(a, b, steps, **kw))
    for coarsen, fuse in ((2, 1), (2, 3), (4, 4)):
        got = np.asarray(ops.ca_run(a, b, steps, fuse=fuse,
                                    coarsen=coarsen, **kw))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fractal,n,block,coarsen",
                         [("sierpinski-carpet", 27, 3, 3),
                          ("vicsek-cross", 27, 3, 3)])
def test_coarsened_write_generalized(fractal, n, block, coarsen):
    m, _ = _fractal_state(fractal, n)
    want = np.asarray(ops.sierpinski_write(m, 3.0, block=block,
                                           fractal=fractal))
    got = np.asarray(ops.sierpinski_write(m, 3.0, block=block,
                                          fractal=fractal,
                                          coarsen=coarsen))
    np.testing.assert_array_equal(got, want)


def test_coarsen_validation():
    with pytest.raises(ValueError):  # not a fractal domain
        GridPlan(TriangularDomain(6), coarsen=2)
    with pytest.raises(ValueError):  # not a power of m=2
        GridPlan(SierpinskiDomain(8), coarsen=3)
    with pytest.raises(ValueError):  # coarser than the whole grid
        GridPlan(SierpinskiDomain(8), coarsen=16)
    with pytest.raises(ValueError):
        GridPlan(SierpinskiDomain(8), coarsen=0)
    # identity coarsening needs no fractal structure
    assert GridPlan(TriangularDomain(6), coarsen=1).coarsen == 1


def test_supertiling_geometry_matches_layout():
    # the packed sub-rectangle of every coarse block must be exactly
    # the fine layout's slots for its members
    dom = SierpinskiDomain(16)
    st = SuperTiling(dom, 4)
    lay = CompactLayout(dom)
    bw, bh = st.sub_shape
    assert bw * bh == st.members_per_tile
    emb2slot = {tuple(c): tuple(s) for c, s in
                zip(dom.coords_host(), lay.slots_host())}
    for CX, CY in st.coarse.coords_host():
        tx, ty = st.tile_index(int(CX), int(CY))
        for (oy, ox), (ey, ex) in st.tile_map():
            fine = (int(CX) * 4 + ex, int(CY) * 4 + ey)
            assert emb2slot[fine] == (int(tx) * bw + ox,
                                      int(ty) * bh + oy)


def test_coarsened_lut_one_row_per_superblock():
    dom = SierpinskiDomain(16)
    plan = GridPlan(dom, "prefetch_lut", storage="compact", coarsen=4)
    lut = np.asarray(plan.lut())
    assert lut.shape == (plan.sched_domain.num_blocks, 28)
    assert plan.sched_domain.num_blocks * 9 == dom.num_blocks
    tiling = plan._tiling
    np.testing.assert_array_equal(lut[:, 2:4], tiling.tiles_host())
    np.testing.assert_array_equal(
        lut[:, 4:], tiling.neighbor_tiles_host().reshape(-1, 24))


def test_cell_offset_grids_match_tile_map():
    dom = SierpinskiDomain(8)
    block = 4
    for storage, coarsen in (("embedded", 2), ("compact", 1),
                             ("compact", 2), ("compact", 4)):
        plan = GridPlan(dom, storage=storage, coarsen=coarsen)
        oy, ox = plan.cell_offset_grids(block)
        assert oy.shape == ox.shape == plan.supertile_shape((block, block))
        tm = plan.tile_map()
        if tm is None:
            want_y, want_x = np.mgrid[0:oy.shape[0], 0:oy.shape[1]]
            np.testing.assert_array_equal(oy, want_y)
            np.testing.assert_array_equal(ox, want_x)
        else:
            for (py, px), (ey, ex) in tm:
                sub_y = oy[py * block:(py + 1) * block,
                           px * block:(px + 1) * block]
                sub_x = ox[py * block:(py + 1) * block,
                           px * block:(px + 1) * block]
                cy, cx = np.mgrid[0:block, 0:block]
                np.testing.assert_array_equal(sub_y, ey * block + cy)
                np.testing.assert_array_equal(sub_x, ex * block + cx)


def test_neighbor_offsets8_prefix_is_von_neumann():
    from repro.core.compact import NEIGHBOR_OFFSETS
    assert NEIGHBOR_OFFSETS8[:4] == NEIGHBOR_OFFSETS
    assert set(NEIGHBOR_OFFSETS8) == {(dx, dy) for dx in (-1, 0, 1)
                                      for dy in (-1, 0, 1)} - {(0, 0)}


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def test_tune_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    c = tune.TuneCache(path)
    params = {"n": 64, "backend": "cpu"}
    assert c.get("ca", params) is None
    c.put("ca", params, {"lowering": "prefetch_lut", "fuse": 4}, 123.4)
    assert c.get("ca", params) == {"lowering": "prefetch_lut", "fuse": 4}
    # a fresh object must read the persisted file
    fresh = tune.TuneCache(path)
    assert fresh.get("ca", params) == {"lowering": "prefetch_lut",
                                       "fuse": 4}
    assert len(fresh) == 1


def test_tune_cache_respects_backend_keys(tmp_path):
    c = tune.TuneCache(str(tmp_path / "tune.json"))
    c.put("ca", {"n": 64, "backend": "tpu"}, {"lowering": "bounding"}, 1.0)
    c.put("ca", {"n": 64, "backend": "cpu"}, {"lowering": "closed_form"},
          2.0)
    assert c.get("ca", {"n": 64, "backend": "tpu"}) == \
        {"lowering": "bounding"}
    # best() stamps the *current* backend into unqualified params
    assert tune.best("ca", {"n": 64}, cache=c) == \
        {"lowering": "closed_form" if jax.default_backend() == "cpu"
         else "bounding"}
    assert tune.best("ca", {"n": 9999}, {"lowering": "x"}, cache=c) == \
        {"lowering": "x"}


def test_tune_cache_tolerates_corrupt_file(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("{not json")
    c = tune.TuneCache(str(path))
    assert c.get("ca", {"n": 1, "backend": "cpu"}) is None
    c.put("ca", {"n": 1, "backend": "cpu"}, {"fuse": 2}, 1.0)
    assert tune.TuneCache(str(path)).get(
        "ca", {"n": 1, "backend": "cpu"}) == {"fuse": 2}


def test_autotune_picks_min_and_caches(tmp_path, monkeypatch):
    c = tune.TuneCache(str(tmp_path / "tune.json"))
    fake_us = {"a": 30.0, "b": 10.0, "c": 20.0}
    monkeypatch.setattr(tune, "measure",
                        lambda fn, *a, **k: fake_us[fn()])

    def build(cfg):
        if cfg["name"] == "inviable":
            raise ValueError("cannot build")
        return lambda: cfg["name"]

    cands = [{"name": k} for k in ("a", "inviable", "b", "c")]
    cfg, us, trials = tune.autotune("k", {"n": 1}, cands, build, cache=c)
    assert cfg == {"name": "b"} and us == 10.0 and len(trials) == 3
    # second call is a pure cache hit: no measurement
    monkeypatch.setattr(tune, "measure",
                        lambda *a, **k: pytest.fail("measured on hit"))
    cfg2, us2, trials2 = tune.autotune("k", {"n": 1}, cands, build,
                                       cache=c)
    assert cfg2 == {"name": "b"} and us2 is None and trials2 == []


def test_autotune_no_viable_candidate_raises(tmp_path):
    c = tune.TuneCache(str(tmp_path / "tune.json"))

    def build(cfg):
        raise ValueError("nope")
    with pytest.raises(ValueError, match="no viable"):
        tune.autotune("k", {"n": 2}, [{"a": 1}], build, cache=c)


def test_grid_mode_auto_resolves_from_cache(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.CACHE_ENV, str(tmp_path / "tune.json"))
    n, block = 16, 4
    a, _ = _fractal_state("sierpinski-gasket", n, binary=True)
    b = jnp.zeros_like(a)
    want = np.asarray(ops.ca_step(a, b, block=block))
    # untuned: auto falls back to the closed_form default
    got = np.asarray(ops.ca_step(a, b, block=block, grid_mode="auto"))
    np.testing.assert_array_equal(got, want)
    # tuned: auto adopts the cached lowering/fuse/coarsen
    tune.default_cache().put(
        "ca", tune._with_backend({"fractal": "sierpinski-gasket", "n": n,
                                  "block": block, "rule": "parity"}),
        {"lowering": "prefetch_lut", "storage": "embedded", "fuse": 2,
         "coarsen": 2}, 1.0)
    got = np.asarray(ops.ca_run(a, b, 4, fuse="auto", grid_mode="auto",
                                coarsen="auto", block=block))
    np.testing.assert_array_equal(
        got, np.asarray(_seq_ca(a, b, 4, block=block)))
    # explicit values are never overridden by the cache
    got = np.asarray(ops.ca_run(a, b, 4, fuse=1, grid_mode="bounding",
                                coarsen=1, block=block))
    np.testing.assert_array_equal(
        got, np.asarray(_seq_ca(a, b, 4, block=block)))


def test_restricted_search_gets_its_own_cache_key(tmp_path):
    # an embedded-only search must not answer (or be answered by) the
    # unrestricted key that grid_mode="auto" lookups use, nor a search
    # restricted to the other storage
    c = tune.TuneCache(str(tmp_path / "tune.json"))
    kw = dict(n=16, block=8, steps=2, max_fuse=1, max_coarsen=1, cache=c)
    cfg_e, us_e, tr_e = tune.autotune_ca(storages=("embedded",), **kw)
    assert us_e is not None
    assert all(t["storage"] == "embedded" for t, _ in tr_e)
    cfg_c, us_c, tr_c = tune.autotune_ca(storages=("compact",), **kw)
    assert us_c is not None  # measured, not a cross-restriction hit
    assert all(t["storage"] == "compact" for t, _ in tr_c)
    assert tune.best("ca", {"fractal": "sierpinski-gasket", "n": 16,
                            "block": 8, "rule": "parity"},
                     cache=c) is None
    # the full-axis search owns the unrestricted key
    cfg, us, _ = tune.autotune_ca(storages=tune.ALL_STORAGES, **kw)
    assert us is not None
    assert tune.best("ca", {"fractal": "sierpinski-gasket", "n": 16,
                            "block": 8, "rule": "parity"},
                     cache=c) == cfg


def test_effective_fuse_clamp():
    from repro.kernels.sierpinski_ca import effective_fuse
    assert effective_fuse(16, 10, 4) == 4        # halo <= block
    assert effective_fuse(16, 10, 4, coarsen=2) == 8
    assert effective_fuse(4, 3, 8) == 3          # never beyond steps
    assert effective_fuse(0, 10, 8) == 1
    assert effective_fuse(4, 0, 8) == 1


def test_autotune_ca_end_to_end(tmp_path):
    c = tune.TuneCache(str(tmp_path / "tune.json"))
    cfg, us, trials = tune.autotune_ca(n=16, block=8, steps=2,
                                       storages=("embedded",),
                                       max_fuse=2, max_coarsen=1,
                                       cache=c)
    assert cfg["lowering"] in LOWERINGS
    assert cfg["fuse"] in (1, 2) and cfg["coarsen"] == 1
    assert cfg["stages"] in (1, 2)
    # every lowering x 2 fuse depths x 2 pipeline depths (the default
    # target can act on num_stages, so the axis is searched)
    assert us > 0 and len(trials) == len(LOWERINGS) * 4
    # and the kernels can consume the result directly
    a, _ = _fractal_state("sierpinski-gasket", 16, binary=True)
    out = ops.ca_run(a, jnp.zeros_like(a), 3, block=8,
                     grid_mode=cfg["lowering"], fuse=cfg["fuse"],
                     coarsen=cfg["coarsen"])
    assert out.shape == a.shape
