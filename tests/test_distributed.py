"""Distributed semantics on fake CPU devices (subprocess so the device
count is set before jax initializes): sharded train step, elastic
restore across mesh shapes, compressed psum in shard_map."""
import os
import subprocess
import sys
import textwrap


_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.train import TrainConfig, Trainer
        from repro.data.pipeline import DataConfig, SyntheticPipeline
        from repro.optim.adamw import AdamWConfig

        cfg = get_config("quickstart", smoke=True)
        tcfg = TrainConfig(steps=3, log_every=100,
                           ckpt_dir="/tmp/rt_mesh_ckpt",
                           optimizer=AdamWConfig(lr=1e-3, total_steps=3))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=32, global_batch=8))
        tr = Trainer(cfg, tcfg, mesh=mesh)
        params, opt, hist = tr.run(pipe)
        l_mesh = hist[0]["loss"]

        import shutil; shutil.rmtree("/tmp/rt_mesh_ckpt")
        pipe2 = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                             seq_len=32, global_batch=8))
        tr2 = Trainer(cfg, tcfg, mesh=None)
        _, _, hist2 = tr2.run(pipe2)
        np.testing.assert_allclose(l_mesh, hist2[0]["loss"], rtol=1e-4)
        import shutil; shutil.rmtree("/tmp/rt_mesh_ckpt")
        print("OK", l_mesh)
    """)
    assert "OK" in out


def test_elastic_restore_across_mesh_shapes():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs import get_config
        from repro.distributed import sharding as S
        from repro.distributed.elastic import elastic_restore, candidate_meshes
        from repro.models import abstract_init, init, loss_fn

        cfg = get_config("quickstart", smoke=True)
        params = init(jax.random.PRNGKey(0), cfg)
        mgr = CheckpointManager("/tmp/rt_elastic", keep=1)
        mgr.save(7, params)

        # restore onto an 8-device mesh, then onto a degraded 6-device mesh
        for ndev in (8, 6):
            devs = jax.devices()[:ndev]
            mesh, step, restored, meta = elastic_restore(
                mgr, abstract_init(cfg), cfg,
                mesh=None if ndev == 8 else
                jax.make_mesh((3, 2), ("data", "model"), devices=devs[:6]))
            assert step == 7
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert candidate_meshes(6)[0][0] * candidate_meshes(6)[0][1] == 6
        import shutil; shutil.rmtree("/tmp/rt_elastic")
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_in_shard_map():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import compressed_psum_grads, init_residual

        mesh = jax.make_mesh((8,), ("data",))
        grads = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        res = init_residual(grads)

        @partial(shard_map, mesh=mesh,
                 in_specs=(P("data", None), P("data", None)),
                 out_specs=(P("data", None), P("data", None)))
        def sync(g, r):
            sg, nr = compressed_psum_grads({"w": g}, {"w": r}, ("data",))
            return sg["w"], nr["w"]

        sg, nr = sync(grads["w"], res["w"])
        # exact mean of the 8 per-device shards (each 1x8 row)
        want = jnp.mean(grads["w"], axis=0, keepdims=True)
        want = jnp.broadcast_to(want, (8, 8))
        np.testing.assert_allclose(np.asarray(sg), np.asarray(want),
                                   rtol=0.02, atol=0.05)
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_single_cell_quickstart_scale():
    # an end-to-end mini dry-run on 8 fake devices: every piece of the
    # dryrun path (specs, shardings, walker) below production scale
    out = run_sub("""
        import jax, numpy as np
        from repro.launch.dryrun import input_specs, model_flops
        from repro.configs import get_config
        from repro.distributed import sharding as S
        from repro.models import model as model_lib
        from repro.launch import hlo_analysis
        from repro.launch.train import TrainConfig, make_train_step
        from repro.optim.adamw import AdamWConfig, init_state
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_config("quickstart", smoke=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        abs_params = model_lib.abstract_init(cfg)
        pshard = S.named_sharding_tree(
            S.param_spec_tree(abs_params, cfg), mesh)
        tcfg = TrainConfig(grad_accum=1, optimizer=AdamWConfig())
        step = make_train_step(cfg, tcfg)
        abs_opt = jax.eval_shape(
            lambda: init_state(abs_params, tcfg.optimizer))
        oshard = {"m": pshard, "v": pshard,
                  "count": NamedSharding(mesh, P())}
        batch = {"inputs": jax.ShapeDtypeStruct((8, 64), "int32"),
                 "labels": jax.ShapeDtypeStruct((8, 64), "int32")}
        bshard = {k: NamedSharding(mesh, P(("data",), None))
                  for k in batch}
        with mesh:
            c = jax.jit(step, in_shardings=(pshard, oshard, bshard)) \\
                .lower(abs_params, abs_opt, batch).compile()
        cost = hlo_analysis.analyze(c.as_text())
        useful = model_flops(cfg, "train_4k")  # not used, just call it
        assert cost.flops > 0 and cost.coll_wire_bytes > 0
        assert c.memory_analysis().temp_size_in_bytes > 0
        print("OK flops=%.2e" % cost.flops)
    """)
    assert "OK" in out
