"""Compact n^H storage subsystem tests (repro.core.compact + the
``storage=`` axis of GridPlan and the kernels).

Layers covered:
  * map level: generalized ``lambda_inverse`` round-trips for every
    FractalSpec, and the cell-level ``pack_to_orthotope`` /
    ``unpack_from_orthotope`` identity on member cells;
  * layout level: CompactLayout pack/unpack round-trips and
    slot/neighbour addressing for every registered domain;
  * kernel level: compact-resident write / sum / CA bit-identical to the
    embedded-array kernels for every registered domain under all three
    lowerings, and the flash compact-KV path;
  * edge cases: the divisibility / window validation bugfixes and the
    aliased unvisited-block-preservation (donation) semantics.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractal as F
from repro.core.compact import (NEIGHBOR_OFFSETS, CompactLayout,
                                cell_neighbor_tables, key_block_support,
                                pack_kv)
from repro.core.domain import (BandDomain, make_attention_domain,
                               make_fractal_domain)
from repro.core.plan import LOWERINGS, GridPlan, registered_domains
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)

#: per registered-domain block size compatible with cell-level
#: membership (powers of the fractal's subdivision factor)
_BLOCKS = {"sierpinski": 4, "carpet": 3, "vicsek": 3}


def _small_domains():
    return [pytest.param(name, dom, id=name)
            for name, dom in registered_domains("small").items()]


def _domain_state(dom, block):
    """Embedded state: random on member cells of member blocks, zero
    elsewhere (the CA invariant); returns (state, packed state, layout)."""
    lay = CompactLayout(dom)
    nbx, nby = dom.bounding_box
    arr = np.zeros((nby * block, nbx * block), np.float32)
    y, x = np.mgrid[0:nby * block, 0:nbx * block]
    cm = np.asarray(dom.cell_member(x, y, nby * block))
    for bx, by in dom.coords_host():
        arr[by * block:(by + 1) * block, bx * block:(bx + 1) * block] = \
            RNG.normal(size=(block, block))
    arr = np.where(cm, arr, 0).astype(np.float32)
    m = jnp.asarray(arr)
    return m, lay.pack(m, block), lay


# ---------------------------------------------------------------------------
# map-level round trips (satellite: property tests)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r", range(1, 9))
def test_gasket_lambda_inverse_roundtrip_full_orthotope(r):
    ox, oy = F.orthotope_shape(r)
    wy, wx = np.mgrid[0:oy, 0:ox]
    lx, ly = F.lambda_map(wx, wy, r)
    iwx, iwy = F.lambda_inverse(lx, ly, r)
    assert np.array_equal(iwx, wx) and np.array_equal(iwy, wy)


@pytest.mark.parametrize("spec", [F.SIERPINSKI, F.CARPET, F.VICSEK])
@pytest.mark.parametrize("r", range(0, 5))
def test_generalized_lambda_inverse_roundtrip(spec, r):
    i = np.arange(spec.k ** r)
    lx, ly = spec.lambda_map_linear(i, r)
    lx, ly = np.asarray(lx), np.asarray(ly)
    wx, wy = spec.lambda_inverse(lx, ly, r)
    # the de-interleaved digits of i ARE the orthotope coordinate
    wx2, wy2 = F.deinterleave_linear(i, spec.k, r)
    assert np.array_equal(wx, wx2) and np.array_equal(wy, wy2)
    assert np.array_equal(np.asarray(spec.linear_index(lx, ly, r)), i)
    ox, oy = spec.orthotope_shape(r)
    assert ox * oy == spec.k ** r
    assert (wx < ox).all() and (wy < oy).all()


@pytest.mark.parametrize("r", range(1, 9))
def test_pack_unpack_orthotope_identity_on_member_cells(r):
    n = 2 ** r
    g = jnp.asarray(RNG.normal(size=(n, n)), jnp.float32)
    u = np.asarray(F.unpack_from_orthotope(
        F.pack_to_orthotope(g, r), r, n, fill=np.nan))
    m = F.membership_grid(n)
    np.testing.assert_array_equal(u[m], np.asarray(g)[m])
    assert np.isnan(u[~m]).all()


# ---------------------------------------------------------------------------
# layout level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,dom", _small_domains())
def test_layout_slots_are_injective_and_in_grid(name, dom):
    lay = CompactLayout(dom)
    slots = lay.slots_host()
    assert slots.shape == (dom.num_blocks, 2)
    assert len({tuple(s) for s in slots}) == dom.num_blocks
    scols, srows = lay.grid_shape
    assert lay.num_slots >= dom.num_blocks
    assert (slots[:, 0] < scols).all() and (slots[:, 1] < srows).all()
    # traceable slot(bx, by) agrees with the host enumeration
    coords = dom.coords_host().astype(np.int64)
    sx, sy = lay.slot(coords[:, 0], coords[:, 1])
    np.testing.assert_array_equal(np.stack([sx, sy], -1), slots)


@pytest.mark.parametrize("name,dom", _small_domains())
def test_layout_pack_unpack_roundtrip(name, dom):
    block = _BLOCKS.get(name, 4)
    lay = CompactLayout(dom)
    nbx, nby = dom.bounding_box
    arr = jnp.asarray(RNG.normal(size=(nby * block, nbx * block)),
                      jnp.float32)
    packed = lay.pack(arr, block)
    assert packed.shape == lay.array_shape(block)
    u = np.asarray(lay.unpack(packed, block, fill=np.nan))
    a = np.asarray(arr)
    member = np.zeros((nby, nbx), bool)
    coords = dom.coords_host()
    member[coords[:, 1], coords[:, 0]] = True
    for by in range(nby):
        for bx in range(nbx):
            tile = u[by * block:(by + 1) * block,
                     bx * block:(bx + 1) * block]
            if member[by, bx]:
                np.testing.assert_array_equal(
                    tile, a[by * block:(by + 1) * block,
                            bx * block:(bx + 1) * block])
            else:
                assert np.isnan(tile).all()


@pytest.mark.parametrize("name,dom", _small_domains())
def test_layout_neighbor_slots_host(name, dom):
    lay = CompactLayout(dom)
    nbrs = lay.neighbor_slots_host()
    coords = dom.coords_host()
    member = {tuple(c) for c in coords}
    slot_of = {tuple(c): tuple(s)
               for c, s in zip(coords, lay.slots_host())}
    nbx, nby = dom.bounding_box
    for i, (bx, by) in enumerate(coords):
        for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS):
            x, y = int(bx) + dx, int(by) + dy
            inr = 0 <= x < nbx and 0 <= y < nby
            ok = inr and (x, y) in member and bool(dom.contains(x, y))
            assert bool(nbrs[i, j, 2]) == ok
            if ok:
                assert tuple(nbrs[i, j, :2]) == slot_of[(x, y)]


def test_compact_lut_carries_slots_and_neighbors():
    dom = make_fractal_domain("sierpinski-gasket", 8)
    plan = GridPlan(dom, "prefetch_lut", storage="compact")
    lut = np.asarray(plan.lut())
    # 2 coords + 2 own-slot + 8 (sx, sy, valid) neighbour triples
    assert lut.shape == (dom.num_blocks, 28)
    np.testing.assert_array_equal(lut[:, :2], dom.coords_host())
    np.testing.assert_array_equal(lut[:, 2:4], plan.layout.slots_host())
    np.testing.assert_array_equal(
        lut[:, 4:], plan.layout.neighbor_slots_host().reshape(-1, 24))


def test_cell_neighbor_tables_match_dense_lookup():
    r, n = 5, 32
    t = cell_neighbor_tables(r)
    i = np.arange(3 ** r)
    lx, ly = F.lambda_map_linear(i, r)
    lx, ly = np.asarray(lx), np.asarray(ly)
    emb = np.full((n, n), 3 ** r, np.int64)
    emb[ly, lx] = i
    for j, (dx, dy) in enumerate(NEIGHBOR_OFFSETS):
        x, y = lx + dx, ly + dy
        ok = (x >= 0) & (x < n) & (y >= 0) & (y < n)
        want = np.where(ok, emb[np.clip(y, 0, n - 1),
                                np.clip(x, 0, n - 1)], 3 ** r)
        np.testing.assert_array_equal(t[j], want)


# ---------------------------------------------------------------------------
# kernel level: compact storage bit-identical to embedded
# ---------------------------------------------------------------------------

_FRACTAL_CASES = [("sierpinski-gasket", 32, 4), ("sierpinski-gasket", 64, 8),
                  ("sierpinski-carpet", 27, 3), ("vicsek-cross", 27, 3)]


def _fractal_state(fractal, n):
    dom = make_fractal_domain(fractal, n)
    y, x = np.mgrid[0:n, 0:n]
    mask = np.asarray(dom.cell_member(x, y, n))
    return jnp.asarray(np.where(mask, RNG.normal(size=(n, n)), 0),
                       jnp.float32), mask


@pytest.mark.parametrize("fractal,n,block", _FRACTAL_CASES)
@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_write_compact_storage_equals_embedded(fractal, n, block, grid_mode):
    m, mask = _fractal_state(fractal, n)
    lay = CompactLayout(make_fractal_domain(fractal, n // block))
    got_e = np.asarray(ops.sierpinski_write(
        m, 7.0, block=block, grid_mode=grid_mode, fractal=fractal))
    got_c = ops.sierpinski_write(
        lay.pack(m, block), 7.0, block=block, grid_mode=grid_mode,
        fractal=fractal, storage="compact", n=n)
    np.testing.assert_array_equal(
        np.asarray(lay.unpack(got_c, block))[mask], got_e[mask])
    np.testing.assert_array_equal(
        got_e, np.where(mask, np.float32(7.0), np.asarray(m)))


@pytest.mark.parametrize("fractal,n,block", _FRACTAL_CASES)
@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_sum_compact_storage_bit_identical(fractal, n, block, grid_mode):
    m, _ = _fractal_state(fractal, n)
    lay = CompactLayout(make_fractal_domain(fractal, n // block))
    s_e = float(ops.sierpinski_sum(m, block=block, grid_mode=grid_mode,
                                   fractal=fractal))
    s_c = float(ops.sierpinski_sum(lay.pack(m, block), block=block,
                                   grid_mode=grid_mode, fractal=fractal,
                                   storage="compact", n=n))
    assert s_e == s_c  # same grid enumeration -> same accumulation order


@pytest.mark.parametrize("fractal,n,block", _FRACTAL_CASES)
@pytest.mark.parametrize("rule", ["parity", "diffusion"])
@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_ca_compact_storage_bit_identical(fractal, n, block, rule,
                                          grid_mode):
    m, mask = _fractal_state(fractal, n)
    if rule == "parity":
        m = jnp.asarray(np.where(mask, RNG.integers(0, 2, (n, n)), 0),
                        jnp.float32)
    lay = CompactLayout(make_fractal_domain(fractal, n // block))
    got_e = np.asarray(ops.ca_step(m, jnp.zeros_like(m), rule=rule,
                                   block=block, grid_mode=grid_mode,
                                   fractal=fractal))
    mp = lay.pack(m, block)
    got_c = ops.ca_step(mp, jnp.zeros_like(mp), rule=rule, block=block,
                        grid_mode=grid_mode, fractal=fractal,
                        storage="compact", n=n)
    np.testing.assert_array_equal(np.asarray(lay.unpack(got_c, block)),
                                  got_e)
    want = np.asarray(ref.ca_step_ref(m, rule)) \
        if fractal == "sierpinski-gasket" else None
    if want is not None:
        np.testing.assert_allclose(got_e, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name,dom", _small_domains())
@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_registered_domain_sum_and_ca_compact_equivalence(name, dom,
                                                          grid_mode):
    # acceptance: compact-resident ca_step and sierpinski_sum are
    # bit-identical to the embedded kernels for EVERY registered domain
    # under all three lowerings
    block = _BLOCKS.get(name, 4)
    m, mp, lay = _domain_state(dom, block)
    s_e = float(ops.sierpinski_sum(m, block=block, grid_mode=grid_mode,
                                   domain=dom))
    s_c = float(ops.sierpinski_sum(mp, block=block, grid_mode=grid_mode,
                                   domain=dom, storage="compact"))
    assert s_e == s_c
    c_e = np.asarray(ops.ca_step(m, jnp.zeros_like(m), rule="parity",
                                 block=block, grid_mode=grid_mode,
                                 domain=dom))
    c_c = ops.ca_step(mp, jnp.zeros_like(mp), rule="parity", block=block,
                      grid_mode=grid_mode, domain=dom, storage="compact")
    np.testing.assert_array_equal(np.asarray(lay.unpack(c_c, block)), c_e)


def test_ca_compact_multi_step_double_buffer():
    fractal, n, block = "sierpinski-gasket", 32, 4
    m, mask = _fractal_state(fractal, n)
    m = jnp.asarray(np.where(mask, RNG.integers(0, 2, (n, n)), 0),
                    jnp.float32)
    lay = CompactLayout(make_fractal_domain(fractal, n // block))
    a, b = lay.pack(m, block), lay.pack(jnp.zeros_like(m), block)
    want = m
    for _ in range(4):
        new = ops.ca_step(a, b, rule="parity", block=block,
                          storage="compact", n=n)
        b, a = a, new
        want = ref.ca_step_ref(want, "parity")
    np.testing.assert_array_equal(np.asarray(lay.unpack(a, block)),
                                  np.asarray(want))


# ---------------------------------------------------------------------------
# flash compact-KV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_flash_local_rectangular_matches_ref(grid_mode):
    # decode convention: 128 queries against a 512-token cache
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 512, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 512, 32)), jnp.float32)
    got = ops.flash_attention(q, k, v, kind="local", window=128,
                              block_q=64, block_k=64, grid_mode=grid_mode)
    want = ref.attention_ref(q, k, v, "local", window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_flash_compact_kv_bit_identical(grid_mode):
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 512, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 512, 32)), jnp.float32)
    dom = make_attention_domain("local", 2, 8, 3)
    lo, hi = key_block_support(dom)
    assert (lo, hi) == (4, 8)  # only the last sq + window tokens
    kc, vc = pack_kv(k, dom, 64), pack_kv(v, dom, 64)
    assert kc.shape[2] == 256
    got_e = np.asarray(ops.flash_attention(
        q, k, v, kind="local", window=128, block_q=64, block_k=64,
        grid_mode=grid_mode))
    got_c = np.asarray(ops.flash_attention(
        q, kc, vc, kind="local", window=128, block_q=64, block_k=64,
        grid_mode=grid_mode, storage="compact", kv_seq_len=512))
    np.testing.assert_array_equal(got_e, got_c)


def test_flash_compact_kv_identity_for_full_support():
    # causal / square-local support is all of sk: compact == embedded
    q = jnp.asarray(RNG.normal(size=(1, 2, 256, 32)), jnp.float32)
    for kind, kw in (("causal", {}), ("local", {"window": 128})):
        a = ops.flash_attention(q, q, q, kind=kind, block_q=64,
                                block_k=64, **kw)
        b = ops.flash_attention(q, q, q, kind=kind, block_q=64,
                                block_k=64, storage="compact", **kw)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flash_local_rectangular_default_blocks():
    # regression: with default block sizes, min(block_q, sq) and
    # min(block_k, sk) used to diverge for sq < 128 <= sk and trip the
    # square-block check on the advertised decode path
    q = jnp.asarray(RNG.normal(size=(1, 1, 64, 8)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 1024, 8)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 1, 1024, 8)), jnp.float32)
    got = ops.flash_attention(q, k, v, kind="local", window=128)
    want = ref.attention_ref(q, k, v, "local", window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_compact_kv_shape_validation():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 512, 32)), jnp.float32)
    with pytest.raises(ValueError, match="key positions"):
        ops.flash_attention(q, k, k, kind="local", window=128,
                            block_q=64, block_k=64, storage="compact",
                            kv_seq_len=512)


# ---------------------------------------------------------------------------
# edge-case bugfix regression tests
# ---------------------------------------------------------------------------

def test_band_domain_rejects_zero_window():
    with pytest.raises(ValueError, match="at least 1 block"):
        BandDomain(8, 0)


def test_local_attention_domain_requires_window_blocks():
    # the old default window_blocks=0 built a degenerate BandDomain with
    # num_blocks == 0 and a divide-by-zero decode returning garbage
    with pytest.raises(ValueError, match="window_blocks"):
        make_attention_domain("local", 8, 8)


def test_band_domain_rectangular_enumeration():
    d = BandDomain(2, 3, m_k=8)
    assert d.num_blocks == 6
    coords = {tuple(c) for c in d.coords_host()}
    assert coords == {(4, 0), (5, 0), (6, 0), (5, 1), (6, 1), (7, 1)}
    for bx, by in coords:
        assert bool(d.contains(bx, by))
        i = int(d.linear_index(bx, by))
        assert tuple(int(c) for c in d.block_coords(i)) == (bx, by)
    with pytest.raises(ValueError, match="m_k - m_q"):
        BandDomain(2, 5, m_k=4)


@pytest.mark.parametrize("entry", ["write", "sum", "ca"])
def test_kernels_reject_non_dividing_block(entry):
    # verified bug: sierpinski_write(zeros(16,16), block=6) silently
    # wrote 45 of 81 member cells
    m = jnp.zeros((16, 16), jnp.float32)
    with pytest.raises(ValueError, match="must divide"):
        if entry == "write":
            ops.sierpinski_write(m, 1.0, block=6)
        elif entry == "sum":
            ops.sierpinski_sum(m, block=6)
        else:
            ops.ca_step(m, jnp.zeros_like(m), block=6)


@pytest.mark.parametrize("entry", ["write", "sum", "ca"])
def test_kernels_reject_non_power_block_grid(entry):
    # 24/8 = 3 blocks per side is not a power of the gasket's m=2
    m = jnp.zeros((24, 24), jnp.float32)
    with pytest.raises(ValueError, match="scale level"):
        if entry == "write":
            ops.sierpinski_write(m, 1.0, block=8)
        elif entry == "sum":
            ops.sierpinski_sum(m, block=8)
        else:
            ops.ca_step(m, jnp.zeros_like(m), block=8)


@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_write_preserves_unvisited_blocks_under_all_lowerings(grid_mode):
    # donation/alias semantics: blocks never visited by the compact grid
    # must keep their previous contents through the input/output alias
    # (incl. the shifted alias indices of the prefetch_lut path)
    n, block = 32, 4
    sentinel = np.arange(n * n, dtype=np.float32).reshape(n, n) + 100.0
    m = jnp.asarray(sentinel)
    out = np.asarray(ops.sierpinski_write(m, 7.0, block=block,
                                          grid_mode=grid_mode))
    mask = F.membership_grid(n)
    np.testing.assert_array_equal(out[~mask], sentinel[~mask])
    np.testing.assert_array_equal(out[mask], np.float32(7.0))


@pytest.mark.parametrize("grid_mode", LOWERINGS)
def test_write_alias_none_vs_empty_consistent(grid_mode):
    # GridPlan.pallas_call must treat None and {} aliases identically
    dom = make_fractal_domain("sierpinski-gasket", 8)
    plan = GridPlan(dom, grid_mode)
    from repro.kernels.sierpinski_write import _sum_kernel
    import functools as ft
    import jax
    m = jnp.asarray(RNG.normal(size=(32, 32)), jnp.float32)
    outs = []
    for aliases in (None, {}):
        call = plan.pallas_call(
            ft.partial(_sum_kernel, block=4, n=32, plan=plan),
            in_specs=[plan.storage_spec((4, 4))],
            out_specs=plan.block_spec((1, 1), lambda bx, by: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
            input_output_aliases=aliases,
            interpret=True,
        )
        outs.append(float(call(m)[0, 0]))
    assert outs[0] == outs[1]
