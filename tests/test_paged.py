"""Paged block-space KV cache + continuous batching.

Covered:

  * PagedKVPool allocator invariants: reserved null page, lowest-first
    reuse, exhaustion, double-free, fragmentation accounting;
  * layout helpers round-trip (fuse/split, scatter -> gather oracle),
    inactive-slot writes routed to the null page;
  * the acceptance criterion: paged flash decode bit-identical to the
    contiguous seq_pos decode per backend structure x lowering x page
    size, incl. shuffled out-of-order page assignment and local
    windows; slot-sharded paged decode on a fake mesh;
  * per-row seq_pos vector on the contiguous decode path (regression);
  * zig-zag balanced causal sharding bit-identical to unsharded;
  * host page-table verification flags every mutation class;
  * page_size as a persisted autotune knob;
  * the continuous-batching scheduler: mixed-length batches match the
    single-request oracle, preemption is deterministic and leak-free,
    and the paged degradation ladder steps blockspace -> paged-xla.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import paged as P
from repro.models import attention as A

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(11)


def run_sub(code: str, devices: int = 4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=1200)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# allocator + layout helpers
# ---------------------------------------------------------------------------

def test_pool_allocator_invariants():
    pool = P.PagedKVPool(num_pages=6, page_size=8)
    assert pool.free_pages == 5            # page 0 is the null page
    a = pool.alloc(2)
    assert a == [1, 2]                     # lowest-first
    b = pool.alloc(3)
    assert b == [3, 4, 5]
    assert pool.alloc(1) is None           # exhausted, not an error
    pool.free(a)
    assert pool.alloc(1) == [1]            # freed pages are reused
    with pytest.raises(ValueError):
        pool.free([2, 2])                  # double free
    pool.free([P.NULL_PAGE])               # null page: silent no-op
    assert P.NULL_PAGE not in pool._free
    s = pool.stats([5])                    # 5 live tokens on 4 pages
    assert s["used_pages"] == 4
    assert 0.0 < s["fragmentation"] < 1.0


def test_pages_for_ceil_div():
    assert [P.pages_for(n, 8) for n in (0, 1, 8, 9, 16)] == [0, 1, 1, 2, 2]


def test_scatter_gather_roundtrip_and_fuse_split():
    hkv, s, d, ps = 2, 20, 8, 8
    k = jnp.asarray(RNG.normal(size=(hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(hkv, s, d)), jnp.float32)
    kk, vv = P.split_kv(P.fuse_kv(k, v))
    assert np.array_equal(kk, k) and np.array_equal(vv, v)
    # scatter into out-of-order pages, gather back through the table
    pages = jnp.asarray([5, 2, 7], jnp.int32)
    pool = P.init_pool(9, hkv, ps, d)
    pool = P.write_prefill_pages(pool, pages, k, v)
    table = jnp.asarray([[5, 2, 7]], jnp.int32)
    gk, gv = P.gather_kv(pool, table)
    assert np.array_equal(gk[0, :, :s], k)
    assert np.array_equal(gv[0, :, :s], v)
    assert not np.asarray(gk[0, :, s:]).any()   # tail stays zero padding


def test_append_token_routes_inactive_to_null_page():
    hkv, d, ps = 2, 4, 8
    pool = P.init_pool(4, hkv, ps, d)
    table = jnp.asarray([[1, 2], [3, 0]], jnp.int32)
    pos = jnp.asarray([9, 3], jnp.int32)
    k_new = jnp.ones((2, hkv, 1, d), jnp.float32)
    v_new = 2 * jnp.ones((2, hkv, 1, d), jnp.float32)
    out = P.append_token(pool, table, pos, k_new, v_new,
                         active=jnp.asarray([True, False]))
    assert np.asarray(out[2, :hkv, 9 % ps]).all()      # slot 0 wrote page 2
    assert not np.asarray(out[3]).any()                # inactive: untouched
    assert np.asarray(out[P.NULL_PAGE]).any()          # routed to null page


# ---------------------------------------------------------------------------
# bit-identity: the acceptance criterion
# ---------------------------------------------------------------------------

def _paged_case(b, h, hkv, smax, d, ps, lens):
    """Contiguous q/k/v + the same KV scattered into a shuffled pool."""
    q = jnp.asarray(RNG.normal(size=(b, h, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, smax, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, smax, d)), jnp.float32)
    npg = P.pages_for(smax, ps)
    perm = np.random.default_rng(3).permutation(b * npg) + 1
    pool = P.init_pool(b * npg + 1, hkv, ps, d)
    table = np.zeros((b, npg), np.int32)
    for i in range(b):
        pages = perm[i * npg:(i + 1) * npg]
        table[i] = pages
        pool = P.write_prefill_pages(pool, jnp.asarray(pages), k[i], v[i])
    pos = jnp.asarray(lens, jnp.int32)
    return q, k, v, pool, jnp.asarray(table), pos


@pytest.mark.parametrize("backend", ["tpu-interpret", "gpu-interpret"])
@pytest.mark.parametrize("gm", ["closed_form", "prefetch_lut",
                                "bounding", "mma"])
@pytest.mark.parametrize("ps", [8, 16])
def test_paged_decode_bit_identical_to_contiguous(backend, gm, ps):
    b, h, hkv, smax, d = 3, 4, 2, 64, 16
    q, k, v, pool, table, pos = _paged_case(
        b, h, hkv, smax, d, ps, lens=[37, 63, 9])
    # bitwise oracle: the contiguous flash decode at the same block
    # granularity (same online-softmax accumulation order)
    want = A.decode_attention_flash(q, k, v, pos, block_k=ps,
                                    backend=backend)
    got = A.decode_attention_paged(q, pool, table, pos, grid_mode=gm,
                                   backend=backend, verify=True)
    assert np.array_equal(np.asarray(got), np.asarray(want)), (backend, gm)
    # the XLA gather rung reproduces the plain softmax path bitwise
    xla = A.decode_attention_paged_xla(q, pool, table, pos)
    assert np.array_equal(np.asarray(xla),
                          np.asarray(A.decode_attention(q, k, v, pos)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(xla),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ["tpu-interpret", "gpu-interpret"])
def test_paged_decode_local_window(backend):
    b, h, hkv, smax, d, ps = 2, 2, 2, 64, 16, 8
    q, k, v, pool, table, pos = _paged_case(
        b, h, hkv, smax, d, ps, lens=[50, 23])
    want = A.decode_attention_flash(q, k, v, pos, kind="local",
                                    window=16, block_k=ps,
                                    backend=backend)
    got = A.decode_attention_paged(q, pool, table, pos, window=16,
                                   backend=backend)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_paged_decode_slot_sharded_bit_identical():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import paged as P
    from repro.models import attention as A
    rng = np.random.default_rng(5)
    b, h, hkv, smax, d, ps = 4, 4, 2, 32, 8, 8
    q = jnp.asarray(rng.normal(size=(b, h, 1, d)), jnp.float32)
    npg = smax // ps
    pool = P.init_pool(b * npg + 1, hkv, ps, d)
    table = np.zeros((b, npg), np.int32)
    perm = rng.permutation(b * npg) + 1
    for i in range(b):
        k = jnp.asarray(rng.normal(size=(hkv, smax, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(hkv, smax, d)), jnp.float32)
        table[i] = perm[i * npg:(i + 1) * npg]
        pool = P.write_prefill_pages(pool, jnp.asarray(table[i]), k, v)
    table = jnp.asarray(table)
    pos = jnp.asarray([17, 31, 5, 24], jnp.int32)
    mesh = jax.make_mesh((4,), ("data",))
    want = A.decode_attention_paged(q, pool, table, pos)
    got = A.decode_attention_paged(q, pool, table, pos, mesh=mesh,
                                   shard_axis="data")
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # a batch that does not tile the mesh falls back to unsharded
    got3 = A.decode_attention_paged(q[:3], pool, table[:3], pos[:3],
                                    mesh=mesh, shard_axis="data")
    assert np.array_equal(np.asarray(got3), np.asarray(want)[:3])
    print("OK")
    """)


# ---------------------------------------------------------------------------
# per-row seq_pos on the contiguous decode path (regression)
# ---------------------------------------------------------------------------

def test_decode_flash_vector_seq_pos_matches_per_row():
    b, h, hkv, smax, d = 3, 4, 2, 64, 16
    q = jnp.asarray(RNG.normal(size=(b, h, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, smax, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, smax, d)), jnp.float32)
    lens = [41, 63, 13]
    got = A.decode_attention_flash(q, k, v, jnp.asarray(lens, jnp.int32))
    for i, n in enumerate(lens):
        row = A.decode_attention_flash(q[i:i + 1], k[i:i + 1],
                                       v[i:i + 1], n)
        assert np.array_equal(np.asarray(got[i:i + 1]),
                              np.asarray(row)), i
    # a uniform vector is bitwise the scalar broadcast
    uni = A.decode_attention_flash(
        q, k, v, jnp.full((b,), 48, jnp.int32))
    assert np.array_equal(
        np.asarray(uni), np.asarray(A.decode_attention_flash(q, k, v, 48)))


# ---------------------------------------------------------------------------
# zig-zag balanced causal sharding
# ---------------------------------------------------------------------------

def test_zigzag_row_order_is_balanced_permutation():
    from repro.core.shard import zigzag_row_order
    for nby, D in ((8, 2), (16, 4), (24, 3)):
        perm = zigzag_row_order(nby, D)
        assert sorted(perm) == list(range(nby))
        # causal cost of device d = sum over owned rows j of (j+1);
        # the snake makes every device's total identical
        costs = [sum(j + 1 for j in perm[d * (nby // D):
                                         (d + 1) * (nby // D)])
                 for d in range(D)]
        assert len(set(costs)) == 1, (nby, D, costs)


def test_zigzag_flash_sharding_bit_identical():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    b, h, d, s = 1, 2, 16, 256
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    mesh = jax.make_mesh((4,), ("data",))
    for gm in ("closed_form", "prefetch_lut", "bounding", "mma"):
        kw = dict(kind="causal", block_q=16, block_k=16, grid_mode=gm)
        want = ops.flash_attention(q, k, v, **kw)
        got = ops.flash_attention(q, k, v, mesh=mesh,
                                  shard_balance="zigzag", **kw)
        assert np.array_equal(np.asarray(got), np.asarray(want)), gm
    # zigzag requires causal and a row count divisible by 2D
    try:
        ops.flash_attention(q, k, v, kind="full", block_q=16,
                            block_k=16, mesh=mesh,
                            shard_balance="zigzag")
        raise SystemExit("expected ValueError (kind)")
    except ValueError as e:
        assert "causal" in str(e)
    try:
        ops.flash_attention(q[:, :, :64], k[:, :, :64], v[:, :, :64],
                            kind="causal", block_q=16, block_k=16,
                            mesh=mesh, shard_balance="zigzag")
        raise SystemExit("expected ValueError (rows)")
    except ValueError as e:
        assert "divisible" in str(e)
    print("OK")
    """)


# ---------------------------------------------------------------------------
# page-table verification
# ---------------------------------------------------------------------------

def _healthy_table():
    table = np.zeros((3, 8), np.int32)
    table[0, :3] = [1, 2, 3]
    table[1, :2] = [4, 5]
    return table, [20, 13, 0]


def test_verify_page_table_passes_healthy():
    from repro.analysis import verify_page_table
    table, lens = _healthy_table()
    rep = verify_page_table(table, lens, page_size=8, num_pages=16)
    assert not rep.findings


@pytest.mark.parametrize("name,mutate,kw", [
    ("bounds", lambda t: t.__setitem__((0, 1), 99), {}),
    ("bounds", lambda t: t.__setitem__((0, 1), -1), {}),
    ("null-in-extent", lambda t: t.__setitem__((1, 0), 0), {}),
    ("double-map", lambda t: t.__setitem__((1, 1), 2), {}),
    ("stale-free", lambda t: None, {"free_pages": [4]}),
    ("tail-null", lambda t: t.__setitem__((2, 0), 7), {}),
])
def test_verify_page_table_flags_mutations(name, mutate, kw):
    from repro.analysis import PlanVerificationError, verify_page_table
    table, lens = _healthy_table()
    mutate(table)
    with pytest.raises(PlanVerificationError, match=name):
        verify_page_table(table, lens, page_size=8, num_pages=16, **kw)


# ---------------------------------------------------------------------------
# page_size as an autotune knob
# ---------------------------------------------------------------------------

def test_autotune_paged_page_size_knob(tmp_path, monkeypatch):
    from repro.core import tune
    monkeypatch.setenv(tune.CACHE_ENV, str(tmp_path / "tune.json"))
    cfg, us, trials = tune.autotune_paged(
        batch=2, heads=2, seq=32, d=8, page_sizes=(8, 16))
    assert cfg["page_size"] in (8, 16) and "lowering" in cfg
    assert len(trials) >= 2
    # the winner persists and answers the lookup-only path
    params = {"batch": 2, "heads": 2, "kv_heads": 2, "seq": 32, "d": 8,
              "window": 0, "page_sizes": "16+8"}
    assert tune.best("paged", params) == cfg
    # a corrupt page_size marks the entry as a cache miss
    cache = tune.TuneCache(str(tmp_path / "tune.json"))
    cache.put("paged", tune._with_backend(params),
              {**cfg, "page_size": 0}, 1.0)
    assert tune.TuneCache(str(tmp_path / "tune.json")).get(
        "paged", tune._with_backend(params)) is None


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------

def _paged_setup(decode_kernel="blockspace"):
    from repro.configs import get_config
    from repro.models import init
    cfg = get_config("quickstart", smoke=True).replace(
        attn_decode_kernel=decode_kernel)
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mixed_prompts(cfg, lens=(7, 12, 5)):
    rng = np.random.default_rng(1)
    return [rng.integers(0, cfg.vocab_size, (n,)) for n in lens]


def test_paged_server_matches_single_request_oracle():
    from repro.launch.serve import (PagedServeConfig, PagedServer,
                                    ServeConfig, Server)
    cfg, params = _paged_setup()
    reqs = _mixed_prompts(cfg)
    scfg = PagedServeConfig(max_len=32, temperature=0.0, num_slots=2,
                            page_size=8, num_pages=16, guard=False)
    out = PagedServer(cfg, params, scfg).run(reqs, max_new=4)
    oracle = Server(cfg.replace(attn_decode_kernel="xla"), params,
                    ServeConfig(max_len=32, temperature=0.0,
                                guard=False))
    for rid, prompt in enumerate(reqs):
        want = oracle.generate(prompt[None], max_new=4)[0]
        assert np.array_equal(out[rid], want), rid


def test_paged_server_preemption_deterministic_and_leak_free():
    from repro.launch.serve import PagedServeConfig, PagedServer
    cfg, params = _paged_setup()
    reqs = _mixed_prompts(cfg, lens=(14, 18, 10))
    kw = dict(max_len=48, temperature=0.7, top_k=16, seed=5,
              num_slots=3, page_size=8, guard=False)
    starved = PagedServer(cfg, params,
                          PagedServeConfig(num_pages=8, **kw))
    out = starved.run(reqs, max_new=8)
    pre = [e for e in starved.events
           if isinstance(e, dict) and e.get("kind") == "preempt"]
    assert pre, "pool was not starved enough to preempt"
    roomy = PagedServer(cfg, params,
                        PagedServeConfig(num_pages=32, **kw))
    ref = roomy.run(reqs, max_new=8)
    for rid in ref:
        assert np.array_equal(out[rid], ref[rid]), rid
    for srv in (starved, roomy):            # every page returned
        assert srv.alloc.free_pages == srv.scfg.num_pages - 1


def test_paged_server_too_small_pool_raises():
    from repro.launch.serve import PagedServeConfig, PagedServer
    cfg, params = _paged_setup()
    scfg = PagedServeConfig(max_len=32, num_slots=1, page_size=4,
                            num_pages=3, guard=False)
    srv = PagedServer(cfg, params, scfg)
    with pytest.raises(RuntimeError, match="pool"):
        srv.run([np.arange(6) % cfg.vocab_size], max_new=16)


def test_paged_server_ladder_blockspace_to_xla():
    from repro.launch.serve import PagedServeConfig, PagedServer
    from repro.runtime.chaos import ChaosInjector, FaultPlan, FaultSpec
    from repro.runtime.guard import ServerState
    cfg, params = _paged_setup()
    reqs = _mixed_prompts(cfg)
    kw = dict(max_len=32, temperature=0.0, num_slots=2, page_size=8,
              num_pages=16, retries=2, backoff_base_s=0.0)
    want = PagedServer(cfg.replace(attn_decode_kernel="xla"), params,
                       PagedServeConfig(**kw)).run(reqs, max_new=4)
    plan = FaultPlan(0, [FaultSpec("transient_error", "serve.decode", i,
                                   rung=0) for i in range(3)])
    faulty = PagedServer(cfg, params, PagedServeConfig(**kw),
                         chaos=ChaosInjector(plan))
    assert faulty.ladder.rungs[0]["decode_kernel"] == "blockspace"
    out = faulty.run(reqs, max_new=4)
    assert faulty.state == ServerState.DEGRADED
    assert faulty.ladder.current()["decode_kernel"] == "xla"
    for rid in want:
        assert np.array_equal(out[rid], want[rid]), rid


def test_paged_throughput_report_fields():
    from repro.launch.serve import (PagedServeConfig, PagedServer,
                                    paged_throughput_report)
    cfg, params = _paged_setup(decode_kernel="xla")
    srv = PagedServer(cfg, params, PagedServeConfig(
        max_len=32, temperature=0.0, num_slots=2, page_size=8,
        num_pages=16, guard=False))
    rep = paged_throughput_report(srv, _mixed_prompts(cfg), max_new=3)
    assert rep["tokens"] == 9 and rep["requests"] == 3
    assert rep["tok_per_s"] > 0
    assert 0.0 <= rep["mean_fragmentation"] <= 1.0
    assert 0.0 < rep["peak_utilization"] <= 1.0
