"""SSM scans: chunked vs sequential oracles; block/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models import ssm
from repro.models.config import ModelConfig

RNG = np.random.default_rng(11)


def _scan_inputs(b, s, di, n):
    return (jnp.asarray(RNG.normal(size=(b, s, di)), jnp.float32),
            jnp.asarray(RNG.uniform(0.001, 0.1, size=(b, s, di)), jnp.float32),
            -jnp.asarray(RNG.uniform(0.5, 2, size=(di, n)), jnp.float32),
            jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32),
            jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32))


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_selective_scan_matches_sequential(chunk):
    x, dt, A, B, C = _scan_inputs(2, 64, 16, 8)
    got = ssm.selective_scan(x, dt, A, B, C, chunk=chunk)
    want = ssm.selective_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(st.integers(1, 3), st.sampled_from([4, 8, 16]), st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_property_selective_scan_chunking_invariance(b, s, chunk):
    x, dt, A, B, C = _scan_inputs(b, 32, 8, 4)
    a = ssm.selective_scan(x, dt, A, B, C, chunk=chunk)
    bb = ssm.selective_scan(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_ssd_matches_sequential(chunk):
    b, s, nh, p, n = 2, 64, 4, 8, 16
    x = jnp.asarray(RNG.normal(size=(b, s, nh, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.5, size=(b, s, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2, size=(nh,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    got = ssm.ssd_scan(x, dt, A, B, C, chunk=chunk)
    want = ssm.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def _cfg1():
    return ModelConfig(d_model=32, d_state=8, expand=2, conv_kernel=4,
                       ssd_chunk=8, dtype="float32", param_dtype="float32")


def _cfg2():
    return ModelConfig(d_model=32, d_state=16, expand=2, conv_kernel=4,
                       ssd_head_dim=16, ssd_chunk=8, dtype="float32",
                       param_dtype="float32")


def test_mamba1_decode_consistency():
    cfg = _cfg1()
    p = ssm.mamba1_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, 32)), jnp.float32)
    y_all, cache = ssm.mamba1_block(p, x, cfg, return_cache=True)
    c = (jnp.zeros((2, cfg.d_inner, cfg.d_state), jnp.float32),
         jnp.zeros((2, cfg.conv_kernel - 1, cfg.d_inner), jnp.float32))
    ys = []
    for t in range(16):
        y, c = ssm.mamba1_decode(p, x[:, t:t + 1], cfg, c)
        ys.append(y)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_all,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c[0], cache[0], rtol=1e-4, atol=1e-5)


def test_mamba2_decode_consistency():
    cfg = _cfg2()
    p = ssm.mamba2_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, 32)), jnp.float32)
    y_all, _ = ssm.mamba2_block(p, x, cfg, return_cache=True)
    c = (jnp.zeros((2, cfg.ssd_heads, cfg.d_state, cfg.ssd_head_dim),
                   jnp.float32),
         jnp.zeros((2, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.d_state),
                   jnp.float32))
    ys = []
    for t in range(16):
        y, c = ssm.mamba2_decode(p, x[:, t:t + 1], cfg, c)
        ys.append(y)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_all,
                               rtol=1e-4, atol=1e-4)


def test_grads_finite():
    x = jnp.asarray(RNG.normal(size=(2, 16, 32)), jnp.float32)
    for cfg, init, blk in ((_cfg1(), ssm.mamba1_init, ssm.mamba1_block),
                           (_cfg2(), ssm.mamba2_init, ssm.mamba2_block)):
        p = init(jax.random.PRNGKey(0), cfg)
        g = jax.grad(lambda p: jnp.sum(blk(p, x, cfg) ** 2))(p)
        assert all(np.isfinite(np.asarray(v)).all()
                   for v in jax.tree.leaves(g))


def test_causal_conv_is_causal():
    x = jnp.asarray(RNG.normal(size=(1, 16, 4)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(4, 3)), jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    y1 = ssm.causal_conv1d(x, w, b)
    x2 = x.at[:, 10:, :].set(0)
    y2 = ssm.causal_conv1d(x2, w, b)
    np.testing.assert_allclose(y1[:, :10], y2[:, :10], rtol=1e-6)


def test_conv_step_matches_full():
    x = jnp.asarray(RNG.normal(size=(2, 8, 4)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(4, 3)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(4,)), jnp.float32)
    full = ssm.causal_conv1d(x, w, b)
    state = jnp.zeros((2, 2, 4), jnp.float32)
    outs = []
    for t in range(8):
        y, state = ssm.conv_step(state, x[:, t:t + 1], w, b)
        outs.append(y)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=1e-5, atol=1e-6)
