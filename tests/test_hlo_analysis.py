"""The roofline HLO walker: loop-trip multiplication, dot flops,
collective accounting -- validated against analytic counts on real
compiled modules (the property XLA's own cost_analysis lacks)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_module


def _compile(f, *specs, in_shardings=None):
    jf = jax.jit(f) if in_shardings is None else jax.jit(
        f, in_shardings=in_shardings)
    return jf.lower(*specs).compile()


def test_scan_trip_count_multiplies_flops():
    def layer(h, w):
        return jnp.dot(h, w), None

    def f(ws, x):
        h, _ = jax.lax.scan(layer, x, ws)
        return h.sum()

    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    cost = analyze(_compile(f, ws, x).as_text())
    analytic = 8 * 2 * 64 * 256 * 256
    assert 0.95 < cost.flops / analytic < 1.1


def test_unrolled_matches_scan():
    def f_scan(ws, x):
        h, _ = jax.lax.scan(lambda h, w: (jnp.dot(h, w), None), x, ws)
        return h.sum()

    def f_unroll(ws, x):
        for i in range(8):
            x = jnp.dot(x, ws[i])
        return x.sum()

    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    c1 = analyze(_compile(f_scan, ws, x).as_text())
    c2 = analyze(_compile(f_unroll, ws, x).as_text())
    assert 0.9 < c1.flops / c2.flops < 1.15


def test_nested_scan_multiplies():
    def inner(h, w):
        return jnp.dot(h, w), None

    def outer(h, ws):
        h, _ = jax.lax.scan(inner, h, ws)
        return h, None

    def f(ws, x):
        h, _ = jax.lax.scan(outer, x, ws)
        return h.sum()

    ws = jax.ShapeDtypeStruct((4, 8, 64, 64), jnp.float32)  # 4 outer x 8 in
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    cost = analyze(_compile(f, ws, x).as_text())
    analytic = 4 * 8 * 2 * 16 * 64 * 64
    assert 0.9 < cost.flops / analytic < 1.2


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    cost = analyze(_compile(f, a, b).as_text())
    analytic = 2 * 4 * 32 * 64 * 16
    assert 0.95 < cost.flops / analytic < 1.1


def test_collective_wire_bytes():
    n_dev = jax.device_count()
    if n_dev < 2:
        pytest.skip("needs >1 device")


def test_bytes_do_not_charge_full_stacked_params():
    # dynamic-slice of stacked weights inside a scan must charge the
    # slice, not the full stack, per iteration
    def f(ws, x):
        h, _ = jax.lax.scan(lambda h, w: (jnp.dot(h, w), None), x, ws)
        return h.sum()

    ws = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    cost = analyze(_compile(f, ws, x).as_text())
    full_stack_everytime = 64 * (64 * 128 * 128 * 4)
    assert cost.bytes_accessed < full_stack_everytime / 4


def test_parse_module_handles_tuple_types_with_comments():
    txt = """
HloModule m

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %ni = s32[] add(%i, %c1)
  %y = f32[4,4]{1,0} add(%x, %x)
  ROOT %t = (s32[], f32[4,4]) tuple(%ni, %y)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%c0, %x)
  %w = (s32[], f32[4,4]{1,0}, /*index=2*/f32[4,4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    comps, entry = parse_module(txt)
    assert entry == "main"
    cost = analyze(txt)
    # 10 iterations x 16-elem add (+ scalar counter add/compare per trip)
    assert 10 * 16 <= cost.flops <= 10 * 16 + 40
