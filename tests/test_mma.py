"""The ``mma`` lowering's digit-basis matmul decode chains
(:mod:`repro.core.mma`): mixed-precision exactness against the integer
closed forms, the asserted f32-accumulation bound, and kernel-level
parity on both interpret targets."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractal as F
from repro.core import mma

from hypothesis_compat import given, settings, st

SPECS = (F.SIERPINSKI, F.CARPET, F.VICSEK)
#: deepest level per spec whose volume k^r and extent m^r both stay
#: under DIGIT_BOUND -- the exactness envelope the chains assert
MAX_R = {s.name: max(r for r in range(1, 40)
                     if s.k ** r < mma.DIGIT_BOUND
                     and s.m ** r < mma.DIGIT_BOUND)
         for s in SPECS}


# ---------------------------------------------------------------------------
# mixed-precision exactness property: chain == integer closed form for
# every level up to the asserted bound (large magnitudes included)
# ---------------------------------------------------------------------------

@given(st.integers(0, 2), st.data())
@settings(max_examples=60, deadline=None)
def test_property_decode_exact_up_to_bound(which, data):
    spec = SPECS[which]
    r = data.draw(st.integers(1, MAX_R[spec.name]))
    # bias toward the top of the index range, where f32 rounding would
    # first show
    i = data.draw(st.integers(max(0, spec.k ** r - 64),
                              spec.k ** r - 1))
    bx, by = mma.decode_linear(spec, r, jnp.int32(i))
    ex, ey = spec.lambda_map_linear(int(i), r)
    assert (int(bx), int(by)) == (int(ex), int(ey))
    sx, sy = mma.slots_of_linear(spec, r, jnp.int32(i))
    wx, wy = F.deinterleave_linear(int(i), spec.k, r)
    assert (int(sx), int(sy)) == (int(wx), int(wy))


@given(st.integers(0, 2), st.data())
@settings(max_examples=60, deadline=None)
def test_property_inverse_and_linear_exact(which, data):
    spec = SPECS[which]
    r = data.draw(st.integers(1, min(MAX_R[spec.name], 12)))
    i = data.draw(st.integers(0, spec.k ** r - 1))
    x, y = spec.lambda_map_linear(int(i), r)
    li = mma.linear_of(spec, r, jnp.int32(int(x)), jnp.int32(int(y)))
    assert int(li) == int(i)
    sx, sy = mma.inverse_slots(spec, r, jnp.int32(int(x)),
                               jnp.int32(int(y)))
    ex, ey = spec.lambda_inverse(int(x), int(y), r)
    assert (int(sx), int(sy)) == (int(ex), int(ey))


@pytest.mark.parametrize("spec", SPECS)
def test_decode_exact_at_bound_edge_batch(spec):
    """Dense check of the last 4k indices at the deepest in-bound
    level: the largest magnitudes the chain ever accumulates."""
    r = MAX_R[spec.name]
    k_r = spec.k ** r
    i = np.arange(max(0, k_r - 4096), k_r, dtype=np.int64)
    bx, by = mma.decode_linear(spec, r, jnp.asarray(i, jnp.int32))
    ex, ey = spec.lambda_map_linear(i, r)
    np.testing.assert_array_equal(np.asarray(bx), np.asarray(ex))
    np.testing.assert_array_equal(np.asarray(by), np.asarray(ey))


def test_bound_is_asserted():
    for spec in SPECS:
        with pytest.raises(ValueError, match="2\\^24"):
            mma.coords_basis(spec, MAX_R[spec.name] + 1)
    with pytest.raises(ValueError, match="2\\^24"):
        mma.decode_linear(F.SIERPINSKI, MAX_R["sierpinski-gasket"] + 1,
                          jnp.int32(0))


# ---------------------------------------------------------------------------
# kernel-level parity on both interpret targets (the TPU structure
# consumes the mma table, the GPU structure runs the chains in-kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["tpu-interpret", "gpu-interpret"])
@pytest.mark.parametrize("storage", ["embedded", "compact"])
def test_write_mma_matches_closed_form_both_targets(backend, storage):
    from repro.kernels import ops
    n, block = 64, 8
    if storage == "compact":
        from repro.core.compact import CompactLayout
        from repro.core.domain import make_fractal_domain
        lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                n // block))
        m = jnp.zeros(lay.array_shape(block), jnp.float32)
        kw = dict(storage="compact", n=n)
    else:
        m = jnp.zeros((n, n), jnp.float32)
        kw = {}
    outs = [ops.sierpinski_write(m, 7.0, block=block, grid_mode=gm,
                                 backend=backend, **kw)
            for gm in ("closed_form", "mma")]
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  np.asarray(outs[1]))
