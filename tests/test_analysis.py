"""Tier-1: the static plan verifier and interpret-mode access sanitizer.

Three layers:

* clean-matrix: every plan the smoke matrix emits verifies with zero
  findings (no false positives);
* mutation: seed one fault of each class the verifier claims to catch
  -- a corrupted LUT row, a mis-wired neighbour slot, a shifted or
  colliding storage index map, a dropped/duplicated grid step, an
  unsafe in-place alias declaration, a corrupted ghost-map entry --
  and assert the matching check flags it (no false negatives);
* sanitizer: real kernel launches on both interpret targets, traced
  accesses cross-checked against the static read/write sets.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.analysis import (PlanVerificationError, verify_launches,
                            verify_or_raise, verify_plan)
from repro.analysis.verifier import ACCESS_MODELS, HostMesh
from repro.core.domain import SierpinskiDomain, make_fractal_domain
from repro.core.plan import _LUT_NBR, GridPlan
from repro.core.shard import SHARD_GMAP, ShardedPlan

DOM = SierpinskiDomain(8)          # 27 member blocks: fast to enumerate
N = DOM.num_blocks


def _plan(lowering="prefetch_lut", storage="embedded", **kw):
    return GridPlan(SierpinskiDomain(8), lowering, storage=storage, **kw)


def _sharded(d=2, halo=True, lowering="closed_form"):
    return ShardedPlan(SierpinskiDomain(8), lowering, storage="compact",
                       mesh=HostMesh(d), axis="data",
                       partition="storage-rows", halo=halo)


# ---------------------------------------------------------------------------
# clean matrix: no false positives
# ---------------------------------------------------------------------------

def test_smoke_matrix_is_clean():
    from repro.analysis.verify import matrix_plans
    for label, plan, kernel in matrix_plans(smoke=True):
        report = verify_plan(plan, kernel=kernel)
        assert report.ok, f"{label}: {[str(f) for f in report.findings]}"


def test_report_json_roundtrip():
    report = verify_plan(_plan(), kernel="write")
    blob = json.loads(json.dumps(report.to_json()))
    assert blob["ok"] and blob["findings"] == []
    assert set(blob["checks"]) == {"coverage", "race", "table", "bounds",
                                   "alias", "hull"}


def test_verify_or_raise_is_value_error():
    plan = _plan()
    lut = np.array(plan.lut_host())
    lut[0, 0] += 1
    plan.lut_host = lambda: lut
    with pytest.raises(PlanVerificationError) as ei:
        verify_or_raise(plan, kernel="write")
    assert isinstance(ei.value, ValueError)   # the autotune skip path
    assert "table" in str(ei.value)


# ---------------------------------------------------------------------------
# mutation: seeded faults are flagged by the matching check
# ---------------------------------------------------------------------------

def _checks(plan, kernel="write"):
    return {f.check for f in verify_plan(plan, kernel=kernel).findings}


def _corrupt_lut_row(row):
    plan = _plan("prefetch_lut", "embedded")
    lut = np.array(plan.lut_host())
    lut[row, 0] ^= 1                      # flip one decoded coordinate
    plan.lut_host = lambda: lut
    return plan


def test_corrupt_lut_row_flagged():
    assert "table" in _checks(_corrupt_lut_row(0))
    assert "table" in _checks(_corrupt_lut_row(N - 1))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=N - 1))
def test_corrupt_lut_row_flagged_any_row(row):
    assert "table" in _checks(_corrupt_lut_row(row))


def _corrupt_neighbor_slot(row, offset):
    plan = _plan("prefetch_lut", "compact")
    lut = np.array(plan.lut_host())
    base = _LUT_NBR + 3 * offset
    if lut[row, base + 2] == 1:
        # valid neighbour: point its slot somewhere else entirely
        lut[row, base] = (lut[row, base] + 1) % plan.layout.grid_shape[0]
    else:
        lut[row, base + 2] = 1            # claim validity membership denies
    plan.lut_host = lambda: lut
    return plan


def test_corrupt_neighbor_slot_flagged():
    assert "table" in _checks(_corrupt_neighbor_slot(0, 0), kernel="ca")
    assert "table" in _checks(_corrupt_neighbor_slot(N - 1, 7),
                              kernel="ca")


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=N - 1),
       st.integers(min_value=0, max_value=7))
def test_corrupt_neighbor_slot_flagged_any(row, offset):
    assert "table" in _checks(_corrupt_neighbor_slot(row, offset),
                              kernel="ca")


def test_shifted_storage_index_flagged_as_bounds():
    plan = _plan("closed_form", "compact")
    orig = plan.storage_index

    def shifted(ids, refs=()):
        r, c = orig(ids, refs)
        return r + 100, c                 # hull leaves the tile grid
    plan.storage_index = shifted
    assert "bounds" in _checks(plan)


def test_colliding_storage_index_flagged_as_race():
    plan = _plan("closed_form", "compact")
    orig = plan.storage_index

    def collapsed(ids, refs=()):
        r, c = orig(ids, refs)
        return np.zeros_like(np.asarray(r)), np.zeros_like(np.asarray(c))
    plan.storage_index = collapsed
    assert "race" in _checks(plan)


def test_dropped_step_flagged_as_coverage():
    plan = _plan("closed_form", "embedded")
    orig = plan._step_valid

    def drop_first(ids, bx, by, refs=()):
        v = orig(ids, bx, by, refs)
        v = np.ones(np.asarray(ids[-1]).shape, bool) if v is None \
            else np.array(np.broadcast_to(np.asarray(v),
                                          np.asarray(ids[-1]).shape))
        live = np.nonzero(v.ravel())[0]
        v.ravel()[live[0]] = False        # one member block goes dark
        return v
    plan._step_valid = drop_first
    findings = verify_plan(plan, kernel="write").findings
    assert any(f.check == "coverage" and "never covered" in f.detail
               for f in findings)


def test_duplicated_decode_flagged_as_coverage():
    plan = _plan("closed_form", "embedded")
    orig = plan._decode

    def duped(ids, refs=()):
        batch, bx, by = orig(ids, refs)
        bx = np.array(np.broadcast_to(np.asarray(bx),
                                      np.asarray(ids[-1]).shape))
        by = np.array(np.broadcast_to(np.asarray(by),
                                      np.asarray(ids[-1]).shape))
        bx.ravel()[1] = bx.ravel()[0]     # two steps decode one block
        by.ravel()[1] = by.ravel()[0]
        return batch, bx, by
    plan._decode = duped
    assert "coverage" in _checks(plan)


def test_inplace_alias_on_stencil_flagged():
    """The 'corrupted alias entry' fault: a kernel that declares its
    stencil input donated/aliased in place.  Reading neighbour tiles
    that other steps write is a RAW hazard within the launch."""
    ACCESS_MODELS["_test_inplace_stencil"] = {
        "race": True, "neighbors": True, "storage": True,
        "alias_reads": ("center+neighbors",)}
    try:
        plan = _plan("closed_form", "compact")
        assert "alias" in _checks(plan, kernel="_test_inplace_stencil")
        # the safe declaration of the same plan stays clean
        assert _checks(plan, kernel="ca") == set()
    finally:
        del ACCESS_MODELS["_test_inplace_stencil"]


def test_corrupt_ghost_map_flagged():
    plan = _sharded(d=2, halo=True)
    tbl = np.array(plan.shard_table_host())
    gmap = tbl[0, SHARD_GMAP:]
    ghost = np.nonzero(gmap >= plan.rpd)[0]     # a ghost/dump slot
    gmap[ghost[0]] = 0                          # alias it onto row 0
    plan.shard_table_host = lambda: tbl
    assert "table" in _checks(plan)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=0, max_value=10 ** 6))
def test_corrupt_ghost_map_flagged_any(d, seed):
    plan = _sharded(d=d, halo=True)
    tbl = np.array(plan.shard_table_host())
    dev = seed % d
    gmap = tbl[dev, SHARD_GMAP:]
    i = seed % len(gmap)
    gmap[i] = gmap[i] + 1                       # any off-by-one slot
    plan.shard_table_host = lambda: tbl
    assert "table" in _checks(plan)


def test_sharded_plans_clean_and_phase_views_checked():
    for d in (1, 2, 3):
        for halo in (True, False):
            report = verify_plan(_sharded(d=d, halo=halo), kernel="ca")
            assert report.ok, [str(f) for f in report.findings]


def test_corrupt_mma_basis_flagged(monkeypatch):
    """A corrupted digit-basis matrix must not survive verification:
    the mma decode table is re-derived from the integer ground truth,
    so a mis-weighted digit shows up as a table finding."""
    from repro.core import memo, mma
    orig = mma.coords_basis

    def corrupted(spec, r):
        b = np.array(orig(spec, r))
        b[0, 1, 0] += 1.0            # mis-weight digit 1 at level 1
        return b

    memo.clear()                     # drop any clean cached tables
    monkeypatch.setattr(mma, "coords_basis", corrupted)
    try:
        plan = _plan("mma", "embedded", backend="tpu-interpret")
        assert "table" in _checks(plan)
    finally:
        memo.clear()                 # drop the corrupted tables too


def test_corrupt_flash_hull_flagged():
    from repro.core.domain import TriangularDomain
    plan = GridPlan(TriangularDomain(8), "prefetch_lut")
    ext = np.array(plan.row_extents())
    ext[0, 1] += 1                   # widen row 0 past its membership
    plan.row_extents = lambda: ext
    assert "hull" in _checks(plan, kernel="flash")


# ---------------------------------------------------------------------------
# the kernels' verify= debug flag and the autotune rejection path
# ---------------------------------------------------------------------------

def test_kernel_verify_flag():
    from repro.kernels.sierpinski_write import sierpinski_write
    dom = make_fractal_domain("sierpinski-gasket", 8)
    m = jnp.zeros((24, 24), jnp.float32)
    verified = sierpinski_write(m, 1.0, block=3, domain=dom,
                                num_stages=1, interpret=True, verify=True)
    plain = sierpinski_write(m, 1.0, block=3, domain=dom,
                             num_stages=1, interpret=True)
    # the flag verifies, it must never change what the kernel computes
    np.testing.assert_array_equal(np.asarray(verified), np.asarray(plain))
    assert float(verified.sum()) > 0


def test_autotune_rejects_failing_candidates(tmp_path):
    from repro.core.tune import TuneCache, autotune
    measured = []

    def build(cfg):
        def fn():
            measured.append(cfg["x"])
        return fn

    def vfy(cfg):
        if cfg["x"] == "bad":
            raise PlanVerificationError("seeded verification failure")

    cfg, us, trials = autotune(
        "_test", {"p": 1}, [{"x": "bad"}, {"x": "good"}], build,
        cache=TuneCache(str(tmp_path / "t.json")), verify=vfy)
    assert cfg == {"x": "good"}
    assert all(t[0] == {"x": "good"} for t in trials)
    assert "bad" not in measured              # rejected before measuring


def test_autotune_all_rejected_raises(tmp_path):
    from repro.core.tune import TuneCache, autotune

    def vfy(cfg):
        raise PlanVerificationError("seeded")

    with pytest.raises(ValueError, match="no viable candidate"):
        autotune("_test", {"p": 1}, [{"x": 1}], lambda cfg: (lambda: None),
                 cache=TuneCache(str(tmp_path / "t.json")), verify=vfy)


# ---------------------------------------------------------------------------
# interpret-mode access sanitizer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["gpu-interpret", "tpu-interpret"])
@pytest.mark.parametrize("storage", ["embedded", "compact"])
def test_sanitizer_write_clean(backend, storage):
    from repro.core.compact import compact_layout
    from repro.kernels.sierpinski_write import sierpinski_write
    dom = make_fractal_domain("sierpinski-gasket", 8)
    m = jnp.zeros((24, 24), jnp.float32) if storage == "embedded" else \
        jnp.zeros(compact_layout(dom).array_shape(3), jnp.float32)
    out, findings = verify_launches(
        sierpinski_write, m, 1.0, block=3, grid_mode="closed_form",
        storage=storage, domain=dom, num_stages=1, backend=backend,
        kernel="write", strict=True)
    assert findings == []
    assert float(out.sum()) > 0


@pytest.mark.parametrize("backend", ["gpu-interpret", "tpu-interpret"])
def test_sanitizer_ca_clean(backend):
    from repro.core.compact import compact_layout
    from repro.kernels.sierpinski_ca import ca_run
    dom = make_fractal_domain("sierpinski-gasket", 8)
    state = jnp.zeros(compact_layout(dom).array_shape(3), jnp.float32)
    _, findings = verify_launches(
        ca_run, state, jnp.zeros_like(state), 2, fuse=1, block=3,
        grid_mode="closed_form", storage="compact", domain=dom,
        num_stages=1, backend=backend, kernel="ca", strict=True)
    assert findings == []


# ---------------------------------------------------------------------------
# CLI + benchmark harness satellites
# ---------------------------------------------------------------------------

def test_verify_cli_static_smoke(tmp_path):
    from repro.analysis.verify import main
    out = tmp_path / "report.json"
    rc = main(["--matrix", "--smoke", "--no-sanitize", "--quiet",
               "--out", str(out)])
    assert rc == 0
    blob = json.loads(out.read_text())
    assert blob["ok"] and blob["num_findings"] == 0
    assert blob["num_static"] == len(blob["static"]) > 0


def test_bench_only_rejects_unknown_suite(capsys):
    from benchmarks.run import main
    with pytest.raises(SystemExit):
        main(["--only", "bogus", "--no-json"])
    err = capsys.readouterr().err
    assert "unknown suite" in err and "bogus" in err
    assert "map" in err and "attn" in err     # lists what is available


def test_bench_metadata_stamps_git():
    from benchmarks.common import git_revision
    rev = git_revision()
    if not rev:
        pytest.skip("git unavailable")
    assert len(rev["commit"]) == 40
    assert isinstance(rev["dirty"], bool)
