"""XLA attention strategies vs the naive oracle, incl. custom-VJP grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models import attention as A

RNG = np.random.default_rng(7)


def qkv(b, h, hkv, sq, sk, d, dv=None, dtype=jnp.float32):
    dv = dv or d
    return (jnp.asarray(RNG.normal(size=(b, h, sq, d)), dtype),
            jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype),
            jnp.asarray(RNG.normal(size=(b, hkv, sk, dv)), dtype))


@pytest.mark.parametrize("schedule", ["dense", "triangular"])
@pytest.mark.parametrize("b,h,hkv,s,d", [(2, 4, 2, 256, 32),
                                         (1, 8, 1, 128, 64)])
def test_flash_causal_fwd(schedule, b, h, hkv, s, d):
    q, k, v = qkv(b, h, hkv, s, s, d)
    got = A.flash_attention_xla(q, k, v, kind="causal", chunk=64,
                                schedule=schedule)
    want = ref.attention_ref(q, k, v, "causal")
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("schedule", ["dense", "triangular"])
@pytest.mark.parametrize("window", [64, 128])
def test_flash_local_fwd(schedule, window):
    q, k, v = qkv(1, 2, 2, 512, 512, 16)
    got = A.flash_attention_xla(q, k, v, kind="local", window=window,
                                chunk=64, schedule=schedule)
    want = ref.attention_ref(q, k, v, "local", window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("schedule", ["dense", "triangular"])
@pytest.mark.parametrize("kind,window", [("causal", 0), ("local", 64)])
def test_flash_grads_match_simple(schedule, kind, window):
    q, k, v = qkv(1, 4, 2, 128, 128, 16)

    def loss_simple(q, k, v):
        return jnp.sum(A.simple_attention(q, k, v, kind=kind,
                                          window=window) ** 2)

    def loss_flash(q, k, v):
        return jnp.sum(A.flash_attention_xla(
            q, k, v, kind=kind, window=window, chunk=32,
            schedule=schedule) ** 2)

    gs = jax.grad(loss_simple, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(gs, gf, "qkv"):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4,
                                   err_msg=f"d{nm}")


def test_flash_distinct_v_dim():
    # MLA-style: qk head dim != v head dim
    q, k, v = qkv(1, 4, 4, 128, 128, 24, dv=16)
    got = A.flash_attention_xla(q, k, v, kind="causal", chunk=32)
    want = ref.attention_ref(q, k, v, "causal")  # ref handles dv via v
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_matches_truncated_ref():
    q, k, v = qkv(2, 4, 2, 1, 64, 16)
    pos = jnp.asarray(37)
    got = A.decode_attention(q, k, v, pos, kind="causal")
    want = ref.attention_ref(q, k[:, :, :38], v[:, :, :38], "causal")
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_local_window():
    q, k, v = qkv(1, 2, 2, 1, 64, 16)
    pos = jnp.asarray(50)
    got = A.decode_attention(q, k, v, pos, kind="local", window=16)
    want = ref.attention_ref(q, k[:, :, :51], v[:, :, :51], "local",
                             window=16)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_rectangular_causal_offset():
    # q are the LAST sq positions (chunked-prefill convention)
    q, k, v = qkv(1, 2, 2, 64, 256, 16)
    want = ref.attention_ref(q, k, v, "causal")
    for schedule in ("dense", "triangular"):
        got = A.flash_attention_xla(q, k, v, kind="causal", chunk=64,
                                    schedule=schedule)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_dispatcher_thresholds():
    q, k, v = qkv(1, 2, 2, 64, 64, 16)
    a = A.attention(q, k, v, kind="causal", flash_threshold=8192)
    b = A.attention(q, k, v, kind="causal", flash_threshold=16)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError):
        A.attention(q[:, :, :1], k, v, kind="causal")
