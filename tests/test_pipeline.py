"""Pipelined (``num_stages >= 2``) vs synchronous bit-identity.

The software pipeline must be a pure scheduling change: every kernel x
storage x lowering x fuse x stages point returns the same bits as the
synchronous path on both interpret structures, and the sharded overlap
(interior compute concurrent with the halo exchange, boundary steps
after) must propagate a slab-crossing impulse identically.  Covered:

  * backend capability plumbing: ``async_copy`` / ``pipeline_stages``
    flags and the ``resolve_stages`` clamp;
  * the first-iteration LUT-stall fix: ``_lut_row0`` is a host constant
    equal to LUT row 0 on single-device plans, and None on sharded
    plans (per-device chunks are shard_map operands);
  * write/sum DMA streaming and ca fused DMA bit-identity matrices on
    the TPU structure; knob passthrough on the GPU structure;
  * flash attention's KV FIFO (gpu structure) at stages 2..4;
  * host geometry of the overlap machinery: interior/boundary phase
    tables partition each device's owned steps, strip halo rounds never
    mix with full-row rounds for the same ghost row, and the trimmed
    byte count never exceeds the full-row baseline;
  * an impulse seeded against a slab boundary propagates identically
    under stages=2 overlap on forced 8-device meshes (subprocess).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=1200)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def _fake_mesh(D):
    """Host-geometry stand-in: ShardedPlan's partition/halo/phase math
    only reads ``mesh.shape[axis]``."""
    import jax
    if jax.device_count() >= D:
        return jax.make_mesh((D,), ("data",))
    import types
    return types.SimpleNamespace(shape={"data": D})


def _state(n, binary=True):
    from repro.core import fractal as F
    import jax.numpy as jnp
    mask = F.membership_grid(n)
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 2, (n, n)) if binary else \
        rng.normal(size=(n, n))
    return jnp.asarray(np.where(mask, vals, 0).astype(np.float32))


def _packed(n, block, a=None):
    from repro.core.compact import CompactLayout
    from repro.core.domain import make_fractal_domain
    import jax.numpy as jnp
    lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                            n // block))
    if a is None:
        a = jnp.zeros((n, n), jnp.float32)
    return lay.pack(a, block)


# ---------------------------------------------------------------------------
# capability plumbing
# ---------------------------------------------------------------------------

def test_backend_stage_capabilities_and_clamp():
    from repro.core import backend
    tpu, gpu = backend.TARGETS["tpu"], backend.TARGETS["gpu"]
    ti = backend.TARGETS["tpu-interpret"]
    gi = backend.TARGETS["gpu-interpret"]
    # in-kernel DMA is a TPU-structure capability; the GPU structure
    # pipelines through the compiler knob instead
    assert tpu.async_copy and ti.async_copy
    assert not gpu.async_copy and not gi.async_copy
    for t in (tpu, gpu, ti, gi):
        assert t.pipeline_stages >= 2
        assert t.resolve_stages(None) == 1      # "auto" -> synchronous
        assert t.resolve_stages(1) == 1
        assert t.resolve_stages(2) == 2
        assert t.resolve_stages(999) == t.pipeline_stages


def test_lut_row0_hoist_is_host_constant():
    from repro.core.domain import make_fractal_domain
    from repro.core.plan import GridPlan
    from repro.core.shard import ShardedPlan
    dom = make_fractal_domain("sierpinski-gasket", 8)
    for storage in ("embedded", "compact"):
        plan = GridPlan(dom, "prefetch_lut", storage=storage)
        row0 = plan._lut_row0()
        assert row0 is not None
        assert np.array_equal(np.asarray(row0),
                              np.asarray(plan.lut_host()[0]))
    sp = ShardedPlan(dom, "prefetch_lut", storage="compact",
                     mesh=_fake_mesh(2), axis="data", halo=True)
    assert sp._lut_row0() is None  # chunks are shard_map operands


# ---------------------------------------------------------------------------
# host geometry: phase tables + strip halo rounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("D", [2, 3, 4, 8])
def test_phase_tables_partition_owned_steps(D):
    from repro.core.domain import make_fractal_domain
    from repro.core.shard import ShardedPlan
    dom = make_fractal_domain("sierpinski-gasket", 8)  # n=64, block=8
    plan = ShardedPlan(dom, "prefetch_lut", storage="compact",
                       mesh=_fake_mesh(D), axis="data", halo=True)
    h = plan.halo
    mi, mb = plan.phase_widths()
    for d in range(D):
        own = set(range(int(plan._count[d])))
        i, b = set(map(int, h.int_steps[d])), set(map(int, h.bnd_steps[d]))
        assert i.isdisjoint(b)
        assert i | b == own  # every owned step in exactly one phase
    tabs = plan.phase_tables_host()
    if mi == 0 or mb == 0:
        assert tabs is None  # degenerate split: overlap has no benefit
        return
    for tbl, lists, m in zip(tabs, (h.int_steps, h.bnd_steps), (mi, mb)):
        assert tbl.shape == (D, 1 + m) and tbl.dtype == np.int32
        for d in range(D):
            c = int(tbl[d, 0])
            assert c == len(lists[d])
            assert list(tbl[d, 1:1 + c]) == list(lists[d])
            assert not tbl[d, 1 + c:].any()  # zero padding
    for which, width in (("interior", mi), ("boundary", mb)):
        pv = plan.phase_view(which)
        assert pv.steps_per_shard == width
        assert pv.num_scalar_prefetch == plan.num_scalar_prefetch + 1


def test_phase_view_rejects_unsupported_plans():
    from repro.core.domain import make_fractal_domain
    from repro.core.shard import ShardedPlan
    dom = make_fractal_domain("sierpinski-gasket", 8)
    bounding = ShardedPlan(dom, "bounding", storage="compact",
                           mesh=_fake_mesh(2), axis="data", halo=True)
    with pytest.raises(ValueError, match="bounding"):
        bounding.phase_view("interior")
    no_halo = ShardedPlan(dom, "closed_form", storage="compact",
                          mesh=_fake_mesh(2), axis="data", halo=False)
    with pytest.raises(ValueError, match="halo"):
        no_halo.phase_view("interior")
    ok = ShardedPlan(dom, "closed_form", storage="compact",
                     mesh=_fake_mesh(2), axis="data", halo=True)
    with pytest.raises(ValueError, match="unknown phase"):
        ok.phase_view("everything")


@pytest.mark.parametrize("D", [2, 4])
def test_halo_strips_trim_bytes_and_never_mix_with_full(D):
    from repro.core.domain import make_fractal_domain
    from repro.core.shard import ShardedPlan
    dom = make_fractal_domain("sierpinski-gasket", 8)
    plan = ShardedPlan(dom, "closed_form", storage="compact",
                       mesh=_fake_mesh(D), axis="data", halo=True)
    h = plan.halo
    assert plan.tile_map() is None  # embedded-ordered tiles -> strips
    for cls_map in h.row_class:
        for classes in cls_map.values():
            assert classes <= {"full", "top", "bot"}
            if "full" in classes:
                assert classes == {"full"}  # full absorbs the strips
    # trimming targets strip heights below the row unit (h = fuse <
    # block in every launch); there it always beats full rows, and
    # shallower fuse ships fewer bytes
    sizes = [h.bytes_exchanged(plan, 8, h=hh)["strips"]
             for hh in (1, 3)]
    full = h.bytes_exchanged(plan, 8, h=1)["full_rows"]
    assert 0 < sizes[0] <= sizes[1] <= full
    # column trimming stacks on top: the occupied window never ships
    # more than the full-width strip
    by = h.bytes_exchanged(plan, 8, h=1)
    assert 0 < by["trimmed"] <= by["strips"]
    # packed supertiles are not embedded-row-ordered: full rows only
    coarse = ShardedPlan(dom, "closed_form", storage="compact",
                         coarsen=2, mesh=_fake_mesh(D), axis="data",
                         halo=True)
    assert coarse.tile_map() is not None
    assert all(cls == "full" for _, cls, *_ in coarse.halo.rounds)
    byc = coarse.halo.bytes_exchanged(coarse, 8)
    assert byc["strips"] == byc["full_rows"]
    assert byc["trimmed"] <= byc["strips"]


# ---------------------------------------------------------------------------
# single-device bit-identity matrices (interpret structures)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("storage", ["embedded", "compact"])
def test_write_sum_dma_bit_identical(storage):
    from repro.kernels.sierpinski_write import (sierpinski_sum,
                                               sierpinski_write)
    n, block = 32, 8
    for gm in ("closed_form", "prefetch_lut", "bounding"):
        for coarsen in (1, 2):
            base = None
            for stages in (1, 2):
                m = _packed(n, block) if storage == "compact" else \
                    _state(n) * 0
                w = sierpinski_write(m, value=3.0, block=block,
                                     grid_mode=gm, storage=storage,
                                     n=n, coarsen=coarsen,
                                     num_stages=stages,
                                     backend="tpu-interpret")
                s = sierpinski_sum(w, block=block, grid_mode=gm,
                                   storage=storage, n=n,
                                   coarsen=coarsen, num_stages=stages,
                                   backend="tpu-interpret")
                out = (np.asarray(w), float(s))
                if base is None:
                    base = out
                else:
                    key = (gm, coarsen, stages)
                    assert np.array_equal(base[0], out[0]), key
                    assert base[1] == out[1], key
            # value lands on exactly the 3^log2(n) gasket cells
            assert base[1] == 3.0 * 3 ** 5


@pytest.mark.parametrize("storage", ["embedded", "compact"])
def test_ca_pipelined_bit_identical(storage):
    import jax.numpy as jnp
    from repro.kernels import ops
    n, block, steps = 32, 8, 5
    a0 = _state(n)
    for gm in ("closed_form", "prefetch_lut", "bounding"):
        for fuse in (1, 3):
            a = _packed(n, block, a0) if storage == "compact" else a0
            b = jnp.zeros_like(a)
            ref = None
            for stages in (1, 2, 4):
                out = np.asarray(ops.ca_run(
                    a, b, steps, fuse=fuse, rule="parity", block=block,
                    grid_mode=gm, storage=storage, n=n,
                    num_stages=stages, backend="tpu-interpret",
                    donate=False))
                if ref is None:
                    ref = out
                    assert ref.any()  # the matrix point is non-trivial
                else:
                    assert np.array_equal(ref, out), (gm, fuse, stages)


def test_gpu_structure_accepts_stage_knob():
    # On the GPU structure num_stages maps to the compiler knob (a
    # no-op under interpret) -- results must not change and nothing
    # may reject the parameter.
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.sierpinski_write import sierpinski_write
    n, block = 32, 8
    a = _packed(n, block, _state(n))
    b = jnp.zeros_like(a)
    outs = [np.asarray(ops.ca_run(a, b, 4, fuse=2, rule="parity",
                                  block=block, grid_mode="prefetch_lut",
                                  storage="compact", n=n, num_stages=s,
                                  backend="gpu-interpret", donate=False))
            for s in (1, 4)]
    assert np.array_equal(outs[0], outs[1])
    ws = [np.asarray(sierpinski_write(_packed(n, block), value=2.0,
                                      block=block, grid_mode="closed_form",
                                      storage="compact", n=n,
                                      num_stages=s,
                                      backend="gpu-interpret"))
          for s in (1, 2)]
    assert np.array_equal(ws[0], ws[1])


@pytest.mark.parametrize("kind,window", [("causal", 0), ("local", 64)])
def test_flash_kv_fifo_bit_identical(kind, window):
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention
    sq, d, heads, block = 256, 32, 2, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, heads, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, heads, sq, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, heads, sq, d)), jnp.float32)

    def run(stages, backend):
        return np.asarray(flash_attention(
            q, k, v, kind=kind, window=window, block_q=block,
            block_k=block, num_stages=stages, backend=backend))

    ref = run(1, "gpu-interpret")
    for stages in (2, 3, 4):
        assert np.array_equal(ref, run(stages, "gpu-interpret")), stages
    # the TPU structure has no KV FIFO; the knob must still be accepted
    tref = run(1, "tpu-interpret")
    assert np.array_equal(tref, run(2, "tpu-interpret"))


# ---------------------------------------------------------------------------
# sharded halo-compute overlap (subprocess, forced 8-device mesh)
# ---------------------------------------------------------------------------

def test_sharded_overlap_impulse_bit_identical():
    # An impulse seeded on the bottom row (dense in the gasket, and on
    # the last device's slab) reaches across every slab boundary within
    # steps x fuse; stages=2 routes boundary steps through the phase
    # tables + ghost strips concurrently with interior compute, and
    # must reproduce the single-device synchronous run exactly.  The
    # bounding lowering exercises the sync fallback under stages=2.
    out = run_sub("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import fractal as F
    from repro.core.compact import CompactLayout
    from repro.core.domain import make_fractal_domain
    from repro.kernels import ops

    n, block, steps, fuse = 64, 8, 6, 3
    state = np.zeros((n, n), np.float32)
    state[n - 1, 0] = 1.0
    a0 = jnp.asarray(state * F.membership_grid(n))
    lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                            n // block))
    checked = 0
    for D in (2, 8):
        mesh = jax.make_mesh((D,), ("data",))
        for gm in ("closed_form", "prefetch_lut", "bounding"):
            for storage in ("compact", "embedded"):
                a = lay.pack(a0, block) if storage == "compact" else a0
                b = jnp.zeros_like(a)
                kw = dict(fuse=fuse, rule="parity", block=block,
                          grid_mode=gm, storage=storage, n=n,
                          donate=False)
                ref = np.asarray(ops.ca_run(a, b, steps, num_stages=1,
                                            **kw))
                assert ref.any()
                for stages in (1, 2):
                    got = np.asarray(ops.ca_run(
                        a, b, steps, mesh=mesh, num_stages=stages,
                        **kw))
                    assert np.array_equal(got, ref), \\
                        (D, gm, storage, stages)
                    checked += 1
    print("OK", checked)
    """)
    assert "OK 24" in out
