"""End-to-end behaviour tests for the whole system: train -> checkpoint
-> preempt/restart -> serve, on the quickstart arch."""
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.launch.serve import ServeConfig, Server
from repro.launch.train import TrainConfig, Trainer
from repro.optim.adamw import AdamWConfig


def test_train_restart_serve_roundtrip(tmp_path):
    cfg = get_config("quickstart", smoke=True)
    tcfg = TrainConfig(steps=8, log_every=100, ckpt_every=4,
                       ckpt_dir=str(tmp_path),
                       optimizer=AdamWConfig(lr=1e-3, total_steps=8))

    def pipe():
        return SyntheticPipeline(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))

    # phase 1: train to step 8 (checkpoints at 4 and exit)
    t1 = Trainer(cfg, tcfg)
    params1, _, hist1 = t1.run(pipe())
    assert len(hist1) == 8

    # phase 2: restart -- must resume at 8, train 4 more
    tcfg2 = TrainConfig(steps=12, log_every=100, ckpt_dir=str(tmp_path),
                        optimizer=AdamWConfig(lr=1e-3, total_steps=12))
    p2 = pipe()
    t2 = Trainer(cfg, tcfg2)
    step, params2, _ = t2.restore_or_init(p2)
    assert step == 8
    params2, _, hist2 = t2.run(p2)
    assert len(hist2) == 4  # only the remaining steps

    # phase 3: serve from the final checkpoint
    from repro.checkpoint.manager import CheckpointManager
    from repro.models import abstract_init
    mgr = CheckpointManager(str(tmp_path))
    _, params, _, _ = mgr.restore(None, abstract_init(cfg))
    server = Server(cfg, params, ServeConfig(max_len=48, temperature=0.0))
    out = server.generate(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)),
        max_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_loss_improves_on_learnable_data(tmp_path):
    cfg = get_config("quickstart", smoke=True)
    tcfg = TrainConfig(steps=25, log_every=100, ckpt_dir=str(tmp_path),
                       optimizer=AdamWConfig(lr=5e-3, warmup_steps=3,
                                             total_steps=25))
    pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, global_batch=4))
    _, _, hist = Trainer(cfg, tcfg).run(pipe)
    assert np.mean([h["loss"] for h in hist[-5:]]) < \
        np.mean([h["loss"] for h in hist[:5]])
