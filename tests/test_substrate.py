"""Training/serving substrate: optimizer, data pipeline determinism,
checkpoint atomicity + elastic restore, end-to-end loss decrease, serve
generate, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.launch.serve import ServeConfig, Server
from repro.launch.train import TrainConfig, Trainer
from repro.models import init
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim import compression


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, schedule="constant", clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0]), "norm_scale": jnp.ones(2)}
    state = adamw.init_state(params, cfg)
    target = jnp.asarray([1.0, 1.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(
            (p["norm_scale"] - 1) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, m = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_weight_decay_mask():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                      schedule="constant")
    params = {"w": jnp.ones(2), "norm_scale": jnp.ones(2)}
    state = adamw.init_state(params, cfg)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw.apply_updates(params, zero_g, state, cfg)
    assert float(jnp.abs(p2["w"] - 1).sum()) > 0       # decayed
    assert float(jnp.abs(p2["norm_scale"] - 1).sum()) == 0  # exempt


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine", min_lr_frac=0.1)
    lrs = [float(adamw.schedule_lr(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 99]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] > lrs[3] > lrs[4]          # decay
    assert lrs[4] >= 0.1 * 0.99              # floor


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_resume():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=7)
    p1 = SyntheticPipeline(cfg)
    batches = [p1.next_batch() for _ in range(5)]
    state = p1.state_dict()
    more = [p1.next_batch() for _ in range(2)]
    # resume from state: identical continuation
    p2 = SyntheticPipeline(cfg)
    p2.load_state_dict(state)
    again = [p2.next_batch() for _ in range(2)]
    for a, b in zip(more, again):
        np.testing.assert_array_equal(a["inputs"], b["inputs"])
    # restart from scratch: identical prefix
    p3 = SyntheticPipeline(cfg)
    np.testing.assert_array_equal(p3.next_batch()["inputs"],
                                  batches[0]["inputs"])


def test_pipeline_host_sharding():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=7)
    hosts = [SyntheticPipeline(cfg, host_index=i, host_count=2)
             for i in range(2)]
    b0, b1 = hosts[0].next_batch(), hosts[1].next_batch()
    assert b0["inputs"].shape == (4, 16)
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_pipeline_labels_shifted():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=1)
    b = SyntheticPipeline(cfg).next_batch()
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones(4, jnp.int32)}}
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    for step in (1, 2, 3):
        mgr.save(step, params, opt, {"step": step * 10})
    assert mgr.all_steps() == [2, 3]  # GC keeps 2
    step, p2, o2, meta = mgr.restore(None, params, opt)
    assert step == 3 and meta["data_state"]["step"] == 30
    np.testing.assert_array_equal(p2["a"], params["a"])
    np.testing.assert_array_equal(o2["m"]["nested"]["b"],
                                  opt["m"]["nested"]["b"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"a": jnp.ones((3, 3))})


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"a": jnp.ones(3)})
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# trainer end-to-end (CPU, no mesh)
# ---------------------------------------------------------------------------

def test_trainer_loss_decreases(tmp_path):
    cfg = get_config("quickstart", smoke=True)
    tcfg = TrainConfig(steps=30, log_every=100, ckpt_dir=str(tmp_path),
                       optimizer=AdamWConfig(lr=1e-2, warmup_steps=3,
                                             total_steps=30))
    pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, global_batch=4))
    trainer = Trainer(cfg, tcfg)
    params, opt_state, history = trainer.run(pipe)
    first = np.mean([h["loss"] for h in history[:5]])
    last = np.mean([h["loss"] for h in history[-5:]])
    assert last < first - 0.5, (first, last)


def test_trainer_restart_resumes_step(tmp_path):
    cfg = get_config("quickstart", smoke=True)
    tcfg = TrainConfig(steps=6, log_every=100, ckpt_dir=str(tmp_path),
                       optimizer=AdamWConfig(lr=1e-3, total_steps=6))
    pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=2))
    Trainer(cfg, tcfg).run(pipe)
    # second run restores at step 6 and does nothing more
    pipe2 = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=32, global_batch=2))
    t2 = Trainer(cfg, tcfg)
    step, _, _ = t2.restore_or_init(pipe2)
    assert step == 6
    assert pipe2.step == pipe.step


def test_trainer_grad_accum_matches_full_batch(tmp_path):
    cfg = get_config("quickstart", smoke=True).replace(vocab_size=256)
    pipe = SyntheticPipeline(DataConfig(vocab_size=256, seq_len=32,
                                        global_batch=4))
    batch = pipe.next_batch()
    from repro.launch.train import make_train_step
    from repro.optim.adamw import init_state
    params = init(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=0.0, warmup_steps=0, schedule="constant",
                      weight_decay=0.0)
    s1 = make_train_step(cfg, TrainConfig(grad_accum=1, optimizer=opt))
    s2 = make_train_step(cfg, TrainConfig(grad_accum=2, optimizer=opt))
    state = init_state(params, opt)
    b1 = {k: jnp.asarray(v) for k, v in batch.items()}
    b2 = {k: jnp.asarray(v).reshape((2, 2) + v.shape[1:])
          for k, v in batch.items()}
    _, _, m1 = s1(params, state, b1)
    _, _, m2 = s2(params, state, b2)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_server_generates_and_is_greedy_deterministic():
    cfg = get_config("quickstart", smoke=True)
    params = init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, ServeConfig(max_len=48, temperature=0.0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16))
    out1 = server.generate(prompts, max_new=8)
    out2 = server.generate(prompts, max_new=8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)


def test_server_matches_stepwise_decode():
    """Greedy generate == manually feeding argmax tokens through logits."""
    from repro.models import logits_fn
    cfg = get_config("quickstart", smoke=True)
    params = init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, ServeConfig(max_len=24, temperature=0.0))
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (1, 8))
    out = server.generate(prompts, max_new=4)
    seq = list(prompts[0])
    for _ in range(4):
        logits, _ = logits_fn(params, jnp.asarray([seq]), cfg)
        seq.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(out[0], np.asarray(seq[8:]))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_small():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    y = compression.compress_roundtrip(x)
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert rel < 0.01


def test_error_feedback_is_unbiased_over_steps():
    # with error feedback, the accumulated compressed sum tracks the true sum
    rng = np.random.default_rng(4)
    residual = jnp.zeros(256)
    total_true = jnp.zeros(256)
    total_comp = jnp.zeros(256)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
        gf = g + residual
        q, s = compression.quantize_int8(gf)
        deq = compression.dequantize_int8(q, s, gf.shape)
        residual = gf - deq
        total_true += g
        total_comp += deq
    err = float(jnp.linalg.norm(total_true - total_comp))
    # the residual bounds the error independent of step count
    assert err < float(jnp.linalg.norm(residual)) + 1e-3
