"""Tests for the block-space domain abstraction (repro.core.domain)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core import fractal as F
from repro.core.domain import (BandDomain, BoundingBoxDomain,
                               GeneralizedFractalDomain, SierpinskiDomain,
                               TriangularDomain, make_attention_domain)


@pytest.mark.parametrize("n_b", [1, 2, 4, 8, 16, 64])
def test_sierpinski_domain_enumeration(n_b):
    d = SierpinskiDomain(n_b)
    c = d.coords_host()
    assert c.shape == (d.num_blocks, 2)
    assert len({tuple(r) for r in c}) == d.num_blocks
    for x, y in c:
        assert F.is_member(int(x), int(y), n_b)
        assert bool(d.contains(int(x), int(y)))


@pytest.mark.parametrize("n_b", [4, 16, 64, 256])
def test_sierpinski_space_efficiency_matches_theorem(n_b):
    # Theorem 2: compact grid uses n**H of the n**2 bounding-box blocks.
    d = SierpinskiDomain(n_b)
    assert d.num_blocks == n_b ** 2 * d.space_efficiency()
    assert d.num_blocks == F.gasket_volume(n_b)


@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 17, 64, 257])
def test_triangular_enumeration(m):
    t = TriangularDomain(m)
    c = t.coords_host()
    want = {(k, q) for q in range(m) for k in range(q + 1)}
    assert {tuple(r) for r in c} == want
    assert t.num_blocks == len(want)


@given(st.integers(1, 2000), st.data())
@settings(max_examples=200, deadline=None)
def test_property_triangular_decode(m, data):
    t = TriangularDomain(m)
    i = data.draw(st.integers(0, t.num_blocks - 1))
    k, q = t.block_coords(i)
    k, q = int(k), int(q)
    assert 0 <= k <= q < m
    assert q * (q + 1) // 2 + k == i  # exact inverse of the enumeration


@pytest.mark.parametrize("m,w", [(8, 3), (8, 8), (5, 1), (16, 4), (7, 9),
                                 (64, 8), (1, 1)])
def test_band_enumeration(m, w):
    b = BandDomain(m, w)
    c = b.coords_host()
    weff = min(w, m)
    want = {(k, q) for q in range(m)
            for k in range(max(0, q - weff + 1), q + 1)}
    assert {tuple(r) for r in c} == want
    assert b.num_blocks == len(want)
    for k, q in want:
        assert bool(b.contains(k, q))


def test_bounding_box_domain():
    bb = BoundingBoxDomain(4, 3)
    c = bb.coords_host()
    assert {tuple(r) for r in c} == {(x, y) for y in range(3) for x in range(4)}
    assert bb.space_efficiency() == 1.0


def test_bounding_box_with_membership():
    n = 8
    bb = BoundingBoxDomain(n, n, member=lambda x, y: F.is_member(x, y, n))
    kept = [(x, y) for x, y in bb.coords_host() if bool(bb.contains(int(x), int(y)))]
    assert len(kept) == F.gasket_volume(n)


def test_generalized_fractal_domain():
    d = GeneralizedFractalDomain(F.VICSEK, 9)
    c = d.coords_host()
    grid = F.VICSEK.membership_grid(9)
    assert len(c) == 25
    assert all(grid[y, x] for x, y in c)


def test_attention_domain_factory():
    assert isinstance(make_attention_domain("causal", 8, 8), TriangularDomain)
    assert isinstance(make_attention_domain("local", 8, 8, 2), BandDomain)
    assert isinstance(make_attention_domain("full", 4, 8), BoundingBoxDomain)
    with pytest.raises(ValueError):
        make_attention_domain("causal", 4, 8)
    with pytest.raises(ValueError):
        make_attention_domain("nope", 4, 4)


def test_space_efficiency_ordering():
    # narrow band << fractal << triangular << bounding box, for big m
    s = SierpinskiDomain(256).space_efficiency()
    t = TriangularDomain(256).space_efficiency()
    b = BandDomain(256, 16).space_efficiency()
    assert b < s < t < 1.0
