"""GridPlan equivalence tests: for every registered domain the three
lowerings must agree with each other and with the host oracle
enumeration, at several scale levels / subdivision factors.

Layers covered:
  * host: coords_host == brute-force membership enumeration,
  * traced: closed-form block_coords under jit == host table (the table
    IS the prefetch_lut payload, so this is closed_form == prefetch_lut
    at the decode level),
  * kernel: the Pallas write / CA / flash kernels produce bit-identical
    outputs under all three lowerings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractal as F
from repro.core.domain import (GeneralizedFractalDomain, SierpinskiDomain,
                               make_fractal_domain)
from repro.core.plan import (LOWERINGS, GridPlan, normalize_lowering,
                             registered_domains, xla_schedule)
from repro.kernels import ops, ref

RNG = np.random.default_rng(3)


def _all_domains():
    """Every registered family at several r / m."""
    out = []
    for size in ("small", "medium"):
        for name, dom in registered_domains(size).items():
            out.append(pytest.param(dom, id=f"{name}-{size}"))
    return out


def _oracle_set(dom):
    nbx, nby = dom.bounding_box
    return {(x, y) for y in range(nby) for x in range(nbx)
            if dom.always_member or bool(dom.contains(x, y))}


# ---------------------------------------------------------------------------
# decode-level equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dom", _all_domains())
def test_coords_host_matches_oracle(dom):
    c = dom.coords_host()
    assert c.shape == (dom.num_blocks, 2)
    got = {tuple(r) for r in c}
    assert len(got) == dom.num_blocks  # enumeration is injective
    assert got == _oracle_set(dom)


@pytest.mark.parametrize("dom", _all_domains())
def test_closed_form_decode_equals_lut_table(dom):
    # the traced closed-form decode must reproduce the host table that
    # the prefetch_lut lowering ships to the scalar core
    i = jnp.arange(dom.num_blocks, dtype=jnp.int32)
    bx, by = jax.jit(dom.block_coords)(i)
    got = np.stack([np.asarray(bx), np.asarray(by)], -1)
    np.testing.assert_array_equal(got, dom.coords_host())


@pytest.mark.parametrize("dom", _all_domains())
def test_grid_shapes_per_lowering(dom):
    nbx, nby = dom.bounding_box
    for lowering, want in (("closed_form", (dom.num_blocks,)),
                           ("prefetch_lut", (dom.num_blocks,)),
                           ("bounding", (nby, nbx)),
                           ("mma", (dom.num_blocks,)),
                           ("compact", (dom.num_blocks,))):
        plan = GridPlan(dom, lowering, batch_dims=(3,))
        assert plan.grid == (3,) + want
        # prefetch_lut always binds its table; mma does only on
        # block-indexed structures (the gpu structure chains in-kernel)
        assert plan.num_scalar_prefetch == int(plan._table_backed)
        if lowering == "prefetch_lut":
            assert plan._table_backed
        elif lowering == "mma":
            assert plan._table_backed == plan.target.block_indexed
        else:
            assert not plan._table_backed


@pytest.mark.parametrize("dom", _all_domains())
def test_row_extents_match_enumeration(dom):
    ext = GridPlan(dom).row_extents()
    members = _oracle_set(dom)
    nbx, nby = dom.bounding_box
    for by in range(nby):
        xs = [x for (x, y) in members if y == by]
        if xs:
            assert ext[by, 0] == min(xs) and ext[by, 1] == max(xs)
        else:
            assert ext[by, 1] < ext[by, 0]


def test_coords_host_is_memoized():
    d = SierpinskiDomain(16)
    assert d.coords_host() is d.coords_host()


def test_membership_grid_is_memoized():
    spec = F.FractalSpec("test-gasket", k=3, m=2,
                         offsets=((0, 0), (0, 1), (1, 1)))
    assert spec.membership_grid(8) is spec.membership_grid(8)


@pytest.mark.parametrize("spec", [F.SIERPINSKI, F.CARPET, F.VICSEK])
@pytest.mark.parametrize("r", [1, 2, 3])
def test_generalized_is_member_matches_dense_grid(spec, r):
    n = spec.m ** r
    y, x = np.mgrid[0:n, 0:n]
    got = np.asarray(spec.is_member(jnp.asarray(x), jnp.asarray(y), n))
    np.testing.assert_array_equal(got, spec.membership_grid(n))


def test_generalized_contains_is_traceable():
    # the digit-test contains must trace (no dense-grid constant capture)
    d = GeneralizedFractalDomain(F.VICSEK, 9)
    got = jax.jit(d.contains)(jnp.arange(9)[None, :], jnp.arange(9)[:, None])
    np.testing.assert_array_equal(np.asarray(got),
                                  F.VICSEK.membership_grid(9))


def test_lowering_names():
    assert normalize_lowering("compact") == "closed_form"
    with pytest.raises(ValueError):
        normalize_lowering("nope")
    assert xla_schedule("bounding") == "dense"
    assert xla_schedule("prefetch_lut") == "triangular"
    assert xla_schedule("compact") == "triangular"
    assert xla_schedule("mma") == "triangular"


# ---------------------------------------------------------------------------
# kernel-level equivalence (bit-identical across lowerings)
# ---------------------------------------------------------------------------

_FRACTAL_CASES = [("sierpinski-gasket", 16, 4), ("sierpinski-gasket", 64, 8),
                  ("sierpinski-carpet", 9, 3), ("sierpinski-carpet", 27, 3),
                  ("vicsek-cross", 9, 3), ("vicsek-cross", 27, 9)]


def _fractal_state(fractal, n):
    dom = make_fractal_domain(fractal, n)
    y, x = np.mgrid[0:n, 0:n]
    mask = np.asarray(dom.cell_member(x, y, n))
    return jnp.asarray(np.where(mask, RNG.normal(size=(n, n)), 0),
                       jnp.float32), mask


@pytest.mark.parametrize("fractal,n,block", _FRACTAL_CASES)
def test_write_lowerings_bit_identical(fractal, n, block):
    m, mask = _fractal_state(fractal, n)
    outs = [np.asarray(ops.sierpinski_write(
        m, 7.0, block=block, grid_mode=gm, fractal=fractal))
        for gm in LOWERINGS]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    want = np.where(mask, np.float32(7.0), np.asarray(m))
    np.testing.assert_array_equal(outs[0], want)


@pytest.mark.parametrize("fractal,n,block", _FRACTAL_CASES)
def test_sum_lowerings_agree(fractal, n, block):
    m, mask = _fractal_state(fractal, n)
    sums = [float(ops.sierpinski_sum(m, block=block, grid_mode=gm,
                                     fractal=fractal))
            for gm in LOWERINGS]
    assert sums[0] == sums[1]  # identical schedule -> bit-identical
    assert sums[0] == sums[3]  # mma walks the same compact schedule
    np.testing.assert_allclose(sums[2], sums[0], rtol=1e-6)
    np.testing.assert_allclose(
        sums[0], float(np.asarray(m)[mask].sum()), rtol=1e-5)


@pytest.mark.parametrize("fractal,n,block",
                         [("sierpinski-gasket", 32, 8),
                          ("sierpinski-carpet", 27, 3),
                          ("vicsek-cross", 27, 3)])
@pytest.mark.parametrize("rule", ["parity", "diffusion"])
def test_ca_lowerings_bit_identical(fractal, n, block, rule):
    m, mask = _fractal_state(fractal, n)
    if rule == "parity":
        m = jnp.asarray(np.where(mask, RNG.integers(0, 2, (n, n)), 0),
                        jnp.float32)
    outs = [np.asarray(ops.ca_step(m, jnp.zeros_like(m), rule=rule,
                                   block=block, grid_mode=gm,
                                   fractal=fractal))
            for gm in LOWERINGS]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    assert (outs[0][~mask] == 0).all()


@pytest.mark.parametrize("kind,kw", [("causal", {}),
                                     ("local", {"window": 128}),
                                     ("full", {})])
def test_flash_lowerings_bit_identical(kind, kw):
    q = jnp.asarray(RNG.normal(size=(1, 4, 256, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 256, 32)), jnp.float32)
    outs = [np.asarray(ops.flash_attention(q, k, v, kind=kind, block_q=64,
                                           block_k=64, grid_mode=gm, **kw))
            for gm in LOWERINGS]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    want = np.asarray(ref.attention_ref(q, k, v, kind, **kw))
    np.testing.assert_allclose(outs[0], want, rtol=2e-5, atol=2e-5)


def test_flash_full_compact_enumeration():
    # "full" now runs under the compact lowerings too (row-major
    # bounding-box domain), including rectangular grids
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 384, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 1, 384, 32)), jnp.float32)
    outs = [np.asarray(ops.flash_attention(q, k, v, kind="full", block_q=64,
                                           block_k=128, grid_mode=gm))
            for gm in LOWERINGS]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


# ---------------------------------------------------------------------------
# XLA schedule plumbing
# ---------------------------------------------------------------------------

def test_xla_flash_accepts_lowering_names():
    from repro.models.attention import flash_attention_xla
    q = jnp.asarray(RNG.normal(size=(1, 2, 256, 32)), jnp.float32)
    dense = flash_attention_xla(q, q, q, kind="causal", chunk=64,
                                schedule="bounding")
    tri = flash_attention_xla(q, q, q, kind="causal", chunk=64,
                              schedule="prefetch_lut")
    np.testing.assert_allclose(np.asarray(dense), np.asarray(tri),
                               rtol=2e-5, atol=2e-5)


def test_config_grid_lowering_resolution():
    from repro.models.config import ModelConfig
    cfg = ModelConfig()
    assert cfg.attn_schedule_resolved == "dense"
    assert cfg.grid_mode == "closed_form"
    cfg2 = cfg.replace(grid_lowering="prefetch_lut")
    assert cfg2.attn_schedule_resolved == "triangular"
    assert cfg2.grid_mode == "prefetch_lut"
    cfg3 = cfg.replace(grid_lowering="bounding")
    assert cfg3.attn_schedule_resolved == "dense"
