"""MoE dispatch vs dense oracle; MLA prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig

RNG = np.random.default_rng(13)


def _moe_cfg(**kw):
    base = dict(d_model=32, d_ff_expert=64, n_experts=8, top_k=2, moe=True,
                n_shared_experts=1, capacity_factor=8.0, dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("top_k,shared", [(1, 0), (2, 1), (4, 2)])
def test_moe_matches_dense_oracle(top_k, shared):
    cfg = _moe_cfg(top_k=top_k, n_shared_experts=shared)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, 32)), jnp.float32)
    out, aux = moe_lib.moe_block(p, x, cfg)
    want = moe_lib.moe_block_dense_ref(p, x, cfg)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_are_bounded():
    cfg = _moe_cfg(capacity_factor=1.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(4, 32, 32)), jnp.float32)
    out, _ = moe_lib.moe_block(p, x, cfg)
    dense = moe_lib.moe_block_dense_ref(p, x, cfg)
    # dropped tokens lose routed mass but keep shared-expert output
    assert np.isfinite(np.asarray(out)).all()
    # most tokens should still match the oracle
    close = np.isclose(np.asarray(out), np.asarray(dense),
                       rtol=1e-3, atol=1e-4).all(axis=-1)
    assert close.mean() > 0.5


def test_moe_grads_flow_to_all_parts():
    cfg = _moe_cfg()
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, 32)), jnp.float32)

    def loss(p):
        out, aux = moe_lib.moe_block(p, x, cfg)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_leaves_with_path(g):
        assert np.isfinite(np.asarray(leaf)).all()
    # router must receive gradient (through gates and aux loss)
    assert float(jnp.abs(g["router"]).sum()) > 0


def test_moe_aux_loss_balanced_vs_collapsed():
    cfg = _moe_cfg(router_aux_weight=1.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    # collapsed router: all tokens to expert 0
    p_bad = dict(p)
    p_bad["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jnp.asarray(RNG.normal(size=(2, 32, 32)), jnp.float32)
    _, aux_ok = moe_lib.moe_block(p, x, cfg)
    _, aux_bad = moe_lib.moe_block(p_bad, x, cfg)
    assert float(aux_bad) > float(aux_ok)


def _mla_cfg():
    return ModelConfig(d_model=64, n_heads=4, q_lora_rank=32,
                       kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                       v_head_dim=16, use_mla=True, dtype="float32",
                       param_dtype="float32")


def test_mla_decode_matches_prefill():
    cfg = _mla_cfg()
    p = mla_lib.mla_init(jax.random.PRNGKey(1), cfg)
    S = 12
    x = jnp.asarray(RNG.normal(size=(2, S, 64)), jnp.float32)
    y_all = mla_lib.mla_block(p, x, cfg, jnp.arange(S))
    cache = (jnp.zeros((2, S, cfg.kv_lora_rank), jnp.float32),
             jnp.zeros((2, S, cfg.qk_rope_dim), jnp.float32))
    ys = []
    for t in range(S):
        y, cache = mla_lib.mla_decode(p, x[:, t:t + 1], cfg, cache, t)
        ys.append(y)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_all,
                               rtol=1e-4, atol=1e-4)


def test_mla_cache_is_compressed():
    cfg = _mla_cfg()
    # latent cache size per token = kv_lora + qk_rope << 2*H*hd
    latent = cfg.kv_lora_rank + cfg.qk_rope_dim
    full = 2 * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
    assert latent < full / 4


def test_mla_grads_finite():
    cfg = _mla_cfg()
    p = mla_lib.mla_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 8, 64)), jnp.float32)
    g = jax.grad(lambda p: jnp.sum(
        mla_lib.mla_block(p, x, cfg, jnp.arange(8)) ** 2))(p)
    assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
