"""Chaos harness + guarded runtime: fault classification, guarded
retries, degradation ladder, EOS masking, replay-deterministic
sampling, decode-state checkpoint/resume, and the chaos matrix's
recovered-bit-identical guarantees."""
import json
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.runtime.chaos import (ChaosInjector, FaultPlan,  # noqa: E402
                                 FaultSpec, corrupt_tune_cache,
                                 tear_checkpoint)
from repro.runtime.guard import (Backoff, DegradationLadder,  # noqa: E402
                                 FailureReport, GuardedCall,
                                 GuardExhausted, ServerState,
                                 TransientFault, ValidationError,
                                 classify_error, sample_key, spot_check,
                                 validate_finite)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# classification / backoff / validation
# ---------------------------------------------------------------------------

def test_classify_error_taxonomy():
    from jax.errors import JaxRuntimeError
    assert classify_error(TransientFault("x")) == "transient"
    assert classify_error(ValidationError("nan")) == "transient"
    assert classify_error(TimeoutError()) == "transient"
    assert classify_error(ConnectionError()) == "transient"
    # XLA runtime errors: transient unless compile/shape-family
    assert classify_error(
        JaxRuntimeError("UNAVAILABLE: socket closed")) == "transient"
    assert classify_error(
        JaxRuntimeError("INVALID_ARGUMENT: shape mismatch")) == "fatal"
    # generic RuntimeErrors: fatal unless a transient marker
    assert classify_error(RuntimeError("RESOURCE_EXHAUSTED: oom")) == \
        "transient"
    assert classify_error(RuntimeError("boom")) == "fatal"
    # programming errors never retry
    assert classify_error(ValueError("shape")) == "fatal"
    assert classify_error(TypeError()) == "fatal"
    assert classify_error(KeyError("k")) == "fatal"


def test_backoff_deterministic_and_bounded():
    a = Backoff(base_s=0.1, factor=2.0, max_s=0.5, jitter=0.5, seed=7)
    b = Backoff(base_s=0.1, factor=2.0, max_s=0.5, jitter=0.5, seed=7)
    da = [a.delay(i) for i in range(1, 8)]
    db = [b.delay(i) for i in range(1, 8)]
    assert da == db                       # seeded => replayable schedule
    for i, d in enumerate(da, start=1):
        raw = min(0.1 * 2.0 ** (i - 1), 0.5)
        assert 0.5 * raw <= d <= 1.5 * raw
    c = Backoff(base_s=0.1, jitter=0.5, seed=8)
    assert [c.delay(i) for i in range(1, 8)] != da  # decorrelated


def test_validate_finite_and_spot_check():
    validate_finite({"a": jnp.ones(3), "b": np.arange(4)})
    with pytest.raises(ValidationError, match="non-finite"):
        validate_finite({"x": {"y": np.array([1.0, np.nan])}})
    with pytest.raises(ValidationError):
        validate_finite(np.array([np.inf]))
    ref = {"w": np.arange(6, dtype=np.float32)}
    spot_check(ref)(dict(ref))
    with pytest.raises(ValidationError, match="differs"):
        spot_check(ref)({"w": np.arange(6, dtype=np.float32) + 1})


# ---------------------------------------------------------------------------
# GuardedCall
# ---------------------------------------------------------------------------

def _no_backoff():
    return Backoff(base_s=0.0, jitter=0.0)


def test_guarded_call_retries_transient_then_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientFault("injected")
        return jnp.asarray(42.0)

    g = GuardedCall(flaky, "step", retries=3, backoff=_no_backoff())
    assert float(g()) == 42.0
    assert calls["n"] == 3
    assert g.recoveries == 1
    kinds = [e.kind for e in g.events]
    assert kinds == ["transient", "retry", "transient", "retry", "ok"]


def test_guarded_call_fatal_raises_immediately_with_report(tmp_path):
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("shape mismatch (8,) vs (4,)")

    g = GuardedCall(bad, "decode", retries=5, backoff=_no_backoff())
    with pytest.raises(GuardExhausted) as ei:
        g()
    assert calls["n"] == 1                # fatal => no retry
    report = ei.value.report
    assert report.classification == "fatal"
    assert report.error_type == "ValueError"
    path = report.write(str(tmp_path / "r.json"))
    loaded = json.load(open(path))
    assert loaded["name"] == "decode"
    assert loaded["events"][0]["kind"] == "fatal"


def test_guarded_call_exhaustion_report():
    def always():
        raise TransientFault("still down")

    g = GuardedCall(always, "step", retries=2, backoff=_no_backoff())
    with pytest.raises(GuardExhausted) as ei:
        g()
    assert ei.value.report.classification == "exhausted"
    assert ei.value.report.attempts == 3  # 1 initial + 2 retries


def test_guarded_call_validation_failure_retries():
    calls = {"n": 0}

    def nan_once():
        calls["n"] += 1
        return jnp.asarray(np.nan if calls["n"] == 1 else 1.0)

    fixed = []
    g = GuardedCall(nan_once, "step", retries=2, backoff=_no_backoff(),
                    validators=[validate_finite],
                    before_retry=lambda: fixed.append(True))
    assert float(g()) == 1.0
    assert fixed == [True]                # before_retry hook ran
    assert [e.kind for e in g.events][0] == "validation"


def test_guarded_call_deadline_recorded_and_enforced():
    g = GuardedCall(lambda: 1, "slow", retries=0, deadline_s=-1.0,
                    backoff=_no_backoff())
    assert g() == 1                       # recorded, not enforced
    assert any(e.kind == "deadline" for e in g.events)
    g2 = GuardedCall(lambda: 1, "slow", retries=0, deadline_s=-1.0,
                     enforce_deadline=True, backoff=_no_backoff())
    with pytest.raises(GuardExhausted):
        g2()


# ---------------------------------------------------------------------------
# FaultPlan / ladder / sampling keys
# ---------------------------------------------------------------------------

def test_fault_plan_seeded_replayable_and_json_roundtrip():
    p1 = FaultPlan.from_seed(11, sites=("a", "b"), n_faults=4, horizon=9)
    p2 = FaultPlan.from_seed(11, sites=("a", "b"), n_faults=4, horizon=9)
    assert p1.to_json() == p2.to_json()
    p3 = FaultPlan.from_json(p1.to_json())
    assert p3.to_json() == p1.to_json()
    assert FaultPlan.from_seed(12, sites=("a", "b"), n_faults=4,
                               horizon=9).to_json() != p1.to_json()
    plan = FaultPlan(0, [FaultSpec("transient_error", "s", 2, rung=0)])
    assert plan.for_call("s", 2, rung=0)
    assert not plan.for_call("s", 2, rung=1)   # rung-conditioned
    assert plan.for_call("s", 2, rung=None)    # unconditioned caller
    assert not plan.for_call("s", 3, rung=0)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor_strike", "s", 0)


def test_degradation_ladder_transitions():
    seen = []
    lad = DegradationLadder([{"decode": "blockspace"}, {"decode": "xla"},
                             {"decode": "cpu"}], on_transition=seen.append)
    assert lad.current() == {"decode": "blockspace"}
    assert not lad.degraded
    assert lad.step_down("nan storm")
    assert lad.level == 1 and lad.degraded
    assert lad.step_down("still failing")
    assert lad.exhausted()
    assert not lad.step_down("bottom")     # nothing left
    assert len(lad.transitions) == 2 == len(seen)
    assert lad.transitions[0]["reason"] == "nan storm"
    assert lad.transitions[0]["to"] == {"decode": "xla"}


def test_sample_key_pure_function_of_coordinates():
    base = jax.random.PRNGKey(3)
    k1 = sample_key(base, pos=7, batch=4)
    k2 = sample_key(base, pos=7, batch=4)
    assert np.array_equal(np.asarray(k1), np.asarray(k2))
    assert k1.shape[0] == 4
    assert not np.array_equal(np.asarray(k1),
                              np.asarray(sample_key(base, 8, 4)))
    # distinct per slot
    assert len({tuple(np.asarray(r)) for r in k1}) == 4


# ---------------------------------------------------------------------------
# fault_tolerance surfaces (satellite: Heartbeat / PreemptionGuard /
# retry_step)
# ---------------------------------------------------------------------------

def test_heartbeat_straggle_callback_fires():
    from repro.distributed.fault_tolerance import Heartbeat
    seen = []
    hb = Heartbeat(deadline_s=0.0, on_straggle=seen.append)
    dt = hb.beat()
    assert hb.straggle_events == 1
    assert seen and seen[0] == dt
    hb2 = Heartbeat(deadline_s=1e6)
    hb2.beat()
    assert hb2.straggle_events == 0


def test_preemption_guard_install_restore_and_fire():
    from repro.distributed.fault_tolerance import PreemptionGuard
    before = signal.getsignal(signal.SIGTERM)
    with PreemptionGuard() as g:
        assert signal.getsignal(signal.SIGTERM) != before
        assert not g.fired
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.fired
    assert signal.getsignal(signal.SIGTERM) == before


def test_retry_step_classifies_transient_vs_fatal():
    from repro.distributed.fault_tolerance import retry_step
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("UNAVAILABLE: preempted")
        return "ok"

    assert retry_step(flaky, retries=3, backoff_s=0.25,
                      sleep=slept.append) == "ok"
    assert calls["n"] == 3
    assert len(slept) == 2
    assert all(s > 0 for s in slept)      # jittered backoff slept twice

    def fatal():
        calls["n"] += 1
        raise ValueError("bad shape")

    calls["n"] = 0
    with pytest.raises(ValueError):
        retry_step(fatal, retries=5, sleep=slept.append)
    assert calls["n"] == 1                # fatal => no retry


def test_retry_step_exhaustion_reraises():
    from repro.distributed.fault_tolerance import retry_step
    calls = {"n": 0}

    def down():
        calls["n"] += 1
        raise TransientFault("net down")

    with pytest.raises(TransientFault):
        retry_step(down, retries=2, sleep=lambda s: None)
    assert calls["n"] == 3


# ---------------------------------------------------------------------------
# checkpoint torn-write recovery (satellite)
# ---------------------------------------------------------------------------

def test_checkpoint_torn_write_recovery(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=3)
    p1 = {"w": np.arange(8, dtype=np.float32)}
    p2 = {"w": np.arange(8, dtype=np.float32) * 2}
    mgr.save(1, p1)
    mgr.save(2, p2)
    tear_checkpoint(str(tmp_path))
    # auto-select falls back past the torn latest step
    step, params, _, meta = mgr.restore(None, {"w": np.zeros(8,
                                                            np.float32)})
    assert step == 1
    assert np.array_equal(np.asarray(params["w"]), p1["w"])
    assert meta["skipped_torn_steps"] == [2]
    # an explicitly requested torn step is never silently substituted
    with pytest.raises(Exception):
        mgr.restore(2, {"w": np.zeros(8, np.float32)})
    # the next save clears the torn .tmp debris
    mgr.save(3, p2)
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    step, params, _, meta = mgr.restore(None, {"w": np.zeros(8,
                                                             np.float32)})
    assert step == 3 and "skipped_torn_steps" not in meta


def test_checkpoint_all_torn_raises(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"w": np.zeros(4, np.float32)})
    tear_checkpoint(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="torn"):
        mgr.restore(None, {"w": np.zeros(4, np.float32)})


def test_tune_cache_rejects_corrupt_entry(tmp_path, monkeypatch):
    from repro.core import tune
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv(tune.CACHE_ENV, path)
    params = {"fractal": "sierpinski-gasket", "n": 16, "block": 4,
              "rule": "parity"}
    corrupt_tune_cache(path, "ca", params)
    assert tune.best("ca", params, default={"lowering": "closed_form"}) \
        == {"lowering": "closed_form"}
    # a sane entry still round-trips
    cache = tune.TuneCache(path)
    cache.put("ca", tune._with_backend(dict(params)),
              {"lowering": "prefetch_lut", "fuse": 2, "coarsen": 1}, 9.0)
    assert tune.best("ca", params, cache=cache)["fuse"] == 2


# ---------------------------------------------------------------------------
# chaos: Pallas-layer scenarios (poisoned tile, corrupt table)
# ---------------------------------------------------------------------------

def test_chaos_poison_tile_detected_and_recovered():
    from repro.runtime.chaos import scenario_poison_tile
    r = scenario_poison_tile(0, True)
    assert r["status"] == "recovered", r


def test_chaos_corrupt_table_detected_and_recovered():
    from repro.runtime.chaos import scenario_corrupt_table
    r = scenario_corrupt_table(0, True)
    assert r["status"] == "recovered", r


def test_chaos_bitflip_poison_survives_nan_screen_caught_by_spot_check():
    """A finite bit-flip sails through the NaN screen -- only the
    spot-check validator catches it (why the ladder keeps both)."""
    from repro.kernels.sierpinski_write import sierpinski_write
    m = jnp.zeros((16, 16), jnp.float32)

    def run():
        return sierpinski_write(m, 1.0, block=4, grid_mode="closed_form",
                                coarsen=1, num_stages=1)

    clean = np.asarray(run())
    plan = FaultPlan(0, [FaultSpec("poison_tile", "pallas", 0,
                                   mode="bitflip")])
    with ChaosInjector(plan) as chaos:
        bad = np.asarray(run())            # unguarded: corruption lands
        assert not np.array_equal(bad, clean)
        validate_finite(bad)               # NaN screen is blind to it
        with pytest.raises(ValidationError):
            spot_check(clean)(bad)
        chaos.refresh()
        guard = GuardedCall(run, "write", retries=2,
                            backoff=_no_backoff(),
                            validators=[spot_check(clean)],
                            before_retry=chaos.refresh)
        out = np.asarray(guard())
    assert np.array_equal(out, clean)


def test_chaos_injector_restores_hooks():
    from repro.core import backend as backend_lib
    orig_pp = jax.lax.ppermute
    plan = FaultPlan(0, [FaultSpec("drop_halo", "ppermute", 0)])
    with ChaosInjector(plan):
        assert jax.lax.ppermute is not orig_pp
    assert jax.lax.ppermute is orig_pp
    prev = backend_lib.set_emit_hook(None)   # nothing left installed
    backend_lib.set_emit_hook(prev)
    assert prev is None


# ---------------------------------------------------------------------------
# serving: EOS, deterministic sampling, ladder, drain/resume
# ---------------------------------------------------------------------------

def _server(scfg=None, chaos=None, decode_kernel=""):
    from repro.configs import get_config
    from repro.launch.serve import ServeConfig, Server
    from repro.models import init
    cfg = get_config("quickstart", smoke=True)
    if decode_kernel:
        cfg = cfg.replace(attn_decode_kernel=decode_kernel)
    params = init(jax.random.PRNGKey(0), cfg)
    scfg = scfg or ServeConfig(max_len=16, retries=3,
                               backoff_base_s=0.0)
    return cfg, params, Server(cfg, params, scfg, chaos=chaos)


def test_server_eos_early_stop_per_slot():
    from repro.launch.serve import ServeConfig
    cfg, params, server = _server(ServeConfig(max_len=16,
                                              backoff_base_s=0.0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 4))
    ref = server.generate(prompts, max_new=8)
    assert ref.shape == (2, 8)             # eos_id=-1: never stops
    # pick the token slot 0 greedily emits at step 2 as the EOS id
    eos = int(ref[0, 2])
    _, _, server2 = _server(ServeConfig(max_len=16, eos_id=eos,
                                        backoff_base_s=0.0))
    out = server2.generate(prompts, max_new=8)
    # slot 0 finished at step 2: everything after is EOS padding
    assert out[0, 2] == eos
    assert (out[0, 3:] == eos).all()
    # unfinished slots keep generating the reference stream
    for b in range(2):
        stop = np.argmax(ref[b] == eos) if (ref[b] == eos).any() \
            else ref.shape[1]
        assert np.array_equal(out[b, :stop + 1], ref[b, :stop + 1])
    # all slots finished => the loop stops early
    if (out == eos).all(axis=1).all():
        assert out.shape[1] < 8


def test_server_transient_faults_recover_bit_identical():
    from repro.runtime.chaos import scenario_transient_runtime
    r = scenario_transient_runtime(0, True)
    assert r["status"] == "recovered", r
    assert r["detected"] and r["bit_identical"]


def test_server_degradation_ladder_blockspace_to_xla():
    from repro.launch.serve import ServeConfig, Server
    scfg = ServeConfig(max_len=16, temperature=0.5, seed=9, retries=2,
                       backoff_base_s=0.0)
    cfg, params, ref_xla = _server(scfg, decode_kernel="xla")
    prompts = np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 4))
    want = ref_xla.generate(prompts, max_new=5)

    # every rung-0 decode attempt faults (indices cover the retry
    # budget); the guard exhausts, the ladder steps down to xla, and
    # the stream completes there
    plan = FaultPlan(0, [FaultSpec("transient_error", "serve.decode", i,
                                   rung=0) for i in range(3)])
    chaos = ChaosInjector(plan)
    cfg_bs = cfg.replace(attn_decode_kernel="blockspace")
    faulty = Server(cfg_bs, params, scfg, chaos=chaos)
    assert faulty.ladder.rungs[0]["decode_kernel"] == "blockspace"
    out = faulty.generate(prompts, max_new=5)

    assert faulty.state == ServerState.DEGRADED
    assert faulty.ladder.level == 1
    assert len(faulty.ladder.transitions) == 1
    t = faulty.ladder.transitions[0]
    assert t["from"]["decode_kernel"] == "blockspace"
    assert t["to"]["decode_kernel"] == "xla"
    assert np.array_equal(out, want)       # served stream == xla run
    assert any(e["kind"] == "degrade" for e in faulty.events
               if isinstance(e, dict))


def test_server_ladder_exhausted_writes_failure_report(tmp_path):
    from repro.launch.serve import ServeConfig, Server
    from repro.configs import get_config
    from repro.models import init
    cfg = get_config("quickstart", smoke=True)   # xla: single-rung ladder
    params = init(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_len=16, retries=1, backoff_base_s=0.0,
                       report_dir=str(tmp_path))
    plan = FaultPlan(0, [FaultSpec("transient_error", "serve.decode", i)
                         for i in range(4)])
    server = Server(cfg, params, scfg, chaos=ChaosInjector(plan))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 4))
    with pytest.raises(GuardExhausted):
        server.generate(prompts, max_new=4)
    reports = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert reports, "no failure report written"
    rep = json.load(open(tmp_path / reports[0]))
    assert rep["classification"] == "exhausted"
    assert rep["name"] == "serve.decode"


def test_server_sigterm_drain_and_resume_bit_identical():
    from repro.runtime.chaos import scenario_sigterm_mid_decode
    r = scenario_sigterm_mid_decode(0, True)
    assert r["status"] == "recovered", r
    assert r["drained"] and r["bit_identical"]


# ---------------------------------------------------------------------------
# trainer wiring + chaos CLI
# ---------------------------------------------------------------------------

def test_trainer_writes_failure_report_on_fatal_step(tmp_path):
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.launch.train import TrainConfig, Trainer
    cfg = get_config("quickstart", smoke=True)
    tcfg = TrainConfig(steps=2, log_every=100, ckpt_dir=str(tmp_path),
                       step_retries=1, retry_backoff_s=0.0)
    tr = Trainer(cfg, tcfg)
    tr._step = lambda p, o, b: (_ for _ in ()).throw(
        ValueError("injected fatal shape error"))
    pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=16, global_batch=2))
    with pytest.raises(ValueError):
        tr.run(pipe)
    reports = [f for f in os.listdir(tmp_path)
               if f.startswith("failure_step_")]
    assert reports
    rep = json.load(open(tmp_path / reports[0]))
    assert rep["classification"] == "fatal"


def test_chaos_matrix_cli_multi_device():
    out = run_sub("""
        from repro.runtime.chaos import main
        rc = main(["--matrix", "--smoke", "--quiet",
                   "--only", "poison_tile,drop_halo,fatal_report",
                   "--out", "/tmp/chaos_ci_report.json"])
        import json
        rep = json.load(open("/tmp/chaos_ci_report.json"))
        assert rep["ok"], rep
        assert rep["devices"] == 4
        statuses = {r["fault"]: r["status"] for r in rep["results"]}
        assert statuses["drop_halo"] == "recovered", statuses
        print("RC", rc)
    """)
    assert "RC 0" in out
