"""Unit + property tests for the paper's lambda(w) map (repro.core.fractal)."""
import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import fractal as F


@pytest.mark.parametrize("r", range(0, 10))
def test_volume_is_hausdorff_power(r):
    # Lemma 1: V = 3**r = n**H
    n = 2 ** r
    assert F.gasket_volume(n) == 3 ** r
    if r:
        assert math.isclose(3 ** r, n ** F.HAUSDORFF, rel_tol=1e-9)


@pytest.mark.parametrize("r", range(0, 9))
def test_lambda_is_bijection_onto_membership(r):
    # Lemma 2 + Theorem 1: the orthotope maps 1:1 onto the embedded gasket.
    n = 2 ** r
    ox, oy = F.orthotope_shape(r)
    assert ox * oy == 3 ** r
    wy, wx = np.mgrid[0:oy, 0:ox]
    lx, ly = F.lambda_map(wx, wy, r)
    coords = set(zip(lx.ravel().tolist(), ly.ravel().tolist()))
    assert len(coords) == 3 ** r  # injective
    member = {(x, y) for y, x in zip(*np.nonzero(F.membership_grid(n)))}
    assert coords == member  # surjective onto the fractal


@pytest.mark.parametrize("r", range(0, 9))
def test_linear_map_matches_2d_map_as_set(r):
    n = 2 ** r
    i = np.arange(3 ** r)
    lx, ly = F.lambda_map_linear(i, r)
    member = {(x, y) for y, x in zip(*np.nonzero(F.membership_grid(n)))}
    assert set(zip(lx.tolist(), ly.tolist())) == member


@pytest.mark.parametrize("r", range(1, 9))
def test_lambda_inverse_roundtrip(r):
    ox, oy = F.orthotope_shape(r)
    wy, wx = np.mgrid[0:oy, 0:ox]
    lx, ly = F.lambda_map(wx, wy, r)
    iwx, iwy = F.lambda_inverse(lx, ly, r)
    assert np.array_equal(iwx, wx)
    assert np.array_equal(iwy, wy)


@given(st.integers(0, 12), st.integers(0, 3 ** 12 - 1))
@settings(max_examples=200, deadline=None)
def test_property_linear_map_hits_members_only(r, i):
    i = i % (3 ** r)
    lx, ly = F.lambda_map_linear(int(i), r)
    n = 2 ** r
    assert 0 <= lx < n and 0 <= ly < n
    assert F.is_member(int(lx), int(ly), n)


@given(st.integers(1, 10), st.data())
@settings(max_examples=100, deadline=None)
def test_property_beta_recovers_region(r, data):
    # beta_mu of a mapped coordinate's preimage equals the base-3 digit.
    i = data.draw(st.integers(0, 3 ** r - 1))
    digits = [(i // 3 ** (mu - 1)) % 3 for mu in range(1, r + 1)]
    # reconstruct (w_x, w_y) from the alternating digit convention
    wx = sum(d * 3 ** k for k, d in enumerate(digits[1::2]))
    wy = sum(d * 3 ** k for k, d in enumerate(digits[0::2]))
    for mu in range(1, r + 1):
        assert int(F.beta_mu(wx, wy, mu)) == digits[mu - 1]
    lx, ly = F.lambda_map(wx, wy, r)
    lx2, ly2 = F.lambda_map_linear(i, r)
    assert (int(lx), int(ly)) == (int(lx2), int(ly2))


@pytest.mark.parametrize("spec", [F.SIERPINSKI, F.CARPET, F.VICSEK])
@pytest.mark.parametrize("r", range(0, 4))
def test_generalized_fractal_bijection(spec, r):
    n = spec.m ** r
    i = np.arange(spec.k ** r)
    lx, ly = spec.lambda_map_linear(i, r)
    coords = set(zip(lx.tolist(), ly.tolist()))
    member = {(x, y) for y, x in zip(*np.nonzero(spec.membership_grid(n)))}
    assert coords == member
    assert len(coords) == spec.k ** r


def test_gasket_bit_test_equals_recursive_construction():
    for r in range(0, 9):
        n = 2 ** r
        assert np.array_equal(F.membership_grid(n),
                              F.SIERPINSKI.membership_grid(n))


@pytest.mark.parametrize("r", [3, 5, 6])
def test_pack_unpack_roundtrip(r):
    import jax.numpy as jnp
    n = 2 ** r
    g = jnp.arange(n * n, dtype=jnp.int32).reshape(n, n)
    p = F.pack_to_orthotope(g, r)
    ox, oy = F.orthotope_shape(r)
    assert p.shape == (oy, ox)
    u = np.asarray(F.unpack_from_orthotope(p, r, n, fill=-1))
    m = F.membership_grid(n)
    assert np.array_equal(u[m], np.asarray(g)[m])
    assert (u[~m] == -1).all()


def test_hausdorff_constant():
    assert abs(F.HAUSDORFF - 1.5849625007) < 1e-9
    assert abs(F.CARPET.hausdorff - math.log(8, 3)) < 1e-12
    assert abs(F.VICSEK.hausdorff - math.log(5, 3)) < 1e-12
