"""Full-model tests: every family builds, trains one step, and decode
matches the full forward token-by-token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, decode_step, init, init_cache,
                          logits_fn, loss_fn, prefill)
from repro.models.model import group_layout

RNG = np.random.default_rng(17)

MINI = {
    "dense-localglobal": ModelConfig(
        name="dense-localglobal", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256,
        attn_pattern=("local", "local", "global"), local_window=8,
        qkv_bias=True, dtype="float32", param_dtype="float32", remat=False),
    "mla-moe": ModelConfig(
        name="mla-moe", family="moe", n_layers=5, d_model=64, n_heads=4,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16, d_ff=128, d_ff_expert=32, moe=True,
        n_experts=8, top_k=2, n_shared_experts=1, first_dense=1,
        capacity_factor=8.0, vocab_size=128, dtype="float32",
        param_dtype="float32", remat=False),
    "mamba1": ModelConfig(
        name="mamba1", family="ssm", n_layers=4, d_model=64,
        ssm_kind="mamba1", d_state=8, expand=2, conv_kernel=4, ssd_chunk=8,
        d_ff=0, vocab_size=128, dtype="float32", param_dtype="float32",
        remat=False),
    "zamba-hybrid": ModelConfig(
        name="zamba-hybrid", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, ssm_kind="mamba2", d_state=16,
        ssd_head_dim=16, ssd_chunk=8, expand=2, conv_kernel=4,
        hybrid_attn_period=2, d_ff=128, vocab_size=128, dtype="float32",
        param_dtype="float32", remat=False),
    "moe-interleaved": ModelConfig(
        name="moe-interleaved", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, d_ff_expert=64, moe=True,
        n_experts=4, top_k=1, n_shared_experts=1, moe_period=2,
        moe_offset=1, capacity_factor=8.0, vocab_size=128, dtype="float32",
        param_dtype="float32", remat=False),
    "embeddings-input": ModelConfig(
        name="embeddings-input", family="audio", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
        input_mode="embeddings", dtype="float32", param_dtype="float32",
        remat=False),
}


def _batch(cfg, b=2, s=16):
    if cfg.input_mode == "embeddings":
        inputs = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)),
                             jnp.float32)
    else:
        inputs = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)))
    labels = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)))
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("name", list(MINI))
def test_loss_and_grads(name):
    cfg = MINI[name]
    p = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = loss_fn(p, batch, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(p)
    assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))


@pytest.mark.parametrize("name", list(MINI))
def test_decode_matches_forward(name):
    cfg = MINI[name]
    p = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    inputs = batch["inputs"]
    b, s = inputs.shape[0], inputs.shape[1]
    logits_all, _ = logits_fn(p, inputs, cfg)
    cache = init_cache(cfg, b, s)
    lg = []
    for t in range(s):
        inp = inputs[:, t:t + 1] if cfg.input_mode == "tokens" \
            else inputs[:, t:t + 1, :]
        l, cache = decode_step(p, inp, cache, t, cfg)
        lg.append(l)
    np.testing.assert_allclose(jnp.concatenate(lg, 1), logits_all,
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("name", ["dense-localglobal", "mamba1"])
def test_prefill_last_logits(name):
    cfg = MINI[name]
    p = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits_all, _ = logits_fn(p, batch["inputs"], cfg)
    pl, caches = prefill(p, batch["inputs"], cfg)
    np.testing.assert_allclose(pl, logits_all[:, -1:], rtol=2e-4, atol=2e-4)


def test_remat_matches_no_remat():
    cfg = MINI["dense-localglobal"]
    p = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    l1, _ = loss_fn(p, batch, cfg)
    l2, _ = loss_fn(p, batch, cfg.replace(remat=True))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_chunked_ce_matches_full():
    cfg = MINI["dense-localglobal"]
    p = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    l1, _ = loss_fn(p, batch, cfg)
    l2, _ = loss_fn(p, batch, cfg.replace(logit_chunk=4))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_attn_schedule_equivalence_end_to_end():
    # compact (triangular) vs bounding-box (dense) schedule: same loss
    cfg = MINI["dense-localglobal"].replace(flash_threshold=8,
                                            attn_chunk=8)
    p = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    l1, _ = loss_fn(p, batch, cfg.replace(attn_schedule="dense"))
    l2, _ = loss_fn(p, batch, cfg.replace(attn_schedule="triangular"))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_group_layout_covers_all_layers():
    for cfg in MINI.values():
        prefix, period, n_groups = group_layout(cfg)
        assert prefix + period * n_groups == cfg.n_layers


def test_param_count_analytic_close_to_actual():
    cfg = MINI["dense-localglobal"]
    p = init(jax.random.PRNGKey(0), cfg)
    actual = sum(np.prod(l.shape) for l in jax.tree.leaves(p))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.05
