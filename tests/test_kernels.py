"""Pallas kernel validation (interpret mode) against the ref.py oracles.

Per instructions: sweep shapes/dtypes and assert_allclose vs the
pure-jnp oracle for every kernel.

``REPRO_GRID_MODE`` (comma-separated lowering names) overrides the
default grid-mode sweep -- CI uses it to re-run the whole parity suite
under a single lowering (e.g. ``REPRO_GRID_MODE=mma``).
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractal as F
from repro.kernels import ops, ref

GRID_MODES = (os.environ["REPRO_GRID_MODE"].split(",")
              if os.environ.get("REPRO_GRID_MODE")
              else ["compact", "bounding"])

RNG = np.random.default_rng(0)


def _fractal_state(n, dtype, binary=False):
    mask = F.membership_grid(n)
    if binary:
        s = RNG.integers(0, 2, size=(n, n))
    else:
        s = RNG.normal(size=(n, n))
    return jnp.asarray(np.where(mask, s, 0), dtype)


# ---------------------------------------------------------------------------
# sierpinski_write / sierpinski_sum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(8, 2), (16, 4), (64, 16), (64, 64),
                                     (256, 32), (128, 8)])
@pytest.mark.parametrize("grid_mode", GRID_MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_sierpinski_write(n, block, grid_mode, dtype):
    m = _fractal_state(n, dtype)
    got = ops.sierpinski_write(m, 7.0, block=block, grid_mode=grid_mode)
    want = ref.sierpinski_write_ref(m, 7.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,block", [(16, 4), (64, 16), (256, 64)])
def test_sierpinski_sum(n, block):
    m = _fractal_state(n, jnp.float32)
    got = ops.sierpinski_sum(m, block=block)
    want = ref.sierpinski_sum_ref(m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_write_touches_exactly_the_fractal():
    n = 64
    m = jnp.zeros((n, n), jnp.float32)
    out = np.asarray(ops.sierpinski_write(m, 1.0, block=8))
    assert out.sum() == F.gasket_volume(n)  # Lemma 1: 3**r cells written


# ---------------------------------------------------------------------------
# ca_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(16, 4), (32, 8), (64, 16), (64, 32)])
@pytest.mark.parametrize("rule", ["parity", "diffusion"])
@pytest.mark.parametrize("grid_mode", GRID_MODES)
def test_ca_step(n, block, rule, grid_mode):
    s = _fractal_state(n, jnp.float32, binary=(rule == "parity"))
    got = ops.ca_step(s, jnp.zeros_like(s), rule=rule, block=block,
                      grid_mode=grid_mode)
    want = ref.ca_step_ref(s, rule)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_ca_multi_step_double_buffer():
    n, block = 32, 8
    s = _fractal_state(n, jnp.float32, binary=True)
    a, b = s, jnp.zeros_like(s)
    want = s
    for _ in range(5):
        new = ops.ca_step(a, b, rule="parity", block=block)
        b, a = a, new
        want = ref.ca_step_ref(want, "parity")
    np.testing.assert_allclose(np.asarray(a), np.asarray(want))


def test_ca_preserves_zero_outside_fractal():
    n = 64
    s = _fractal_state(n, jnp.float32)
    out = np.asarray(ops.ca_step(s, jnp.zeros_like(s), rule="diffusion",
                                 block=16))
    assert (out[~F.membership_grid(n)] == 0).all()


def test_ca_diffusion_conserves_mass():
    # graph-Laplacian diffusion conserves the total heat on the gasket
    n = 64
    s = _fractal_state(n, jnp.float32)
    out = ops.ca_step(s, jnp.zeros_like(s), rule="diffusion", block=16)
    np.testing.assert_allclose(float(jnp.sum(out)), float(jnp.sum(s)),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def _qkv(b, h, hkv, sq, sk, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, h, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,h,hkv,s,d,bq", [
    (1, 1, 1, 128, 32, 64),
    (2, 4, 2, 256, 32, 64),
    (1, 8, 1, 256, 64, 128),   # MQA
    (2, 2, 2, 128, 128, 64),
])
@pytest.mark.parametrize("grid_mode", GRID_MODES)
def test_flash_causal(b, h, hkv, s, d, bq, grid_mode):
    q, k, v = _qkv(b, h, hkv, s, s, d, jnp.float32)
    got = ops.flash_attention(q, k, v, kind="causal", block_q=bq,
                              block_k=bq, grid_mode=grid_mode)
    want = ref.attention_ref(q, k, v, "causal")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [64, 128, 256])
@pytest.mark.parametrize("grid_mode", GRID_MODES)
def test_flash_local(window, grid_mode):
    q, k, v = _qkv(1, 2, 2, 512, 512, 32, jnp.float32)
    got = ops.flash_attention(q, k, v, kind="local", window=window,
                              block_q=64, block_k=64, grid_mode=grid_mode)
    want = ref.attention_ref(q, k, v, "local", window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_full_rectangular():
    q, k, v = _qkv(1, 2, 1, 128, 384, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, kind="full", block_q=64,
                              block_k=128, grid_mode="bounding")
    want = ref.attention_ref(q, k, v, "full")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, rtol):
    q, k, v = _qkv(1, 2, 1, 256, 256, 32, dtype)
    got = ops.flash_attention(q, k, v, kind="causal", block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, "causal")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


def test_flash_compact_equals_bounding():
    # the two grid modes are bit-identical per block schedule
    q, k, v = _qkv(1, 4, 2, 256, 256, 32, jnp.float32)
    a = ops.flash_attention(q, k, v, kind="causal", block_q=64, block_k=64,
                            grid_mode="compact")
    b = ops.flash_attention(q, k, v, kind="causal", block_q=64, block_k=64,
                            grid_mode="bounding")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
