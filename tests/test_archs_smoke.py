"""Per-assigned-architecture smoke tests: reduced same-family config,
one forward/train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells, get_config
from repro.models import init, logits_fn, loss_fn
from repro.models.model import group_layout

RNG = np.random.default_rng(23)


def _batch(cfg, b=2, s=16):
    if cfg.input_mode == "embeddings":
        inputs = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)),
                             jnp.float32)
    else:
        inputs = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)))
    return {"inputs": inputs,
            "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)))}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = logits_fn(params, batch["inputs"], cfg)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert cfg.padded_vocab % 16 == 0 and cfg.padded_vocab >= cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g, np.float32)).all()
               for g in jax.tree.leaves(grads)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published dims (not instantiated,
    only inspected -- full params are exercised via the dry-run)."""
    cfg = get_config(arch)
    expected = {
        "falcon-mamba-7b": dict(n_layers=64, d_model=4096, vocab_size=65024,
                                d_state=16, ssm_kind="mamba1", d_ff=0),
        "gemma3-12b": dict(n_layers=48, d_model=3840, n_heads=16,
                           n_kv_heads=8, d_ff=15360, vocab_size=262144),
        "qwen1.5-32b": dict(n_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=40, d_ff=27392, vocab_size=152064,
                            qkv_bias=True),
        "qwen2.5-32b": dict(n_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=8, d_ff=27648, vocab_size=152064,
                            qkv_bias=True),
        "phi3-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab_size=32064),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 kv_lora_rank=512, n_experts=160, top_k=6,
                                 n_shared_experts=2, d_ff_expert=1536,
                                 vocab_size=102400, use_mla=True),
        "llama4-maverick-400b-a17b": dict(n_layers=48, d_model=5120,
                                          n_heads=40, n_kv_heads=8,
                                          n_experts=128, top_k=1,
                                          d_ff_expert=8192,
                                          vocab_size=202048),
        "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32,
                               d_ff=8192, vocab_size=2048,
                               input_mode="embeddings"),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, d_state=64,
                            ssm_kind="mamba2", vocab_size=32000,
                            hybrid_attn_period=6),
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=92553,
                              input_mode="embeddings"),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_layout_is_scannable(arch):
    cfg = get_config(arch)
    prefix, period, n_groups = group_layout(cfg)
    assert prefix + period * n_groups == cfg.n_layers
    assert prefix <= 2  # compile-time sanity: almost everything scans


def test_param_counts_are_in_the_right_ballpark():
    """Sanity check the analytic parameter counts against the arch names."""
    expect_b = {"falcon-mamba-7b": (6, 9), "gemma3-12b": (10, 14),
                "qwen1.5-32b": (28, 36), "qwen2.5-32b": (28, 36),
                "phi3-mini-3.8b": (3.3, 4.5),
                "deepseek-v2-236b": (200, 260),
                "llama4-maverick-400b-a17b": (330, 440),
                "musicgen-large": (2.5, 4.2), "zamba2-2.7b": (2.2, 3.6),
                "internvl2-26b": (18, 26)}
    for arch, (lo, hi) in expect_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


def test_active_params_llama4_and_deepseek():
    n = get_config("llama4-maverick-400b-a17b").active_param_count() / 1e9
    assert 12 <= n <= 22, n  # "a17b"
    n = get_config("deepseek-v2-236b").active_param_count() / 1e9
    assert 15 <= n <= 27, n  # 21B active


def test_cells_cover_assignment():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40
    run = [c for c in all_cells if not c[2]]
    skipped = [c for c in all_cells if c[2]]
    # long_500k runs only for the sub-quadratic archs
    assert {a for a, s, _ in run if s == "long_500k"} == {
        "falcon-mamba-7b", "gemma3-12b", "zamba2-2.7b"}
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s, _ in skipped)
