"""Serving throughput: paged continuous batching vs contiguous prealloc.

The paged pool + continuous batching wins on *mixed-length* traffic two
ways the rows make explicit:

  * wall clock -- the contiguous baseline pads every prompt in a wave
    to the wave maximum and decodes the whole wave until its longest
    request finishes; the paged scheduler prefills each request at its
    true length and refills a slot the moment its request completes;
  * memory -- the contiguous server preallocates ``slots x max_len``
    KV up front (internal fragmentation approaches 1 on short
    requests), the pool allocates pages on demand.

Also here: the zig-zag causal shard balance folded into the serving
measurements -- static per-device work imbalance of the contiguous
band partition vs the snake (exact 1.00), plus a wall-clock A/B when
the process actually has multiple devices.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import row


def _mixed_requests(vocab: int, n: int, lo: int, hi: int,
                    new_lo: int = 8, new_hi: int = 32, seed: int = 0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(lo, hi + 1, n)
    news = rng.integers(new_lo, new_hi + 1, n)
    return ([rng.integers(0, vocab, (int(L),)) for L in lens],
            [int(m) for m in news])


def _contiguous_waves(server, B, requests, max_news):
    """Static batching: waves of ``num_slots`` padded to the wave
    maximum, decoded until the wave's longest request finishes (the
    classic baseline -- short requests ride along to the wave end)."""
    for i in range(0, len(requests), B):
        wave = requests[i:i + B]
        news = max_news[i:i + B]
        lmax = max(len(p) for p in wave)
        prompts = np.stack([np.pad(p, (0, lmax - len(p)), mode="wrap")
                            for p in wave])
        if len(wave) < B:   # ragged tail wave: pad with clones
            prompts = np.pad(prompts, ((0, B - len(wave)), (0, 0)),
                             mode="edge")
        server.generate(prompts, max_new=max(news))


def _paged_drain(server, requests, max_news, rid0: int):
    for j, (prompt, m) in enumerate(zip(requests, max_news)):
        server.submit(rid0 + j, prompt, m)
    while server.pending or any(s is not None for s in server.slots):
        while server._admit_one():
            pass
        server.step()


def run(slot_counts=(2, 4), n_requests: int = 12):
    """Steady-state throughput: both servers are warmed over the full
    request set first (jit traces for every wave / prompt-length shape
    exist), then an identical second pass is timed."""
    from repro.configs import get_config
    from repro.launch.serve import (PagedServeConfig, PagedServer,
                                    ServeConfig, Server)
    from repro.models import init

    print("# serving throughput: paged continuous batching vs "
          "contiguous prealloc (mixed-length)")
    cfg = get_config("quickstart", smoke=True)
    params = init(jax.random.PRNGKey(0), cfg)
    max_len = 64
    requests, max_news = _mixed_requests(
        cfg.vocab_size, n_requests, lo=4, hi=28)
    useful = sum(max_news)
    lens_max = max(len(p) for p in requests)
    assert lens_max + max(max_news) <= max_len

    for B in slot_counts:
        scfg = PagedServeConfig(max_len=max_len, temperature=0.0,
                                num_slots=B, page_size=8,
                                num_pages=2 + B * (max_len // 8),
                                guard=False, validate=False)
        # contiguous static-batching baseline: same requests, same
        # slot count, slots x max_len KV preallocated
        contig = Server(cfg, params, ServeConfig(
            max_len=max_len, temperature=0.0, guard=False,
            validate=False))
        _contiguous_waves(contig, B, requests, max_news)   # warm
        dt_c = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            _contiguous_waves(contig, B, requests, max_news)
            dt_c = min(dt_c, time.perf_counter() - t0)
        live = float(np.mean([len(p) + m for p, m in
                              zip(requests, max_news)]))
        frag_c = 1.0 - live / max_len
        row(f"serve_throughput/contiguous/slots={B}",
            dt_c / useful * 1e6,
            f"tok_per_s={useful / dt_c:.1f},frag={frag_c:.2f}")

        server = PagedServer(cfg, params, scfg)
        _paged_drain(server, requests, max_news, rid0=0)   # warm
        dt_p = float("inf")
        for r in range(1, 4):
            t0 = time.perf_counter()
            _paged_drain(server, requests, max_news,
                         rid0=r * len(requests))
            dt_p = min(dt_p, time.perf_counter() - t0)
        frag = [s["fragmentation"] for s in server.stats_history] or [0]
        row(f"serve_throughput/paged/slots={B}/ps=8",
            dt_p / useful * 1e6,
            f"tok_per_s={useful / dt_p:.1f},"
            f"frag={float(np.mean(frag)):.2f},"
            f"speedup_vs_contiguous={dt_c / dt_p:.2f}")


def run_page_sizes(page_sizes=(4, 8, 16), n_requests: int = 6):
    """Fragmentation/throughput trade of the page-size knob (the axis
    ``repro.core.tune.autotune_paged`` searches)."""
    from repro.configs import get_config
    from repro.launch.serve import PagedServeConfig, PagedServer
    from repro.models import init

    print("# paged page-size sweep (fragmentation vs throughput)")
    cfg = get_config("quickstart", smoke=True)
    params = init(jax.random.PRNGKey(0), cfg)
    requests, max_news = _mixed_requests(
        cfg.vocab_size, n_requests, lo=4, hi=16, new_lo=4,
        new_hi=24, seed=1)
    useful = sum(max_news)
    for ps in page_sizes:
        scfg = PagedServeConfig(max_len=48, temperature=0.0,
                                num_slots=2, page_size=ps,
                                num_pages=2 + 2 * (48 // ps),
                                guard=False, validate=False)
        server = PagedServer(cfg, params, scfg)
        _paged_drain(server, requests, max_news, rid0=0)   # warm
        t0 = time.perf_counter()
        _paged_drain(server, requests, max_news, rid0=len(requests))
        dt = time.perf_counter() - t0
        frag = [s["fragmentation"] for s in server.stats_history] or [0]
        row(f"serve_paged/page_size={ps}", dt / useful * 1e6,
            f"tok_per_s={useful / dt:.1f},"
            f"frag={float(np.mean(frag)):.2f}")


def run_zigzag_balance(device_counts=(2, 4, 8), nby: int = 32):
    """Causal-triangle work balance of the serving prefill shard: the
    contiguous band partition's per-device imbalance vs the zig-zag
    snake (exactly 1.00 by construction).  Static host math -- the
    wall-clock A/B additionally runs when the process has devices."""
    from repro.core.shard import zigzag_row_order

    print("# zig-zag causal shard balance (prefill sharding)")
    for D in device_counts:
        rbd = nby // D
        contig = [sum(j + 1 for j in range(d * rbd, (d + 1) * rbd))
                  for d in range(D)]
        perm = zigzag_row_order(nby, D)
        zz = [sum(j + 1 for j in perm[d * rbd:(d + 1) * rbd])
              for d in range(D)]
        ideal = sum(contig) / D
        row(f"serve_prefill_balance/contiguous/nby={nby}/D={D}",
            0.0, f"imbalance={max(contig) / ideal:.2f}")
        row(f"serve_prefill_balance/zigzag/nby={nby}/D={D}",
            0.0, f"imbalance={max(zz) / ideal:.2f}")

    D = jax.device_count()
    if D < 2 or nby % (2 * D):
        return
    import jax.numpy as jnp

    from repro.kernels import ops
    from .common import time_fn
    rng = np.random.default_rng(0)
    s, d = nby * 16, 16
    q = jnp.asarray(rng.normal(size=(1, 2, s, d)), jnp.float32)
    mesh = jax.make_mesh((D,), ("data",))
    for bal in ("contiguous", "zigzag"):
        t = time_fn(
            lambda: ops.flash_attention(q, q, q, kind="causal",
                                        block_q=16, block_k=16,
                                        mesh=mesh, shard_balance=bal),
            warmup=1, iters=5)
        row(f"serve_prefill_shard/{bal}/s={s}/D={D}", t, "")
