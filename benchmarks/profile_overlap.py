"""DMA-vs-compute overlap profile for the pipelined kernels.

For each kernel the harness separates the launch time into a *traffic*
estimate and a *compute* estimate, then measures how much of the
traffic the ``num_stages >= 2`` software pipeline actually hides:

  * ca:     traffic is measured directly -- the fused launch is rerun
    with ``steps_scalar = 0``, which streams every supertile through
    the same DMA path but runs zero trapezoid iterations;
    ``compute = sync - traffic``.
  * flash:  traffic is the pure-bandwidth lower bound of one K + V
    sweep (a timed XLA reduction over both operands);
    ``compute = sync - traffic``.

Reported per kernel:

  ``occupancy = (traffic + compute) / pipelined`` -- how many seconds
  of serialized work each pipelined second retires (1.0 = nothing
  hidden, 2.0 = perfect double-buffering at traffic == compute);
  ``hidden_frac = clip((sync - pipelined) / traffic, 0, 1)`` -- the
  fraction of the traffic estimate the pipeline removed from the
  critical path.

Interpret-mode numbers characterize the emulated structures (the
interpreter serializes real DMA), so on CPU the value of this harness
is the trend across stages and sizes, not absolute microseconds; on
real accelerators the same rows measure true overlap.

Run:  PYTHONPATH=src python -m benchmarks.profile_overlap [--json PATH]
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from .common import dump_json, row, time_fn


def _occupancy_rows(name: str, traffic: float, sync: float,
                    pipe: float, extra: str = ""):
    compute = max(sync - traffic, 0.0)
    occ = (traffic + compute) / pipe if pipe else 0.0
    hidden = min(max((sync - pipe) / traffic, 0.0), 1.0) \
        if traffic else 0.0
    row(f"{name}/traffic", traffic, extra)
    row(f"{name}/compute", compute, extra)
    row(f"{name}/sync", sync, f"stages=1;{extra}")
    row(f"{name}/pipelined", pipe,
        f"occupancy={occ:.2f};hidden_frac={hidden:.2f};{extra}")


def profile_ca(n: int = 1024, block: int = 128, fuse: int = 8,
               steps: int = 8, stages: int = 2, iters: int = 3):
    from repro.core import fractal as F
    from repro.core.compact import CompactLayout
    from repro.core.domain import make_fractal_domain
    from repro.core.plan import GridPlan
    from repro.kernels import ops
    from repro.kernels.sierpinski_ca import _build_launch

    print(f"# profile_overlap ca: n={n} rho={block} fuse={fuse} "
          f"stages={stages}")
    mask = F.membership_grid(n)
    rng = np.random.default_rng(0)
    a0 = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                     .astype(np.float32))
    dom = make_fractal_domain("sierpinski-gasket", n // block)
    lay = CompactLayout(dom)
    a = lay.pack(a0, block)
    b = jnp.zeros_like(a)

    def run1(a, b, s):
        return ops.ca_run(a, b, steps, fuse=fuse, rule="parity",
                          block=block, grid_mode="prefetch_lut",
                          storage="compact", n=n, num_stages=s,
                          donate=False)

    assert np.array_equal(np.asarray(run1(a, b, 1)),
                          np.asarray(run1(a, b, stages)))
    t_sync = time_fn(run1, a, b, 1, warmup=1, iters=iters)
    t_pipe = time_fn(run1, a, b, stages, warmup=1, iters=iters)

    # traffic ablation: same launch, zero trapezoid iterations
    plan = GridPlan(dom, "prefetch_lut", storage="compact")
    launch = _build_launch(plan, rule="parity", alpha=0.25, block=block,
                           n=n, halo=fuse, shape=a.shape, dtype=a.dtype,
                           stages=1)
    zero = jnp.zeros((1,), jnp.int32)
    stream = jax.jit(lambda a, b: launch(a, b, zero))
    t_traffic = time_fn(stream, a, b, warmup=1, iters=iters)
    t_traffic = min(t_traffic, t_sync)
    _occupancy_rows(f"profile_overlap/ca/n={n}/rho={block}", t_traffic,
                    t_sync, t_pipe, f"fuse={fuse};stages={stages}")


def profile_flash(sq: int = 1024, d: int = 64, block: int = 128,
                  heads: int = 2, stages=(2, 4), iters: int = 3):
    from repro.kernels.flash_attention import flash_attention

    print(f"# profile_overlap flash: sq={sq} d={d} block={block} "
          f"(gpu structure KV FIFO)")
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, heads, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, heads, sq, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, heads, sq, d)), jnp.float32)

    def run1(s):
        return flash_attention(q, k, v, kind="causal", block_q=block,
                               block_k=block, num_stages=s,
                               backend="gpu-interpret")

    ref = np.asarray(run1(1))
    t_sync = time_fn(run1, 1, warmup=1, iters=iters)
    # pure-bandwidth lower bound of one K + V sweep
    sweep = jax.jit(lambda k, v: jnp.sum(k) + jnp.sum(v))
    t_traffic = min(time_fn(sweep, k, v, warmup=1, iters=iters), t_sync)
    best = t_sync, 1
    for s in stages:
        assert np.allclose(np.asarray(run1(s)), ref, atol=0, rtol=0)
        t = time_fn(run1, s, warmup=1, iters=iters)
        row(f"profile_overlap/flash/sq={sq}/d={d}/stages={s}", t,
            f"speedup={t_sync / t:.2f}")
        best = min(best, (t, s))
    _occupancy_rows(f"profile_overlap/flash/sq={sq}/d={d}", t_traffic,
                    t_sync, best[0], f"best_stages={best[1]}")


def run(quick: bool = False):
    if quick:
        profile_ca(n=256, block=32, fuse=4, steps=4)
        profile_flash(sq=256, block=64)
    else:
        profile_ca()
        profile_flash()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small sizes (CI)")
    ap.add_argument("--json", default=None,
                    help="artifact path (default: "
                         "PROFILE_overlap_<tag>.json at the repo root)")
    ap.add_argument("--no-json", action="store_true")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(quick=args.quick)
    if not args.no_json:
        path = args.json
        if path is None:
            tag = args.tag or jax.default_backend()
            root = os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)))
            path = os.path.join(root, f"PROFILE_overlap_{tag}.json")
        dump_json(path)


if __name__ == "__main__":
    main()
