"""Paper SS IV microbenchmark (Fig. 8): write a constant to every cell of
the embedded Sierpinski gasket -- lambda(w) compact map vs bounding-box.

On this CPU container the CUDA kernels are stood in for by their XLA
lowerings of the SAME two algorithms:

  * bounding-box: evaluate the membership bit test over all n^2 cells
    and masked-write (the run-time-discard baseline);
  * lambda(w):    compute the compact map for the 3^r_b blocks inside
    the timed region (the map cost is part of the measurement, as in the
    paper) and tile-scatter the value -- touching only n^H cells.

The block-size sweep rho in {1,2,4,8,16,32} mirrors the paper's
configuration space: blocks are rho x rho tiles scattered per mapped
block coordinate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.core.plan import LOWERINGS
from repro.kernels import ops
from .common import row, time_fn


@functools.partial(jax.jit, static_argnames=("n",))
def bb_write(m, n):
    y, x = jnp.mgrid[0:n, 0:n]
    member = (x & (n - 1 - y)) == 0
    return jnp.where(member, jnp.float32(7.0), m)


@functools.partial(jax.jit, static_argnames=("r_b", "block"))
def lam_write(m, r_b, block):
    i = jnp.arange(3 ** r_b, dtype=jnp.int32)
    lx, ly = F.lambda_map_linear(i, r_b)           # the paper's map
    iy = jnp.arange(block)
    ix = jnp.arange(block)
    rows = (ly[:, None, None] * block + iy[None, :, None])
    cols = (lx[:, None, None] * block + ix[None, None, :])
    gx = cols
    gy = rows
    n = (2 ** r_b) * block
    member = (gx & (n - 1 - gy)) == 0              # intra-block sub-box test
    vals = jnp.where(member, jnp.float32(7.0), 0.0)
    return m.at[rows, cols].set(vals)


@functools.partial(jax.jit, static_argnames=("r", "block"))
def lam_write_packed(mp, r, block):
    """The compact-parallel-space analogue: the state lives in the packed
    layout (3^r_b compact blocks of rho x rho), so the write touches
    exactly the n^H live cells with unit stride -- what the lambda grid
    achieves on an accelerator by never scheduling dead blocks."""
    i = jnp.arange(3 ** r, dtype=jnp.int32)
    lx, ly = F.lambda_map_linear(i, r)     # map still computed (timed)
    sel = ((lx + ly) >= 0)[:, None, None]  # consume the map
    return jnp.where(sel, jnp.float32(7.0), mp)


def run_lowering_ab(iters: int = 5):
    """GridPlan lowering A/B on the Pallas write kernel (interpret on
    CPU): the paper-family lambda-vs-LUT-vs-bounding-box comparison,
    per domain and block size.  On TPU the same sweep times the
    compiled Mosaic kernels."""
    print("# GridPlan lowering A/B (Pallas write kernel):")
    print("#   closed_form = inline lambda decode in index_maps")
    print("#   prefetch_lut = scalar-prefetch coordinate table")
    print("#   bounding     = full grid + run-time discard")
    cases = (
        ("sierpinski-gasket", 64, (8, 16, 32)),
        ("sierpinski-carpet", 27, (3, 9)),
        ("vicsek-cross", 27, (3, 9)),
    )
    for fractal, n, blocks in cases:
        m = jnp.zeros((n, n), jnp.float32)
        for rho in blocks:
            t_closed = None
            for low in LOWERINGS:
                fn = functools.partial(ops.sierpinski_write, value=7.0,
                                       block=rho, grid_mode=low,
                                       fractal=fractal)
                t = time_fn(fn, m, warmup=2, iters=iters)
                if t_closed is None:
                    t_closed = t
                row(f"gridplan_write/{fractal}/n={n}/rho={rho}/{low}", t,
                    f"speedup_vs_closed_form={t_closed / t:.2f}")


def run_storage_ab(iters: int = 5):
    """Compact-vs-embedded *storage* A/B on the Pallas write kernel: the
    same compact grid, with the state array either the dense n x n
    matrix or the packed Lemma 2 orthotope; reports bytes the write
    touches next to the time."""
    from repro.core.compact import CompactLayout
    from repro.core.domain import make_fractal_domain
    print("# storage A/B (Pallas write kernel): embedded n^2 array vs")
    print("#   compact orthotope-resident (Lemma 2) state")
    for n, rho in ((64, 8), (256, 16), (512, 32)):
        m = jnp.zeros((n, n), jnp.float32)
        lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                n // rho))
        mp = jnp.zeros(lay.array_shape(rho), jnp.float32)
        t_emb = time_fn(functools.partial(
            ops.sierpinski_write, value=7.0, block=rho), m,
            warmup=2, iters=iters)
        t_pk = time_fn(functools.partial(
            ops.sierpinski_write, value=7.0, block=rho,
            storage="compact", n=n), mp, warmup=2, iters=iters)
        b_emb, b_pk = 4 * n * n, 4 * lay.num_cells(rho)
        row(f"write_storage/embedded/n={n}/rho={rho}", t_emb,
            f"bytes={b_emb}")
        row(f"write_storage/compact/n={n}/rho={rho}", t_pk,
            f"bytes={b_pk};bytes_saved={1 - b_pk / b_emb:.3f};"
            f"speedup={t_emb / t_pk:.2f}")


def run_backend_ab(iters: int = 5):
    """Per-backend lambda(omega)-vs-bounding A/B on the Pallas write
    and CA kernels -- the paper's figure-level comparison, once per
    emission structure (:mod:`repro.core.backend`).  Rows cover the
    platform-default target plus the *other* structure emulated, so the
    artifact always carries both; on a CUDA machine the ``gpu`` rows
    time compiled Triton."""
    from repro.core import backend as backend_lib
    from repro.kernels.sierpinski_ca import ca_run

    default = backend_lib.resolve(None)
    other = (backend_lib.GPU if default.kind == "tpu"
             else backend_lib.TPU).emulated()
    targets = (default.name, other.name)
    print("# backend A/B: lambda(omega) compact grids vs bounding-box,")
    print(f"#   per emission target ({', '.join(targets)})")
    n, rho = 64, 8
    m = jnp.zeros((n, n), jnp.float32)
    state = jnp.zeros((n, n), jnp.float32)
    for tname in targets:
        times = {}
        for low in LOWERINGS:
            fn = functools.partial(ops.sierpinski_write, value=7.0,
                                   block=rho, grid_mode=low,
                                   backend=tname)
            times[low] = time_fn(fn, m, warmup=2, iters=iters)
        for low in LOWERINGS:
            extra = "" if low == "bounding" else \
                f"speedup_vs_bounding={times['bounding'] / times[low]:.2f}"
            row(f"backend_write/{tname}/n={n}/rho={rho}/{low}",
                times[low], extra)
        ca_times = {}
        for low in ("closed_form", "bounding"):
            fn = functools.partial(ca_run, steps=8, rule="parity",
                                   block=rho, grid_mode=low, fuse=4,
                                   donate=False, backend=tname)
            ca_times[low] = time_fn(fn, state, state, warmup=1,
                                    iters=iters)
        row(f"backend_ca/{tname}/n={n}/rho={rho}/closed_form",
            ca_times["closed_form"],
            f"speedup_vs_bounding="
            f"{ca_times['bounding'] / ca_times['closed_form']:.2f}")
        row(f"backend_ca/{tname}/n={n}/rho={rho}/bounding",
            ca_times["bounding"], "")
    run_map_mma_ab(iters=iters)


def run_map_mma_ab(iters: int = 5):
    """``map_mma/*``: the raw lambda decode itself, digit-basis matmul
    (:mod:`repro.core.mma`, what the ``mma`` lowering computes on the
    MXU / tensor cores) vs the integer closed form -- the map cost in
    isolation, without a kernel around it."""
    from repro.core import mma

    print("# map_mma A/B: digit-basis matmul lambda decode vs the")
    print("#   integer closed form (all 3^r blocks, jitted)")

    @functools.partial(jax.jit, static_argnames=("r",))
    def dec_int(i, r):
        lx, ly = F.lambda_map_linear(i, r)
        return lx + ly

    @functools.partial(jax.jit, static_argnames=("r",))
    def dec_mma(i, r):
        bx, by = mma.decode_linear(F.SIERPINSKI, r, i)
        return bx + by

    for r in (6, 8, 10):
        i = jnp.arange(3 ** r, dtype=jnp.int32)
        t_int = time_fn(dec_int, i, r, warmup=2, iters=iters)
        t_mma = time_fn(dec_mma, i, r, warmup=2, iters=iters)
        row(f"map_mma/r={r}/closed_form", t_int, f"blocks={3 ** r}")
        row(f"map_mma/r={r}/mma", t_mma,
            f"blocks={3 ** r};"
            f"speedup_vs_closed_form={t_int / t_mma:.2f}")


def run(max_r: int = 11):
    run_lowering_ab()
    run_storage_ab()
    run_backend_ab()
    print("# paper Fig.8 analogue: lambda vs bounding-box write, CPU/XLA")
    print("# lam_scatter = embedded-layout scatter (CPU-hostile, kept as")
    print("# the documented negative result); lam_packed = compact layout")
    print("# name,us_per_call,derived")
    for rho in (1, 4, 16, 32):
        for r in range(6, max_r + 1):
            n = 2 ** r
            if n < rho or (n // rho) < 1:
                continue
            r_b = r - int(np.log2(rho))
            m = jnp.zeros((n, n), jnp.float32)
            mp = jnp.zeros((3 ** r_b, rho, rho), jnp.float32)
            t_bb = time_fn(bb_write, m, n, iters=10)
            t_lam = time_fn(lam_write, m, r_b, rho, iters=10)
            t_pk = time_fn(lam_write_packed, mp, r_b, rho, iters=10)
            row(f"sierpinski_write_bb/n={n}/rho={rho}", t_bb,
                f"touch={n * n}")
            row(f"sierpinski_write_lam_scatter/n={n}/rho={rho}", t_lam,
                f"touch={3 ** r_b * rho * rho};speedup={t_bb / t_lam:.2f}")
            row(f"sierpinski_write_lam_packed/n={n}/rho={rho}", t_pk,
                f"touch={3 ** r_b * rho * rho};speedup={t_bb / t_pk:.2f}")
    # parallel-space table (exact, Lemma 1)
    for r in range(4, 17):
        n = 2 ** r
        eff = F.gasket_volume(n) / (n * n)
        row(f"parallel_space/n={n}", 0.0,
            f"blocks_lambda={3 ** r};blocks_bb={n * n};"
            f"efficiency={eff:.5f}")


if __name__ == "__main__":
    run()
