"""CA application benchmark: one nearest-neighbour step on the embedded
gasket, compact vs embedded storage.

Three strategies:

  * embedded: roll-based XLA stencil over the full n x n matrix
    (bounding box memory and work, n^2) -- skipped above
    ``--embedded-max-r`` where the dense buffers stop fitting the
    memory budget;
  * packed:   state stored in the compact linear-lambda layout with
    host-built lambda^-1 neighbour index tables
    (``repro.core.compact.cell_neighbor_tables``, sort-based: no dense
    scratch even at build time); touches only the n^H live cells, so it
    runs at n = 2^14..2^16 where the embedded array cannot be
    allocated;
  * kernel:   the Pallas ``ca_step`` storage A/B (embedded vs
    orthotope-resident compact blocks) at moderate n -- interpret mode
    on CPU, compiled Mosaic on TPU.

Each row reports the bytes the step must move (state read + write) next
to the time.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.core.compact import CompactLayout, cell_neighbor_tables
from repro.core.domain import make_fractal_domain
from repro.kernels import ops, ref
from .common import row, time_fn

# keep the dense path under ~0.5 GiB of f32 buffers by default
EMBEDDED_MAX_R = 12


@jax.jit
def packed_parity_step(state, tables):
    s = jnp.concatenate([state, jnp.zeros((1,), state.dtype)])
    nsum = s[tables[0]] + s[tables[1]] + s[tables[2]] + s[tables[3]]
    return jnp.mod(state + nsum, 2)


@functools.partial(jax.jit, static_argnames=("n",))
def embedded_parity_step(state, n):
    return ref.ca_step_ref(state, "parity")


def run_sched_ab(iters: int = 3, steps: int = 16, cases=((128, 8),)):
    """Fused/coarsened schedule A/B: T x ca_step (the old per-step
    driver) vs one scanned ca_run at several fuse/coarsen settings.

    Every row carries ``speedup_vs_bounding`` (the paper's baseline:
    per-step bounding-box grid) and the fused/coarsened rows also carry
    ``speedup`` vs the per-step closed-form driver -- the launch-count
    arithmetic is ceil(T/fuse) launches instead of T."""
    print("# CA schedule A/B: fused ca_run vs per-step driver "
          f"(T={steps} parity steps)")
    for n, block in cases:
        mask = F.membership_grid(n)
        rng = np.random.default_rng(0)
        a0 = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                         .astype(np.float32))
        z0 = jnp.zeros_like(a0)

        def per_step(a, b, gm):
            for _ in range(steps):
                new = ops.ca_step(a, b, rule="parity", block=block,
                                  grid_mode=gm)
                b, a = a, new
            return a

        t_bound = time_fn(per_step, a0, z0, "bounding", warmup=1,
                          iters=iters)
        t_step = time_fn(per_step, a0, z0, "closed_form", warmup=1,
                         iters=iters)
        row(f"ca_sched/per_step/bounding/n={n}/rho={block}", t_bound,
            f"launches={steps};speedup_vs_bounding=1.00")
        row(f"ca_sched/per_step/closed_form/n={n}/rho={block}", t_step,
            f"launches={steps};"
            f"speedup_vs_bounding={t_bound / t_step:.2f}")

        def fused(fuse, coarsen):
            return time_fn(
                lambda a, b: ops.ca_run(a, b, steps, fuse=fuse,
                                        rule="parity", block=block,
                                        grid_mode="closed_form",
                                        coarsen=coarsen, donate=False),
                a0, z0, warmup=1, iters=iters)

        for fuse in (4, min(16, block)):
            t_f = fused(fuse, 1)
            launches = len(ops.launch_schedule(steps, fuse))
            row(f"ca_sched/fused/fuse={fuse}/n={n}/rho={block}", t_f,
                f"launches={launches};speedup={t_step / t_f:.2f};"
                f"speedup_vs_bounding={t_bound / t_f:.2f}")
        for s in (2, 4):
            if (n // block) % s or s >= n // block:
                continue
            t_c = fused(1, s)
            row(f"ca_sched/coarsen/s={s}/n={n}/rho={block}", t_c,
                f"launches={steps};speedup={t_step / t_c:.2f};"
                f"speedup_vs_bounding={t_bound / t_c:.2f}")
        t_fc = fused(4, 2)
        launches = len(ops.launch_schedule(steps, 4))
        row(f"ca_sched/fused+coarsen/fuse=4/s=2/n={n}/rho={block}", t_fc,
            f"launches={launches};speedup={t_step / t_fc:.2f};"
            f"speedup_vs_bounding={t_bound / t_fc:.2f}")


def run_overlap_ab(iters: int = 3, steps: int = 8,
                   cases=((1024, 128), (4096, 128))):
    """Pipelining A/B: the fused CA launch with ``num_stages=2`` (DMA
    double buffers on the TPU structure; Triton stage knob on a
    compiled gpu) vs the synchronous ``num_stages=1`` path, at sizes
    where tile traffic matters.  Outputs are asserted bit-identical
    before timing.  With >= 2 devices the sharded run is also A/B'd --
    there ``num_stages=2`` additionally overlaps the ppermute halo
    exchange with interior compute -- and each sharded row reports the
    ghost bytes the exchange ships (minimal strips vs the full-row
    scheme).  ``REPRO_OVERLAP_QUICK=1`` shrinks the case list for CI
    runners."""
    import os
    if os.environ.get("REPRO_OVERLAP_QUICK"):
        cases = ((1024, 128),)
    fuse = 8
    print(f"# CA pipelining A/B: num_stages=2 vs synchronous "
          f"(T={steps} parity steps, fuse={fuse})")
    for n, block in cases:
        mask = F.membership_grid(n)
        rng = np.random.default_rng(0)
        a0 = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                         .astype(np.float32))
        lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                n // block))
        a = lay.pack(a0, block)
        b = jnp.zeros_like(a)

        def run1(a, b, stages, mesh=None):
            return ops.ca_run(a, b, steps, fuse=fuse, rule="parity",
                              block=block, grid_mode="prefetch_lut",
                              storage="compact", n=n, num_stages=stages,
                              mesh=mesh, donate=False)

        assert np.array_equal(np.asarray(run1(a, b, 1)),
                              np.asarray(run1(a, b, 2)))
        t_sync = time_fn(run1, a, b, 1, warmup=1, iters=iters)
        t_pipe = time_fn(run1, a, b, 2, warmup=1, iters=iters)
        row(f"ca_overlap/sync/n={n}/rho={block}", t_sync, "stages=1")
        row(f"ca_overlap/pipelined/n={n}/rho={block}", t_pipe,
            f"stages=2;speedup={t_sync / t_pipe:.2f}")
        if jax.device_count() >= 2:
            from repro.core.shard import ShardedPlan
            D = jax.device_count()
            mesh = jax.make_mesh((D,), ("data",))
            plan = ShardedPlan(lay.domain, "prefetch_lut",
                               storage="compact", mesh=mesh,
                               axis="data", halo=True)
            by = plan.halo.bytes_exchanged(plan, block, h=fuse)
            assert np.array_equal(np.asarray(run1(a, b, 1, mesh)),
                                  np.asarray(run1(a, b, 2, mesh)))
            ts = time_fn(run1, a, b, 1, mesh, warmup=1, iters=iters)
            tp = time_fn(run1, a, b, 2, mesh, warmup=1, iters=iters)
            row(f"ca_overlap/shard_sync/D={D}/n={n}/rho={block}", ts,
                f"stages=1;halo_bytes={by['strips']};"
                f"halo_bytes_full_rows={by['full_rows']}")
            row(f"ca_overlap/shard_pipelined/D={D}/n={n}/rho={block}",
                tp, f"stages=2;halo_bytes={by['strips']};"
                f"halo_bytes_full_rows={by['full_rows']};"
                f"speedup={ts / tp:.2f}")


def run_shard_ab(iters: int = 3, steps: int = 8, cases=((128, 8),)):
    """Mesh-scaling A/B: single-device ca_run vs the sharded run at
    every power-of-two device count the host exposes (compact storage
    slab-shards the orthotope with ppermute halos; embedded replicates
    and psums).  Emits one row per (storage, D) with the per-device
    state bytes next to the time; skipped on single-device hosts."""
    ndev = jax.device_count()
    if ndev < 2:
        print("# ca_shard: single device, skipping mesh-scaling A/B")
        return
    print(f"# CA mesh-scaling A/B: sharded ca_run over 1..{ndev} "
          f"devices (T={steps} parity steps)")
    sizes = []
    d = 2
    while d <= ndev:
        sizes.append(d)
        d *= 2
    for n, block in cases:
        mask = F.membership_grid(n)
        rng = np.random.default_rng(0)
        a0 = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                         .astype(np.float32))
        z0 = jnp.zeros_like(a0)
        lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                n // block))
        ap, zp = lay.pack(a0, block), lay.pack(z0, block)
        for storage, (a, b) in (("embedded", (a0, z0)),
                                ("compact", (ap, zp))):
            base = time_fn(
                lambda a, b: ops.ca_run(a, b, steps, fuse=1,
                                        rule="parity", block=block,
                                        grid_mode="closed_form",
                                        storage=storage, n=n,
                                        donate=False),
                a, b, warmup=1, iters=iters)
            bytes_dev = 2 * 4 * (lay.num_cells(block)
                                 if storage == "compact" else n * n)
            row(f"ca_shard/{storage}/D=1/n={n}/rho={block}", base,
                f"bytes_per_device={bytes_dev};speedup=1.00")
            for D in sizes:
                mesh = jax.make_mesh((D,), ("data",))
                t = time_fn(
                    lambda a, b: ops.ca_run(a, b, steps, fuse=1,
                                            rule="parity", block=block,
                                            grid_mode="closed_form",
                                            storage=storage, n=n,
                                            mesh=mesh, donate=False),
                    a, b, warmup=1, iters=iters)
                if storage == "compact":
                    from repro.core.shard import ShardedPlan
                    plan = ShardedPlan(
                        lay.domain, "closed_form", storage="compact",
                        mesh=mesh, axis="data", halo=True)
                    lh, lw = plan.local_storage_shape(block)
                    bytes_dev = 2 * 4 * lh * lw
                row(f"ca_shard/{storage}/D={D}/n={n}/rho={block}", t,
                    f"bytes_per_device={bytes_dev};"
                    f"speedup={base / t:.2f}")


def run_kernel_storage_ab(iters: int = 5):
    """Pallas ca_step: embedded vs orthotope-resident compact storage."""
    print("# Pallas ca_step storage A/B (embedded n^2 vs compact n^H blocks)")
    for n, block in ((64, 8), (128, 8), (256, 16)):
        mask = F.membership_grid(n)
        rng = np.random.default_rng(0)
        s = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                        .astype(np.float32))
        z = jnp.zeros_like(s)
        lay = CompactLayout(make_fractal_domain("sierpinski-gasket",
                                                n // block))
        sp, zp = lay.pack(s, block), lay.pack(z, block)
        b_emb = 2 * 4 * n * n
        b_pk = 2 * 4 * lay.num_cells(block)
        t_emb = time_fn(functools.partial(
            ops.ca_step, rule="parity", block=block), s, z,
            warmup=2, iters=iters)
        t_pk = time_fn(functools.partial(
            ops.ca_step, rule="parity", block=block, storage="compact",
            n=n), sp, zp, warmup=2, iters=iters)
        row(f"ca_kernel/embedded/n={n}/rho={block}", t_emb,
            f"bytes={b_emb}")
        row(f"ca_kernel/compact/n={n}/rho={block}", t_pk,
            f"bytes={b_pk};bytes_saved={1 - b_pk / b_emb:.3f};"
            f"speedup={t_emb / t_pk:.2f}")


def run(max_r: int = 11, storage: str = "both",
        embedded_max_r: int = EMBEDDED_MAX_R, kernel_ab: bool = True,
        sched_ab: bool = True):
    if sched_ab:
        run_sched_ab()
    if kernel_ab:
        run_kernel_storage_ab()
    print("# CA step: embedded n^2 stencil vs packed n^H gather (XLA)")
    for r in range(6, max_r + 1):
        n = 2 ** r
        t_emb = None
        if storage in ("both", "embedded"):
            if r > embedded_max_r:
                row(f"ca_embedded/n={n}", 0.0,
                    f"skipped=embedded {4 * n * n} B state over budget")
            else:
                mask = F.membership_grid(n)
                rng = np.random.default_rng(0)
                s_emb = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                                    .astype(np.float32))
                t_emb = time_fn(embedded_parity_step, s_emb, n, iters=10)
                row(f"ca_embedded/n={n}", t_emb,
                    f"cells={n * n};bytes={2 * 4 * n * n}")
        if storage in ("both", "compact"):
            vol = 3 ** r
            tables = jnp.asarray(cell_neighbor_tables(r))
            rng = np.random.default_rng(0)
            s_pack = jnp.asarray(rng.integers(0, 2, vol).astype(np.float32))
            t_pack = time_fn(packed_parity_step, s_pack, tables, iters=10)
            derived = f"cells={vol};bytes={2 * 4 * vol}"
            if t_emb is not None:
                derived += f";speedup={t_emb / t_pack:.2f}"
                # correctness cross-check against the embedded oracle
                i = np.arange(vol)
                lx, ly = F.lambda_map_linear(i, r)
                lx, ly = np.asarray(lx), np.asarray(ly)
                s_cmp = jnp.asarray(np.asarray(s_emb)[ly, lx])
                want = np.asarray(ref.ca_step_ref(s_emb, "parity"))[ly, lx]
                got = np.asarray(packed_parity_step(s_cmp, tables))
                assert np.array_equal(got, want), r
            row(f"ca_packed/n={n}", t_pack, derived)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--storage", default="both",
                    choices=["both", "embedded", "compact"])
    ap.add_argument("--max-r", type=int, default=11)
    ap.add_argument("--embedded-max-r", type=int, default=EMBEDDED_MAX_R)
    ap.add_argument("--no-kernel-ab", action="store_true")
    ap.add_argument("--no-sched-ab", action="store_true")
    args = ap.parse_args()
    run(max_r=args.max_r, storage=args.storage,
        embedded_max_r=args.embedded_max_r,
        kernel_ab=not args.no_kernel_ab,
        sched_ab=not args.no_sched_ab)


if __name__ == "__main__":
    main()
