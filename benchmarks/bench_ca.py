"""CA application benchmark: one nearest-neighbour step on the embedded
gasket.

Two XLA-measurable strategies (the Pallas kernels target TPU and are
validated separately):

  * embedded: roll-based stencil over the full n x n matrix (bounding
    box work, n^2);
  * packed:   the beyond-paper optimization from DESIGN.md -- state
    stored in the compact orthotope layout (Lemma 2) with precomputed
    lambda neighbour index tables; touches only the n^H live cells at
    the cost of gathers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractal as F
from repro.kernels import ref
from .common import row, time_fn


def packed_neighbor_tables(r: int):
    """For each of the 3^r cells (in linear lambda order) the packed index
    of its N/S/W/E neighbour, or 3^r (a zero ghost slot) if absent."""
    n = 2 ** r
    i = np.arange(3 ** r)
    lx, ly = F.lambda_map_linear(i, r)
    # embedded coord -> packed index lookup
    emb_to_packed = np.full((n, n), 3 ** r, dtype=np.int64)
    emb_to_packed[ly, lx] = i
    tables = []
    for dx, dy in ((0, -1), (0, 1), (-1, 0), (1, 0)):
        x, y = lx + dx, ly + dy
        ok = (x >= 0) & (x < n) & (y >= 0) & (y < n)
        t = np.where(ok, emb_to_packed[np.clip(y, 0, n - 1),
                                       np.clip(x, 0, n - 1)], 3 ** r)
        tables.append(t)
    return jnp.asarray(np.stack(tables))  # (4, 3^r)


@jax.jit
def packed_parity_step(state, tables):
    s = jnp.concatenate([state, jnp.zeros((1,), state.dtype)])
    nsum = s[tables[0]] + s[tables[1]] + s[tables[2]] + s[tables[3]]
    return jnp.mod(state + nsum, 2)


@functools.partial(jax.jit, static_argnames=("n",))
def embedded_parity_step(state, n):
    return ref.ca_step_ref(state, "parity")


def run():
    print("# CA step: embedded n^2 stencil vs packed n^H gather")
    for r in range(6, 12):
        n = 2 ** r
        mask = F.membership_grid(n)
        rng = np.random.default_rng(0)
        s_emb = jnp.asarray((rng.integers(0, 2, (n, n)) * mask)
                            .astype(np.float32))
        t_emb = time_fn(embedded_parity_step, s_emb, n, iters=10)

        tables = packed_neighbor_tables(r)
        i = np.arange(3 ** r)
        lx, ly = F.lambda_map_linear(i, r)
        lx, ly = np.asarray(lx), np.asarray(ly)
        s_pack = jnp.asarray(np.asarray(s_emb)[ly, lx])  # linear lambda order
        t_pack = time_fn(packed_parity_step, s_pack, tables, iters=10)

        # correctness cross-check
        want = ref.ca_step_ref(s_emb, "parity")
        got_packed = packed_parity_step(s_pack, tables)
        want_packed = np.asarray(want)[ly, lx]
        assert np.array_equal(np.asarray(got_packed), want_packed), r

        row(f"ca_embedded/n={n}", t_emb, f"cells={n * n}")
        row(f"ca_packed/n={n}", t_pack,
            f"cells={3 ** r};speedup={t_emb / t_pack:.2f}")


if __name__ == "__main__":
    run()
